//! fuzz_codecs: deterministic structure-aware differential fuzzer for
//! every codec in the registry.
//!
//! Each iteration draws a random buffer from a seeded xoshiro corpus
//! and, for every codec (the three base64 alphabets, hex, and both
//! base32 variants):
//!
//! 1. encodes it on every supported tier and compares byte-for-byte
//!    against the scalar reference;
//! 2. round-trips the decode on every tier;
//! 3. pushes random chunk splits through the streaming encoder/decoder
//!    and compares against the one-shot output (carry machinery);
//! 4. mutates the valid encoding — truncation, out-of-alphabet byte
//!    swap, padding corruption — and asserts every tier returns the
//!    *same* `Result` as the scalar path, including the exact error
//!    variant and offset.
//!
//! The run is fully deterministic: `B64SIMD_FUZZ_SEED` picks the
//! corpus (default below), `B64SIMD_FUZZ_ITERS` bounds the budget
//! (default 256; CI runs a smoke budget per pinned tier). Any
//! divergence panics with the tier, codec and input length, so a
//! failing seed reproduces with a plain re-run.
//!
//! ```sh
//! B64SIMD_FUZZ_ITERS=64 cargo run --release --example fuzz_codecs
//! ```

use std::env;

use b64simd::base64::streaming::{StreamingDecoder, StreamingEncoder};
use b64simd::base64::{Alphabet, Codec, Engine, Mode, Tier, Whitespace};
use b64simd::codec::{
    Base32Codec, Base32Variant, CodecStreamDecoder, CodecStreamEncoder, HexCodec,
};
use b64simd::workload::Rng64;

fn env_u64(name: &str, default: u64) -> u64 {
    env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Structure-aware mutants of one valid encoding: a strict-prefix
/// truncation, an out-of-alphabet byte swap, and (for padded codecs)
/// two flavors of padding corruption. Empty encodings have no
/// structure to break, so they yield no mutants.
fn mutations(rng: &mut Rng64, golden: &[u8], alphabet: &[u8], pad: Option<u8>) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    if golden.is_empty() {
        return out;
    }
    // Truncation: any strictly shorter prefix. Prefixes that land on a
    // group boundary stay valid — the parity assertion covers Ok too.
    out.push(golden[..rng.below(golden.len() as u64) as usize].to_vec());

    // Alphabet swap: overwrite one position with a byte no table in
    // this codec maps (covers both the foreign-alphabet and garbage
    // cases; the pool avoids every builtin table in both cases).
    const POOL: [u8; 8] = [b'!', b'#', b'~', b'\t', 0x00, 0x7F, 0x80, 0xFF];
    let bad = POOL
        .iter()
        .copied()
        .find(|b| !alphabet.contains(b) && Some(*b) != pad)
        .expect("pool always holds an out-of-alphabet byte");
    let mut swapped = golden.to_vec();
    swapped[rng.below(golden.len() as u64) as usize] = bad;
    out.push(swapped);

    if let Some(pad) = pad {
        // Pad corruption: a pad byte dropped somewhere inside the body…
        let mut padded = golden.to_vec();
        padded[rng.below(golden.len() as u64) as usize] = pad;
        out.push(padded);
        // …and, when the tail is padded, a data byte where a pad belongs.
        if golden.last() == Some(&pad) {
            let mut flipped = golden.to_vec();
            flipped[golden.len() - 1] = alphabet[rng.below(alphabet.len() as u64) as usize];
            out.push(flipped);
        }
    }
    out
}

/// Split `data` into random-size chunks (1..=97 bytes), exercising
/// every carry length in the streaming codecs.
fn random_chunks<'a>(rng: &mut Rng64, mut rest: &'a [u8]) -> Vec<&'a [u8]> {
    let mut out = Vec::new();
    while !rest.is_empty() {
        let take = 1 + rng.below(rest.len().min(97) as u64) as usize;
        let (head, tail) = rest.split_at(take);
        out.push(head);
        rest = tail;
    }
    out
}

fn fuzz_base64(rng: &mut Rng64, data: &[u8]) -> u64 {
    let mut checks = 0;
    for alphabet in [Alphabet::standard(), Alphabet::url(), Alphabet::imap()] {
        let scalar = Engine::with_tier(alphabet.clone(), Tier::Scalar);
        let golden = scalar.encode(data);
        for tier in Tier::supported() {
            let engine = Engine::with_tier(alphabet.clone(), tier);
            assert_eq!(
                engine.encode(data),
                golden,
                "base64/{} encode diverges, tier={tier:?} len={}",
                alphabet.name(),
                data.len()
            );
            assert_eq!(
                engine.decode(&golden).as_deref(),
                Ok(data),
                "base64/{} round-trip fails, tier={tier:?} len={}",
                alphabet.name(),
                data.len()
            );
            checks += 2;
        }

        let mut streamed = Vec::new();
        let mut enc = StreamingEncoder::from_engine(Engine::new(alphabet.clone()));
        for chunk in random_chunks(rng, data) {
            enc.update(chunk, &mut streamed);
        }
        enc.finish(&mut streamed);
        assert_eq!(streamed, golden, "base64/{} streaming encode", alphabet.name());
        let mut back = Vec::new();
        let mut dec = StreamingDecoder::from_engine(Engine::new(alphabet.clone()), Whitespace::None);
        for chunk in random_chunks(rng, &golden) {
            dec.update(chunk, &mut back).expect("valid input");
        }
        dec.finish(&mut back).expect("valid input");
        assert_eq!(back, data, "base64/{} streaming decode", alphabet.name());
        checks += 2;

        for mutant in mutations(rng, &golden, alphabet.chars(), Some(alphabet.pad())) {
            let want = scalar.decode(&mutant);
            for tier in Tier::supported() {
                let got = Engine::with_tier(alphabet.clone(), tier).decode(&mutant);
                assert_eq!(
                    got,
                    want,
                    "base64/{} mutant parity, tier={tier:?} input={:?}",
                    alphabet.name(),
                    String::from_utf8_lossy(&mutant)
                );
                checks += 1;
            }
        }
    }
    checks
}

fn fuzz_hex(rng: &mut Rng64, data: &[u8]) -> u64 {
    let mut checks = 0;
    let scalar = HexCodec::with_tier(Tier::Scalar);
    let golden = scalar.encode(data);
    let lower = golden.to_ascii_lowercase();
    for tier in Tier::supported() {
        let codec = HexCodec::with_tier(tier);
        assert_eq!(codec.encode(data), golden, "hex encode diverges, tier={tier:?}");
        assert_eq!(codec.decode(&golden).as_deref(), Ok(data), "hex round-trip, tier={tier:?}");
        // §8 case-insensitive decode must hold on every tier too.
        assert_eq!(codec.decode(&lower).as_deref(), Ok(data), "hex lowercase, tier={tier:?}");
        checks += 3;
    }

    let mut streamed = Vec::new();
    let mut enc = CodecStreamEncoder::hex();
    for chunk in random_chunks(rng, data) {
        enc.update(chunk, &mut streamed);
    }
    enc.finish(&mut streamed);
    assert_eq!(streamed, golden, "hex streaming encode");
    let mut back = Vec::new();
    let mut dec = CodecStreamDecoder::hex(Whitespace::None);
    for chunk in random_chunks(rng, &golden) {
        dec.update(chunk, &mut back).expect("valid input");
    }
    dec.finish(&mut back).expect("valid input");
    assert_eq!(back, data, "hex streaming decode");
    checks += 2;

    // Hex decodes both cases, so the swap pool sees the union table.
    for mutant in mutations(rng, &golden, b"0123456789ABCDEFabcdef", None) {
        let want = scalar.decode(&mutant);
        for tier in Tier::supported() {
            let got = HexCodec::with_tier(tier).decode(&mutant);
            assert_eq!(
                got,
                want,
                "hex mutant parity, tier={tier:?} input={:?}",
                String::from_utf8_lossy(&mutant)
            );
            checks += 1;
        }
    }
    checks
}

fn fuzz_base32(rng: &mut Rng64, data: &[u8]) -> u64 {
    let mut checks = 0;
    for variant in [Base32Variant::Std, Base32Variant::Hex] {
        let scalar = Base32Codec::with_tier(variant, Tier::Scalar);
        let golden = scalar.encode(data);
        for tier in Tier::supported() {
            let codec = Base32Codec::with_tier(variant, tier);
            assert_eq!(codec.encode(data), golden, "{variant:?} encode diverges, tier={tier:?}");
            assert_eq!(
                codec.decode(&golden, Mode::Strict).as_deref(),
                Ok(data),
                "{variant:?} round-trip, tier={tier:?}"
            );
            checks += 2;
        }

        let mut streamed = Vec::new();
        let mut enc = CodecStreamEncoder::base32(variant);
        for chunk in random_chunks(rng, data) {
            enc.update(chunk, &mut streamed);
        }
        enc.finish(&mut streamed);
        assert_eq!(streamed, golden, "{variant:?} streaming encode");
        let mut back = Vec::new();
        let mut dec = CodecStreamDecoder::base32(variant, Mode::Strict, Whitespace::None);
        for chunk in random_chunks(rng, &golden) {
            dec.update(chunk, &mut back).expect("valid input");
        }
        dec.finish(&mut back).expect("valid input");
        assert_eq!(back, data, "{variant:?} streaming decode");
        checks += 2;

        for mutant in mutations(rng, &golden, variant.chars(), Some(b'=')) {
            let want = scalar.decode(&mutant, Mode::Strict);
            for tier in Tier::supported() {
                let got = Base32Codec::with_tier(variant, tier).decode(&mutant, Mode::Strict);
                assert_eq!(
                    got,
                    want,
                    "{variant:?} mutant parity, tier={tier:?} input={:?}",
                    String::from_utf8_lossy(&mutant)
                );
                checks += 1;
            }
        }
    }
    checks
}

fn main() {
    let iters = env_u64("B64SIMD_FUZZ_ITERS", 256);
    let seed = env_u64("B64SIMD_FUZZ_SEED", 0x4648_B64D);
    println!(
        "fuzz_codecs: iters={iters} seed={seed:#x} tiers={:?} (B64SIMD_FUZZ_ITERS / \
         B64SIMD_FUZZ_SEED to vary)",
        Tier::supported()
    );
    let mut rng = Rng64::new(seed);
    let mut checks: u64 = 0;
    for i in 0..iters {
        // Mixed length profile: mostly small buffers (tail and carry
        // structure lives there), a quarter at kernel-loop sizes.
        let len = match i % 4 {
            0 => rng.below(48) as usize,
            1 => rng.below(512) as usize,
            2 => rng.below(4096) as usize,
            _ => rng.below(65536) as usize,
        };
        let mut data = vec![0u8; len];
        rng.fill(&mut data);
        checks += fuzz_base64(&mut rng, &data);
        checks += fuzz_hex(&mut rng, &data);
        checks += fuzz_base32(&mut rng, &data);
    }
    println!("fuzz_codecs: OK — {checks} differential checks, 0 divergences");
}
