//! serve_datauri — the END-TO-END driver (DESIGN.md E9).
//!
//! Boots the full three-layer system: PJRT runtime (compiled Pallas
//! kernels) under the batching coordinator under the TCP service; then
//! drives it with concurrent clients performing a realistic web workload
//! — encoding images into `data:` URIs and decoding them back — and
//! reports latency percentiles, throughput, and batching efficiency.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_datauri
//! # flags: --requests N --clients N --backend rust|pjrt
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use b64simd::base64::datauri;
use b64simd::base64::{Alphabet, Mode};
use b64simd::coordinator::backend::{native_factory, pjrt_factory, rust_factory};
use b64simd::coordinator::{Router, RouterConfig};
use b64simd::runtime::Manifest;
use b64simd::server::{serve, Client, ServerConfig};
use b64simd::workload::table3_corpus;

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_requests = flag("--requests", 200);
    let n_clients = flag("--clients", 8);
    let args: Vec<String> = std::env::args().collect();
    let want_backend = args
        .iter()
        .position(|a| a == "--backend")
        .and_then(|i| args.get(i + 1))
        .cloned();

    // --- Boot the system.
    let artifacts = Manifest::default_dir();
    let (factory, backend_name) = match want_backend.as_deref() {
        Some("rust") => (rust_factory(), "rust"),
        Some("native") => (native_factory(), "native"),
        Some("pjrt") => (pjrt_factory(artifacts), "pjrt"),
        _ if artifacts.join("manifest.json").exists() => (pjrt_factory(artifacts), "pjrt"),
        _ => (native_factory(), "native"),
    };
    let router = Arc::new(Router::new(factory, RouterConfig::default()));
    let handle = serve(
        router.clone(),
        ServerConfig { addr: "127.0.0.1:0".parse()?, ..Default::default() },
    )?;
    println!("serving on {} (backend={backend_name})", handle.addr);

    // --- Workload: the Table 3 images as data-URI payloads (the small
    //     three; the 34 MB zip would dominate a latency-focused demo).
    let corpus: Vec<_> = table3_corpus().into_iter().filter(|f| f.bytes < 1 << 20).collect();
    println!(
        "workload: {} files x {} requests x {} clients",
        corpus.len(),
        n_requests,
        n_clients
    );

    let t0 = Instant::now();
    let bytes_moved = Arc::new(AtomicU64::new(0));
    let corpus = Arc::new(corpus);
    let mut latencies_all: Vec<u64> = Vec::new();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = handle.addr;
            let corpus = corpus.clone();
            let bytes_moved = bytes_moved.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<u64>> {
                let mut client = Client::connect(addr)?;
                client.ping()?;
                let mut latencies = Vec::with_capacity(n_requests);
                for i in 0..n_requests {
                    let file = &corpus[(c + i) % corpus.len()];
                    let t = Instant::now();
                    // Encode to a data URI payload via the service...
                    let encoded = client.encode(&file.data, "standard")?;
                    // ...then decode it back (round trip = 2 requests).
                    let decoded = client.decode(&encoded, "standard", Mode::Strict)?;
                    latencies.push(t.elapsed().as_micros() as u64);
                    anyhow::ensure!(decoded == file.data, "roundtrip mismatch");
                    bytes_moved.fetch_add((encoded.len() + file.bytes) as u64, Ordering::Relaxed);
                    // Exercise the data-URI layer locally, as a browser would.
                    let uri = datauri::build("image/png", &file.data[..64.min(file.bytes)], &Alphabet::standard());
                    datauri::parse(&uri, &Alphabet::standard()).map_err(|e| anyhow::anyhow!("{e}"))?;
                }
                Ok(latencies)
            })
        })
        .collect();
    for h in handles {
        latencies_all.extend(h.join().expect("client thread")?);
    }
    let wall = t0.elapsed();

    // --- Report.
    latencies_all.sort_unstable();
    let pct = |q: f64| latencies_all[((latencies_all.len() - 1) as f64 * q) as usize];
    let total_requests = latencies_all.len() * 2; // encode + decode per iteration
    let gb = bytes_moved.load(Ordering::Relaxed) as f64 / 1e9;
    println!("\n== E2E report ==");
    println!("requests      : {total_requests} over {wall:.2?}");
    println!("throughput    : {:.0} req/s, {:.3} GB/s payload", total_requests as f64 / wall.as_secs_f64(), gb / wall.as_secs_f64());
    println!("roundtrip lat : p50={}us p90={}us p99={}us", pct(0.50), pct(0.90), pct(0.99));
    println!("server metrics: {}", router.metrics().report());
    println!("batch eff     : {:.1}% of dispatched rows were real data", router.metrics().batch_efficiency() * 100.0);
    handle.shutdown();
    Ok(())
}
