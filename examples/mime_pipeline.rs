//! mime_pipeline: the paper's motivating MIME workload (§1).
//!
//! Builds multipart email messages with binary attachments (RFC 2045
//! base64, 76-char lines), then runs an extraction pipeline that parses
//! the messages, decodes every attachment through the streaming decoder
//! in network-sized chunks, and verifies integrity.
//!
//! ```sh
//! cargo run --release --example mime_pipeline
//! ```

use b64simd::base64::mime::MimeCodec;
use b64simd::base64::{Alphabet, Codec, Mode, Whitespace};
use b64simd::base64::block::BlockCodec;
use b64simd::base64::streaming::StreamingDecoder;
use b64simd::workload::random_bytes;

const BOUNDARY: &str = "=_b64simd_boundary";

/// Build a multipart/mixed message with the given attachments.
fn build_message(attachments: &[(String, Vec<u8>)]) -> Vec<u8> {
    let mime = MimeCodec::new(Alphabet::standard());
    let mut msg = Vec::new();
    msg.extend_from_slice(b"MIME-Version: 1.0\r\n");
    msg.extend_from_slice(
        format!("Content-Type: multipart/mixed; boundary=\"{BOUNDARY}\"\r\n\r\n").as_bytes(),
    );
    for (name, data) in attachments {
        msg.extend_from_slice(format!("--{BOUNDARY}\r\n").as_bytes());
        msg.extend_from_slice(
            format!("Content-Disposition: attachment; filename=\"{name}\"\r\n").as_bytes(),
        );
        msg.extend_from_slice(b"Content-Transfer-Encoding: base64\r\n\r\n");
        msg.extend_from_slice(&mime.encode(data));
        msg.extend_from_slice(b"\r\n");
    }
    msg.extend_from_slice(format!("--{BOUNDARY}--\r\n").as_bytes());
    msg
}

/// Extract attachments: returns (filename, decoded bytes).
fn extract(msg: &[u8]) -> anyhow::Result<Vec<(String, Vec<u8>)>> {
    let text = String::from_utf8_lossy(msg);
    let mut out = Vec::new();
    for part in text.split(&format!("--{BOUNDARY}")).skip(1) {
        let Some((headers, body)) = part.split_once("\r\n\r\n") else { continue };
        if !headers.contains("base64") {
            continue;
        }
        let name = headers
            .split("filename=\"")
            .nth(1)
            .and_then(|s| s.split('"').next())
            .unwrap_or("unnamed")
            .to_string();
        // Stream-decode the body in 1500-byte "packets" (MTU-sized). The
        // CrLf whitespace policy skips the line structure inline on the
        // tiered SIMD path — no per-packet strip pass.
        let mut dec =
            StreamingDecoder::with_policy(Alphabet::standard(), Mode::Strict, Whitespace::CrLf);
        let mut data = Vec::new();
        let body = body.trim_end_matches("\r\n");
        for packet in body.as_bytes().chunks(1500) {
            dec.update(packet, &mut data).map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        }
        dec.finish(&mut data).map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        out.push((name, data));
    }
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    // Attachments with characteristic sizes: an icon, a photo, a document.
    let attachments = vec![
        ("icon.png".to_string(), random_bytes(2_357, 1)),
        ("photo.jpg".to_string(), random_bytes(141_020, 2)),
        ("report.pdf".to_string(), random_bytes(350_003, 3)),
    ];
    let message = build_message(&attachments);
    println!("built multipart message: {} bytes, {} attachments", message.len(), attachments.len());

    // Line-length conformance (RFC 2045 §6.8).
    for line in message.split(|&c| c == b'\n') {
        assert!(line.len() <= 78, "line exceeds 76+CRLF");
    }
    println!("RFC 2045 line lengths: OK (all <= 76)");

    let extracted = extract(&message)?;
    anyhow::ensure!(extracted.len() == attachments.len(), "lost attachments");
    let mut total = 0usize;
    for ((name, original), (got_name, got)) in attachments.iter().zip(&extracted) {
        anyhow::ensure!(name == got_name && original == got, "mismatch in {name}");
        total += got.len();
        println!("extracted {:<12} {:>8} bytes OK", got_name, got.len());
    }

    // A corrupted attachment must be detected, not silently accepted.
    let mut corrupted = message.clone();
    let pos = corrupted.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 200;
    corrupted[pos] = 0xFF;
    anyhow::ensure!(extract(&corrupted).is_err(), "corruption went undetected");
    println!("corruption detection: OK");

    // Equivalent one-shot decode for comparison.
    let flat = BlockCodec::with_mode(Alphabet::standard(), Mode::Strict);
    let enc = flat.encode(&attachments[1].1);
    assert_eq!(flat.decode(&enc)?, attachments[1].1);
    println!("pipeline complete: {total} attachment bytes verified");
    Ok(())
}
