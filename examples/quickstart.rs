//! Quickstart: the public API in five minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: the zero-allocation engine hot path, one-shot encode/decode,
//! runtime-swappable variants (the paper's §5 versatility claim, E8),
//! streaming, error reporting, and — when `artifacts/` exists — the same
//! operations through the compiled PJRT executables.

use std::sync::Arc;

use b64simd::base64::alphabet::STANDARD;
use b64simd::base64::{
    block::BlockCodec, encoded_len, streaming::StreamingEncoder, Alphabet, Codec, DecodeError,
    Engine,
};
use b64simd::runtime::{BlockExecutor, Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    // --- 0. The hot path: tier-dispatched, allocation-free slices.
    //        Feature detection (AVX-512 VBMI → AVX2 → SWAR → scalar)
    //        runs once; force a tier with B64SIMD_TIER=swar etc.
    let engine = Engine::get();
    let message = b"Many common document formats on the Internet are text-only.";
    let mut buf = vec![0u8; encoded_len(message.len())];
    let n = engine.encode_slice(message, &mut buf);
    println!("engine  : tier={} encoded {n} chars without allocating", engine.tier().name());

    // --- 1. One-shot encode/decode with the paper's block algorithm.
    let codec = BlockCodec::new(Alphabet::standard());
    let encoded = codec.encode(message);
    assert_eq!(encoded, &buf[..n]);
    println!("encoded : {}", String::from_utf8_lossy(&encoded));
    let decoded = codec.decode(&encoded)?;
    assert_eq!(decoded, message);
    println!("decoded : {}", String::from_utf8_lossy(&decoded));

    // --- 2. Variants are runtime data (paper §3.1: "any 64-byte mapping
    //        is feasible, even if determined dynamically at runtime").
    let url = BlockCodec::new(Alphabet::url());
    println!("url     : {}", String::from_utf8_lossy(&url.encode(&[0xFB, 0xEF, 0xFF])));
    let mut rotated = [0u8; 64];
    for i in 0..64 {
        rotated[i] = STANDARD[(i + 42) % 64];
    }
    let custom = BlockCodec::new(Alphabet::new("rot42", rotated, b'=')?);
    let custom_enc = custom.encode(message);
    assert_eq!(custom.decode(&custom_enc)?, message);
    println!("rot42   : {}", String::from_utf8_lossy(&custom_enc[..32]));

    // --- 3. Errors carry exact offsets (deferred validation underneath).
    let mut corrupt = encoded.clone();
    corrupt[13] = b'!';
    match codec.decode(&corrupt) {
        Err(DecodeError::InvalidByte { offset, byte }) => {
            println!("corrupt : invalid byte 0x{byte:02x} at offset {offset} (as expected)");
        }
        other => anyhow::bail!("expected InvalidByte, got {other:?}"),
    }

    // --- 4. Streaming: chunked input, identical output.
    let mut enc = StreamingEncoder::new(Alphabet::standard());
    let mut streamed = Vec::new();
    for chunk in message.chunks(7) {
        enc.update(chunk, &mut streamed);
    }
    enc.finish(&mut streamed);
    assert_eq!(streamed, encoded);
    println!("stream  : identical across 7-byte chunks");

    // --- 5. The compiled three-layer path (needs `make artifacts`).
    match Runtime::new(Manifest::default_dir()) {
        Ok(rt) => {
            let ex = BlockExecutor::new(Arc::new(rt));
            let data = vec![0x42u8; 48 * 4];
            let a = Alphabet::standard();
            let via_pjrt = ex.encode_blocks(&data, a.encode_table().as_bytes())?;
            assert_eq!(via_pjrt, BlockCodec::new(a).encode(&data));
            println!("pjrt    : 4 blocks encoded through the compiled HLO, matches Rust");
        }
        Err(e) => println!("pjrt    : skipped ({e}) — run `make artifacts`"),
    }
    println!("quickstart OK");
    Ok(())
}
