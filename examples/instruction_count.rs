//! instruction_count: E2 — the paper's headline instruction-count table.
//!
//! Prints the per-block op accounting for the four codec formulations and
//! the AVX-512-over-AVX2 reduction factors (paper: 7.3x encode, 5.6x
//! decode), plus where to find the jaxpr-level counts for the Pallas
//! kernels.
//!
//! ```sh
//! cargo run --release --example instruction_count
//! ```

use b64simd::perfmodel::opcount;

fn main() {
    println!("E2: instruction-count accounting (loads/stores excluded, like the paper)\n");
    print!("{}", opcount::render_table());
    println!();
    println!("Pallas-kernel (jaxpr) counts: run `python -m compile.opcount` from python/.");
    println!("Recorded results: EXPERIMENTS.md §E2.");
}
