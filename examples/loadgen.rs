//! loadgen — prove the connection cap is gone.
//!
//! Opens thousands of concurrent connections (4× the old 256-thread
//! cap by default) against the codec service and drives interleaved
//! encode traffic over every one of them, verifying each response
//! against an in-process oracle. Exits non-zero if any connection was
//! refused, any request went unanswered, or any response mismatched.
//!
//! ```text
//! cargo run --release --example loadgen -- \
//!     --connections 1000 --seconds 2 [--payload 1024] [--threads 8] \
//!     [--transport epoll|threaded] [--reactors N] [--zerocopy 0|1] \
//!     [--addr HOST:PORT]
//! ```
//!
//! Without `--addr`, an in-process server is started on the chosen
//! transport. The client side multiplexes `--connections` sockets over
//! `--threads` OS threads — the point is that the *server* holds them
//! all concurrently without a thread apiece.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use b64simd::base64::{block::BlockCodec, Alphabet, Codec};
use b64simd::coordinator::backend::native_factory;
use b64simd::coordinator::{Router, RouterConfig};
use b64simd::server::{serve, Client, ServerConfig, Transport};
use b64simd::workload::random_bytes;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let connections: usize =
        flag(&args, "--connections").map(|v| v.parse().expect("--connections")).unwrap_or(1000);
    let seconds: f64 =
        flag(&args, "--seconds").map(|v| v.parse().expect("--seconds")).unwrap_or(2.0);
    let payload_len: usize =
        flag(&args, "--payload").map(|v| v.parse().expect("--payload")).unwrap_or(1024);
    let threads: usize = flag(&args, "--threads")
        .map(|v| v.parse().expect("--threads"))
        .unwrap_or(8)
        .clamp(1, connections.max(1));
    let transport = match flag(&args, "--transport") {
        Some(v) => Transport::parse(&v).expect("--transport epoll|threaded"),
        None => Transport::from_env(),
    };
    // Reactor shards / reply path: flags override the env-driven
    // defaults (B64SIMD_REACTORS / B64SIMD_ZEROCOPY).
    let defaults = ServerConfig::default();
    let reactors: usize = flag(&args, "--reactors")
        .map(|v| v.parse().expect("--reactors"))
        .unwrap_or(defaults.reactors)
        .max(1);
    let zero_copy: bool = flag(&args, "--zerocopy")
        .map(|v| ServerConfig::parse_switch(&v).expect("--zerocopy 0|1"))
        .unwrap_or(defaults.zero_copy);

    // Client + (in-process) server sockets both live in this process;
    // the common 1024-fd soft limit dies long before 1000 connections.
    #[cfg(target_os = "linux")]
    {
        let want = (connections as u64) * 2 + 256;
        match b64simd::net::sys::raise_nofile_limit(want) {
            Ok(limit) if limit < want => {
                eprintln!("loadgen: fd limit {limit} < {want}; connects may fail")
            }
            Ok(_) => {}
            Err(e) => eprintln!("loadgen: could not raise fd limit: {e}"),
        }
    }

    let mut _server = None;
    let (addr, router) = match flag(&args, "--addr") {
        Some(a) => (a.parse().expect("--addr"), None),
        None => {
            let router = Arc::new(Router::new(native_factory(), RouterConfig::default()));
            let handle = serve(
                router.clone(),
                ServerConfig {
                    addr: "127.0.0.1:0".parse().unwrap(),
                    max_connections: connections + 16,
                    transport,
                    reactors,
                    zero_copy,
                    ..Default::default()
                },
            )
            .expect("bind in-process server");
            let addr = handle.addr;
            _server = Some(handle);
            (addr, Some(router))
        }
    };

    let payload = random_bytes(payload_len, 0x10AD);
    let oracle = BlockCodec::new(Alphabet::standard()).encode(&payload);

    println!(
        "loadgen: {connections} connections x {threads} client threads, {payload_len}B payloads, transport={} reactors={reactors} reply={}, target={addr}",
        transport.name(),
        if zero_copy { "zerocopy" } else { "vec" },
    );

    // Phase 1: open every connection and hold it.
    let refused = Arc::new(AtomicU64::new(0));
    let io_failed = Arc::new(AtomicU64::new(0));
    let open_start = Instant::now();
    let mut pools: Vec<Vec<Client>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let refused = refused.clone();
            let io_failed = io_failed.clone();
            let share = connections / threads + usize::from(t < connections % threads);
            handles.push(s.spawn(move || {
                let mut clients = Vec::with_capacity(share);
                for _ in 0..share {
                    match Client::connect(addr) {
                        Ok(mut c) => match c.ping() {
                            Ok(()) => clients.push(c),
                            Err(b64simd::server::client::ClientError::Busy(_)) => {
                                refused.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                io_failed.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(_) => {
                            io_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                clients
            }));
        }
        for h in handles {
            pools.push(h.join().unwrap());
        }
    });
    let opened: usize = pools.iter().map(|p| p.len()).sum();
    let open_secs = open_start.elapsed().as_secs_f64();

    // Phase 2: interleave verified encode requests across *every*
    // connection for the test window (each thread round-robins its
    // share, so every socket serves at least one full pass).
    let requests = Arc::new(AtomicU64::new(0));
    let mismatches = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    std::thread::scope(|s| {
        for pool in pools.iter_mut() {
            let requests = requests.clone();
            let mismatches = mismatches.clone();
            let errors = errors.clone();
            let payload = &payload;
            let oracle = &oracle;
            s.spawn(move || {
                let mut i = 0usize;
                let mut first_pass_done = pool.is_empty();
                while !first_pass_done || Instant::now() < deadline {
                    let n = pool.len();
                    if n == 0 {
                        break;
                    }
                    match pool[i % n].encode(payload, "standard") {
                        Ok(enc) => {
                            requests.fetch_add(1, Ordering::Relaxed);
                            if &enc != oracle {
                                mismatches.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    i += 1;
                    if i >= n {
                        first_pass_done = true;
                    }
                }
            });
        }
    });

    let reqs = requests.load(Ordering::Relaxed);
    let errs = errors.load(Ordering::Relaxed);
    let miss = mismatches.load(Ordering::Relaxed);
    let wire_bytes = reqs * (payload_len as u64 + oracle.len() as u64);
    let opened_of_asked = format!("{opened}/{connections}");
    println!("{:<22}{:>14}", "connections opened", opened_of_asked);
    println!("{:<22}{:>14}", "refused (busy)", refused.load(Ordering::Relaxed));
    println!("{:<22}{:>14}", "connect failures", io_failed.load(Ordering::Relaxed));
    println!("{:<22}{:>14.0}", "conns/sec (open)", opened as f64 / open_secs.max(1e-9));
    println!("{:<22}{:>14}", "requests answered", reqs);
    println!("{:<22}{:>14}", "request errors", errs);
    println!("{:<22}{:>14}", "response mismatches", miss);
    println!("{:<22}{:>14.0}", "requests/sec", reqs as f64 / seconds.max(1e-9));
    println!(
        "{:<22}{:>14.3}",
        "payload GB/s (in+out)",
        wire_bytes as f64 / seconds.max(1e-9) / 1e9
    );
    if let Some(router) = router {
        router.flush();
        println!("server: {}", router.metrics().report());
    }

    let complete = opened == connections && errs == 0 && miss == 0 && reqs >= opened as u64;
    if !complete {
        eprintln!("loadgen: FAILED (dropped/unanswered/mismatched traffic above)");
        std::process::exit(1);
    }
    println!("loadgen: OK — all {connections} concurrent connections served verified traffic");
}
