//! loadgen — prove the connection cap is gone.
//!
//! Opens thousands of concurrent connections (4× the old 256-thread
//! cap by default) against the codec service and drives interleaved
//! encode traffic over every one of them, verifying each response
//! against an in-process oracle. Exits non-zero if any connection was
//! refused, any request went unanswered, or any response mismatched.
//!
//! ```text
//! cargo run --release --example loadgen -- \
//!     --connections 1000 --seconds 2 [--payload 1024] [--threads 8] \
//!     [--transport epoll|uring|threaded] [--reactors N] [--zerocopy 0|1] \
//!     [--addr HOST:PORT] [--http]
//! ```
//!
//! `--http` drives the HTTP/1.1 gateway instead of the native frame
//! protocol: every connection is opened with a verified `GET /healthz`,
//! held, then served verified `POST /encode` traffic, and the run ends
//! with a `GET /metrics` scrape (printed, and asserted to render). The
//! in-process server gets a gateway listener automatically; with
//! `--addr`, point it at the *gateway* address.
//!
//! Without `--addr`, an in-process server is started on the chosen
//! transport. The client side multiplexes `--connections` sockets over
//! `--threads` OS threads — the point is that the *server* holds them
//! all concurrently without a thread apiece.
//!
//! `--chaos torn|slowloris|oversized|corrupt|vanish|all` switches to
//! the adversarial client: each mode misbehaves in one specific way and
//! asserts the lifecycle contract from `docs/PROTOCOL.md` — torn frames
//! are answered normally, stalled partial frames get the typed timeout
//! notice, oversized/corrupt frames poison only their own connection,
//! and clients that vanish mid-burst leak nothing. Exits non-zero on
//! any contract violation. Combine with `--features faults` and a
//! `B64SIMD_FAULTS` plan to run the same contract checks while the
//! server's own syscalls misbehave.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use b64simd::base64::{block::BlockCodec, Alphabet, Codec, Mode};
use b64simd::coordinator::backend::native_factory;
use b64simd::coordinator::{Router, RouterConfig};
use b64simd::server::proto::Message;
use b64simd::server::{serve, Client, ServerConfig, Transport};
use b64simd::workload::random_bytes;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

// ---------------------------------------------------------------------
// HTTP gateway client (--http).
// ---------------------------------------------------------------------

/// Minimal keep-alive HTTP/1.1 client for the gateway mode.
struct HttpConn {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
}

impl HttpConn {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(Self { stream, buf: Vec::new(), pos: 0 })
    }

    fn fill(&mut self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        let mut tmp = [0u8; 64 << 10];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => return Err("unexpected EOF".into()),
                Ok(n) => {
                    self.buf.extend_from_slice(&tmp[..n]);
                    return Ok(());
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("read: {e}")),
            }
        }
    }

    /// One CRLF-terminated line, CRLF consumed.
    fn line(&mut self) -> Result<String, String> {
        loop {
            if let Some(i) = self.buf[self.pos..].windows(2).position(|w| w == b"\r\n") {
                let line = String::from_utf8_lossy(&self.buf[self.pos..self.pos + i]).into_owned();
                self.pos += i + 2;
                return Ok(line);
            }
            self.fill()?;
        }
    }

    fn take(&mut self, n: usize) -> Result<Vec<u8>, String> {
        while self.buf.len() - self.pos < n {
            self.fill()?;
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    /// One request/response exchange (POST bodies use Content-Length;
    /// replies may be Content-Length or chunked).
    fn exchange(
        &mut self,
        method: &str,
        target: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>), String> {
        let mut wire = format!("{method} {target} HTTP/1.1\r\n").into_bytes();
        if method == "POST" {
            wire.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
        }
        wire.extend_from_slice(b"\r\n");
        wire.extend_from_slice(body);
        self.stream.write_all(&wire).map_err(|e| format!("send: {e}"))?;

        let status_line = self.line()?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("bad status line {status_line:?}"))?;
        let mut content_length = None;
        let mut chunked = false;
        loop {
            let line = self.line()?;
            if line.is_empty() {
                break;
            }
            let Some((k, v)) = line.split_once(':') else { continue };
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse::<usize>().ok();
            } else if k.eq_ignore_ascii_case("transfer-encoding")
                && v.eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
        let mut reply = Vec::new();
        if chunked {
            loop {
                let line = self.line()?;
                let size = usize::from_str_radix(line.trim(), 16)
                    .map_err(|_| format!("bad chunk size {line:?}"))?;
                if size == 0 {
                    self.line()?; // empty terminator line
                    break;
                }
                reply.extend_from_slice(&self.take(size)?);
                self.take(2)?; // chunk-data CRLF
            }
        } else if let Some(n) = content_length {
            reply = self.take(n)?;
        }
        Ok((status, reply))
    }
}

/// Assert the per-stage latency histograms on a `/metrics` scrape are
/// present for every stage on the gateway protocol, cumulative-monotone
/// in `le`, agree with `_count` at `+Inf`, and actually counted the
/// traffic just driven.
fn check_stage_histograms(text: &str) -> Result<(), String> {
    use std::collections::HashMap;
    let mut buckets: HashMap<String, Vec<u64>> = HashMap::new();
    let mut counts: HashMap<String, u64> = HashMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("b64simd_stage_latency_us_bucket{") {
            let (labels, value) =
                rest.split_once("} ").ok_or_else(|| format!("bad bucket line {line:?}"))?;
            let series = labels
                .split(',')
                .filter(|kv| !kv.starts_with("le="))
                .collect::<Vec<_>>()
                .join(",");
            let value: u64 =
                value.trim().parse().map_err(|_| format!("bad bucket value {line:?}"))?;
            buckets.entry(series).or_default().push(value);
        } else if let Some(rest) = line.strip_prefix("b64simd_stage_latency_us_count{") {
            let (labels, value) =
                rest.split_once("} ").ok_or_else(|| format!("bad count line {line:?}"))?;
            let value: u64 =
                value.trim().parse().map_err(|_| format!("bad count value {line:?}"))?;
            counts.insert(labels.to_string(), value);
        }
    }
    for stage in ["queue", "kernel", "sink", "flush"] {
        let series = format!("stage=\"{stage}\",proto=\"http\"");
        let b = buckets.get(&series).ok_or_else(|| format!("missing bucket series {series}"))?;
        if b.windows(2).any(|w| w[0] > w[1]) {
            return Err(format!("buckets for {series} are not cumulative-monotone: {b:?}"));
        }
        let count = *counts.get(&series).ok_or_else(|| format!("missing _count for {series}"))?;
        if b.last() != Some(&count) {
            return Err(format!("+Inf bucket {:?} != _count {count} for {series}", b.last()));
        }
        if count == 0 {
            return Err(format!("{series} recorded no samples after the gateway run"));
        }
    }
    Ok(())
}

/// The gateway load scenario: verified health checks to open, verified
/// encodes to drive, a metrics scrape to close. Returns the exit code.
fn run_http(
    addr: std::net::SocketAddr,
    connections: usize,
    threads: usize,
    seconds: f64,
    payload: &[u8],
    oracle: &[u8],
    router: Option<&Router>,
) -> i32 {
    println!("loadgen: HTTP gateway mode, target={addr}");

    // Phase 1: open every connection with a verified health check, hold.
    let refused = AtomicU64::new(0);
    let io_failed = AtomicU64::new(0);
    let open_start = Instant::now();
    let mut pools: Vec<Vec<HttpConn>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let refused = &refused;
            let io_failed = &io_failed;
            let share = connections / threads + usize::from(t < connections % threads);
            handles.push(s.spawn(move || {
                let mut conns = Vec::with_capacity(share);
                for _ in 0..share {
                    match HttpConn::connect(addr) {
                        Ok(mut c) => match c.exchange("GET", "/healthz", b"") {
                            Ok((200, body)) if body == b"ok\n" => conns.push(c),
                            Ok((503, _)) => {
                                refused.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(_) | Err(_) => {
                                io_failed.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(_) => {
                            io_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                conns
            }));
        }
        for h in handles {
            pools.push(h.join().unwrap());
        }
    });
    let opened: usize = pools.iter().map(|p| p.len()).sum();
    let open_secs = open_start.elapsed().as_secs_f64();

    // Phase 2: verified POST /encode round-robined over every socket.
    let requests = AtomicU64::new(0);
    let mismatches = AtomicU64::new(0);
    let errors = AtomicU64::new(0);
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    std::thread::scope(|s| {
        for pool in pools.iter_mut() {
            let requests = &requests;
            let mismatches = &mismatches;
            let errors = &errors;
            s.spawn(move || {
                let mut i = 0usize;
                let mut first_pass_done = pool.is_empty();
                while !first_pass_done || Instant::now() < deadline {
                    let n = pool.len();
                    if n == 0 {
                        break;
                    }
                    match pool[i % n].exchange("POST", "/encode", payload) {
                        Ok((200, body)) => {
                            requests.fetch_add(1, Ordering::Relaxed);
                            if body != oracle {
                                mismatches.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Ok(_) | Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    i += 1;
                    if i >= n {
                        first_pass_done = true;
                    }
                }
            });
        }
    });

    // Close with a metrics scrape: the ops surface must render, and the
    // per-stage histograms must cover the traffic we just drove.
    let mut scrape_ok = false;
    let scrape = HttpConn::connect(addr)
        .map_err(|e| e.to_string())
        .and_then(|mut c| c.exchange("GET", "/metrics", b""));
    match scrape {
        Ok((200, body)) => {
            let text = String::from_utf8_lossy(&body);
            scrape_ok = text.contains("b64simd_conns_open")
                && text.contains("b64simd_http_requests_total");
            match check_stage_histograms(&text) {
                Ok(()) => {}
                Err(e) => {
                    scrape_ok = false;
                    b64simd::log_error!("loadgen", "stage histogram check failed: {e}");
                }
            }
            for line in text.lines().filter(|l| {
                l.starts_with("b64simd_http_requests_total")
                    || l.starts_with("b64simd_conns_open")
                    || l.starts_with("b64simd_rate_limited_total")
                    || l.starts_with("b64simd_timeouts_total")
                    || l.starts_with("b64simd_stage_latency_us_count")
            }) {
                println!("metrics: {line}");
            }
        }
        Ok((status, _)) => b64simd::log_error!("loadgen", "metrics scrape answered {status}"),
        Err(e) => b64simd::log_error!("loadgen", "metrics scrape failed: {e}"),
    }

    let reqs = requests.load(Ordering::Relaxed);
    let errs = errors.load(Ordering::Relaxed);
    let miss = mismatches.load(Ordering::Relaxed);
    let opened_of_asked = format!("{opened}/{connections}");
    println!("{:<22}{:>14}", "connections opened", opened_of_asked);
    println!("{:<22}{:>14}", "refused (503 busy)", refused.load(Ordering::Relaxed));
    println!("{:<22}{:>14}", "connect failures", io_failed.load(Ordering::Relaxed));
    println!("{:<22}{:>14.0}", "conns/sec (open)", opened as f64 / open_secs.max(1e-9));
    println!("{:<22}{:>14}", "requests answered", reqs);
    println!("{:<22}{:>14}", "request errors", errs);
    println!("{:<22}{:>14}", "response mismatches", miss);
    println!("{:<22}{:>14.0}", "requests/sec", reqs as f64 / seconds.max(1e-9));
    if let Some(router) = router {
        router.flush();
        println!("server: {}", router.metrics().report());
    }

    let complete =
        opened == connections && errs == 0 && miss == 0 && reqs >= opened as u64 && scrape_ok;
    if !complete {
        b64simd::log_error!("loadgen", "FAILED (dropped/unanswered/mismatched HTTP traffic above)");
        return 1;
    }
    println!("loadgen: OK — all {connections} gateway connections served verified traffic");
    0
}

// ---------------------------------------------------------------------
// Adversarial chaos client (--chaos MODE).
// ---------------------------------------------------------------------

/// Read one length-prefixed reply frame; `Ok(None)` on EOF/reset.
fn read_reply(stream: &mut TcpStream) -> Result<Option<Message>, String> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match stream.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err("EOF inside a length prefix".into()),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset && got == 0 => {
                return Ok(None)
            }
            Err(e) => return Err(format!("reading reply prefix: {e}")),
        }
    }
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    stream
        .read_exact(&mut body)
        .map_err(|e| format!("reading reply body: {e}"))?;
    Message::from_bytes(&body).map(Some).map_err(|e| format!("parsing reply: {e}"))
}

fn chaos_connect(addr: std::net::SocketAddr) -> Result<TcpStream, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect: {e}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(15)))
        .map_err(|e| format!("set_read_timeout: {e}"))?;
    Ok(stream)
}

fn encode_frame(id: u64, data: Vec<u8>) -> Vec<u8> {
    Message::Encode { id, alphabet: "standard".into(), mode: Mode::Strict, data }
        .to_frame_bytes()
        .expect("frame within MAX_FRAME")
}

/// Torn delivery: valid frames dribbled a byte (then a half) at a time
/// must be reassembled and answered normally — byte-granularity arrival
/// never trips the frame-granularity read deadline.
fn chaos_torn(addr: std::net::SocketAddr) -> Result<(), String> {
    let payload = random_bytes(256, 0xC0A7);
    let oracle = BlockCodec::new(Alphabet::standard()).encode(&payload);
    let mut stream = chaos_connect(addr)?;
    let frame = encode_frame(1, payload.clone());
    for b in &frame {
        stream.write_all(&[*b]).map_err(|e| format!("torn write: {e}"))?;
        std::thread::sleep(Duration::from_micros(200));
    }
    match read_reply(&mut stream)? {
        Some(Message::RespData { id: 1, data }) if data == oracle => {}
        other => return Err(format!("torn frame not answered normally: {other:?}")),
    }
    // Same again split at an awkward boundary (inside the length prefix).
    let frame = encode_frame(2, payload);
    stream.write_all(&frame[..3]).map_err(|e| format!("torn write: {e}"))?;
    std::thread::sleep(Duration::from_millis(20));
    stream.write_all(&frame[3..]).map_err(|e| format!("torn write: {e}"))?;
    match read_reply(&mut stream)? {
        Some(Message::RespData { id: 2, data }) if data == oracle => Ok(()),
        other => Err(format!("split frame not answered normally: {other:?}")),
    }
}

/// Slow loris: a partial frame that never completes must draw the
/// normative `timeout: request frame stalled` notice and a close —
/// dripping header bytes must not refresh the deadline.
fn chaos_slowloris(addr: std::net::SocketAddr) -> Result<(), String> {
    let mut stream = chaos_connect(addr)?;
    stream
        .write_all(&[64, 0, 0])
        .map_err(|e| format!("loris write: {e}"))?;
    match read_reply(&mut stream)? {
        Some(Message::RespError { id: 0, message })
            if message == "timeout: request frame stalled" =>
        {
            match read_reply(&mut stream)? {
                None => Ok(()),
                other => Err(format!("expected EOF after stall notice, got {other:?}")),
            }
        }
        other => Err(format!("expected stall notice, got {other:?}")),
    }
}

/// Oversized: a length prefix beyond MAX_FRAME poisons the connection
/// (no reply, close) and must not take the server with it.
fn chaos_oversized(addr: std::net::SocketAddr) -> Result<(), String> {
    let mut stream = chaos_connect(addr)?;
    stream
        .write_all(&u32::MAX.to_le_bytes())
        .map_err(|e| format!("oversized write: {e}"))?;
    match read_reply(&mut stream)? {
        None | Some(Message::RespError { .. }) => {}
        other => return Err(format!("oversized frame answered with {other:?}")),
    }
    // The poison stayed on our connection.
    let mut probe = Client::connect(addr).map_err(|e| format!("probe connect: {e:?}"))?;
    probe.ping().map_err(|e| format!("probe ping after oversized: {e:?}"))
}

/// Corrupt: pipelined good requests *before* garbage are answered, the
/// garbage closes only that connection.
fn chaos_corrupt(addr: std::net::SocketAddr) -> Result<(), String> {
    let payload = random_bytes(64, 0xBAD);
    let oracle = BlockCodec::new(Alphabet::standard()).encode(&payload);
    let mut stream = chaos_connect(addr)?;
    let mut wire = encode_frame(3, payload);
    // A plausible length prefix followed by an unknown tag and junk.
    wire.extend_from_slice(&16u32.to_le_bytes());
    wire.extend_from_slice(&[0x7F; 16]);
    stream.write_all(&wire).map_err(|e| format!("corrupt write: {e}"))?;
    match read_reply(&mut stream)? {
        Some(Message::RespData { id: 3, data }) if data == oracle => {}
        other => return Err(format!("request before corruption unanswered: {other:?}")),
    }
    loop {
        // Poison semantics allow one error frame before the close.
        match read_reply(&mut stream)? {
            None => break,
            Some(Message::RespError { .. }) => continue,
            other => return Err(format!("unexpected reply after corruption: {other:?}")),
        }
    }
    let mut probe = Client::connect(addr).map_err(|e| format!("probe connect: {e:?}"))?;
    probe.ping().map_err(|e| format!("probe ping after corruption: {e:?}"))
}

/// Vanish: clients that drop mid-burst with replies unread (the close
/// turns into RST) must leak nothing — the server keeps serving and its
/// connection gauge drains.
fn chaos_vanish(addr: std::net::SocketAddr, router: Option<&Router>) -> Result<(), String> {
    let before = router.map(|r| r.metrics().conns_open.load(Ordering::Relaxed));
    for i in 0..32u64 {
        let mut stream = chaos_connect(addr)?;
        let mut wire = Vec::new();
        for j in 0..4 {
            wire.extend_from_slice(&encode_frame(i * 8 + j, random_bytes(512, i * 31 + j)));
        }
        // Half a frame on the end so the server is mid-parse when the
        // socket dies.
        wire.extend_from_slice(&[9, 9, 9]);
        stream.write_all(&wire).map_err(|e| format!("vanish write: {e}"))?;
        drop(stream); // unread replies => RST at the server
    }
    // Server must still be healthy...
    let payload = random_bytes(128, 0xDEAD);
    let oracle = BlockCodec::new(Alphabet::standard()).encode(&payload);
    let mut probe = Client::connect(addr).map_err(|e| format!("probe connect: {e:?}"))?;
    let got = probe
        .encode(&payload, "standard")
        .map_err(|e| format!("probe encode after vanish: {e:?}"))?;
    if got != oracle {
        return Err("probe encode mismatched after vanish".into());
    }
    // ...and (in-process only) the vanished connections must all be
    // reaped once the dust settles.
    if let (Some(router), Some(before)) = (router, before) {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let open = router.metrics().conns_open.load(Ordering::Relaxed);
            if open <= before + 1 {
                break; // +1 = our live probe
            }
            if Instant::now() > deadline {
                return Err(format!("vanished conns leaked: gauge {open} (baseline {before})"));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    Ok(())
}

/// Run the requested chaos modes; returns the process exit code.
fn run_chaos(mode: &str, addr: std::net::SocketAddr, router: Option<&Router>) -> i32 {
    let all = ["torn", "slowloris", "oversized", "corrupt", "vanish"];
    let selected: Vec<&str> = if mode == "all" {
        all.to_vec()
    } else if all.contains(&mode) {
        vec![mode]
    } else {
        b64simd::log_error!("loadgen", "unknown --chaos mode '{mode}' (torn|slowloris|oversized|corrupt|vanish|all)");
        return 2;
    };
    let mut failures = 0;
    for m in &selected {
        let result = match *m {
            "torn" => chaos_torn(addr),
            "slowloris" => chaos_slowloris(addr),
            "oversized" => chaos_oversized(addr),
            "corrupt" => chaos_corrupt(addr),
            "vanish" => chaos_vanish(addr, router),
            _ => unreachable!(),
        };
        match result {
            Ok(()) => println!("chaos {m:<10} OK"),
            Err(e) => {
                failures += 1;
                b64simd::log_error!("loadgen", "chaos {m:<10} FAILED: {e}");
            }
        }
    }
    if let Some(router) = router {
        router.flush();
        println!("server: {}", router.metrics().report());
    }
    if failures > 0 {
        b64simd::log_error!("loadgen", "chaos FAILED ({failures}/{} modes)", selected.len());
        1
    } else {
        println!("loadgen: chaos OK — lifecycle contract held across {} modes", selected.len());
        0
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let connections: usize =
        flag(&args, "--connections").map(|v| v.parse().expect("--connections")).unwrap_or(1000);
    let seconds: f64 =
        flag(&args, "--seconds").map(|v| v.parse().expect("--seconds")).unwrap_or(2.0);
    let payload_len: usize =
        flag(&args, "--payload").map(|v| v.parse().expect("--payload")).unwrap_or(1024);
    let threads: usize = flag(&args, "--threads")
        .map(|v| v.parse().expect("--threads"))
        .unwrap_or(8)
        .clamp(1, connections.max(1));
    let transport = match flag(&args, "--transport") {
        // Flags parse strictly: a typo should fail loudly with the
        // accepted set, not silently run the default transport.
        Some(v) => Transport::parse_strict(&v).unwrap_or_else(|e| panic!("--transport: {e}")),
        None => Transport::from_env(),
    };
    // Reactor shards / reply path: flags override the env-driven
    // defaults (B64SIMD_REACTORS / B64SIMD_ZEROCOPY).
    let defaults = ServerConfig::default();
    let reactors: usize = flag(&args, "--reactors")
        .map(|v| v.parse().expect("--reactors"))
        .unwrap_or(defaults.reactors)
        .max(1);
    let zero_copy: bool = flag(&args, "--zerocopy")
        .map(|v| ServerConfig::parse_switch(&v).expect("--zerocopy 0|1"))
        .unwrap_or(defaults.zero_copy);
    let chaos = flag(&args, "--chaos");
    // `--http` is a bare switch (`flag` expects a value), so scan for it.
    let http_mode = args.iter().any(|a| a == "--http");

    // Client + (in-process) server sockets both live in this process;
    // the common 1024-fd soft limit dies long before 1000 connections.
    #[cfg(target_os = "linux")]
    {
        let want = (connections as u64) * 2 + 256;
        match b64simd::net::sys::raise_nofile_limit(want) {
            Ok(limit) if limit < want => {
                b64simd::log_warn!("loadgen", "fd limit {limit} < {want}; connects may fail")
            }
            Ok(_) => {}
            Err(e) => b64simd::log_warn!("loadgen", "could not raise fd limit: {e}"),
        }
    }

    let mut _server = None;
    let mut http_target = None;
    let (addr, router) = match flag(&args, "--addr") {
        Some(a) => (a.parse().expect("--addr"), None),
        None => {
            let router = Arc::new(Router::new(native_factory(), RouterConfig::default()));
            let mut config = ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                max_connections: connections + 16,
                transport,
                reactors,
                zero_copy,
                ..Default::default()
            };
            if chaos.is_some() {
                // Tight lifecycle windows so the slow-loris scenario
                // resolves in milliseconds, not the production 10s.
                config.read_timeout = Duration::from_millis(400);
                config.idle_timeout = Duration::from_secs(5);
                config.write_timeout = Duration::from_secs(2);
            }
            if http_mode {
                config.http_addr = Some("127.0.0.1:0".parse().unwrap());
            }
            let handle = serve(router.clone(), config).expect("bind in-process server");
            let addr = handle.addr;
            http_target = handle.http_addr;
            _server = Some(handle);
            (addr, Some(router))
        }
    };

    if let Some(mode) = chaos {
        let code = run_chaos(&mode, addr, router.as_deref());
        if let Some(handle) = _server.take() {
            handle.shutdown(); // graceful drain is part of the contract
        }
        std::process::exit(code);
    }

    let payload = random_bytes(payload_len, 0x10AD);
    let oracle = BlockCodec::new(Alphabet::standard()).encode(&payload);

    if http_mode {
        // With `--addr` the caller points us straight at the gateway;
        // in-process runs got a gateway listener above.
        let target = http_target.unwrap_or(addr);
        let code =
            run_http(target, connections, threads, seconds, &payload, &oracle, router.as_deref());
        if let Some(handle) = _server.take() {
            handle.shutdown();
        }
        std::process::exit(code);
    }

    println!(
        "loadgen: {connections} connections x {threads} client threads, {payload_len}B payloads, transport={} reactors={reactors} reply={}, target={addr}",
        transport.name(),
        if zero_copy { "zerocopy" } else { "vec" },
    );

    // Phase 1: open every connection and hold it.
    let refused = Arc::new(AtomicU64::new(0));
    let io_failed = Arc::new(AtomicU64::new(0));
    let open_start = Instant::now();
    let mut pools: Vec<Vec<Client>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let refused = refused.clone();
            let io_failed = io_failed.clone();
            let share = connections / threads + usize::from(t < connections % threads);
            handles.push(s.spawn(move || {
                let mut clients = Vec::with_capacity(share);
                for _ in 0..share {
                    match Client::connect(addr) {
                        Ok(mut c) => match c.ping() {
                            Ok(()) => clients.push(c),
                            Err(b64simd::server::client::ClientError::Busy(_)) => {
                                refused.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                io_failed.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(_) => {
                            io_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                clients
            }));
        }
        for h in handles {
            pools.push(h.join().unwrap());
        }
    });
    let opened: usize = pools.iter().map(|p| p.len()).sum();
    let open_secs = open_start.elapsed().as_secs_f64();

    // Phase 2: interleave verified encode requests across *every*
    // connection for the test window (each thread round-robins its
    // share, so every socket serves at least one full pass).
    let requests = Arc::new(AtomicU64::new(0));
    let mismatches = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    std::thread::scope(|s| {
        for pool in pools.iter_mut() {
            let requests = requests.clone();
            let mismatches = mismatches.clone();
            let errors = errors.clone();
            let payload = &payload;
            let oracle = &oracle;
            s.spawn(move || {
                let mut i = 0usize;
                let mut first_pass_done = pool.is_empty();
                while !first_pass_done || Instant::now() < deadline {
                    let n = pool.len();
                    if n == 0 {
                        break;
                    }
                    match pool[i % n].encode(payload, "standard") {
                        Ok(enc) => {
                            requests.fetch_add(1, Ordering::Relaxed);
                            if &enc != oracle {
                                mismatches.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    i += 1;
                    if i >= n {
                        first_pass_done = true;
                    }
                }
            });
        }
    });

    let reqs = requests.load(Ordering::Relaxed);
    let errs = errors.load(Ordering::Relaxed);
    let miss = mismatches.load(Ordering::Relaxed);
    let wire_bytes = reqs * (payload_len as u64 + oracle.len() as u64);
    let opened_of_asked = format!("{opened}/{connections}");
    println!("{:<22}{:>14}", "connections opened", opened_of_asked);
    println!("{:<22}{:>14}", "refused (busy)", refused.load(Ordering::Relaxed));
    println!("{:<22}{:>14}", "connect failures", io_failed.load(Ordering::Relaxed));
    println!("{:<22}{:>14.0}", "conns/sec (open)", opened as f64 / open_secs.max(1e-9));
    println!("{:<22}{:>14}", "requests answered", reqs);
    println!("{:<22}{:>14}", "request errors", errs);
    println!("{:<22}{:>14}", "response mismatches", miss);
    println!("{:<22}{:>14.0}", "requests/sec", reqs as f64 / seconds.max(1e-9));
    println!(
        "{:<22}{:>14.3}",
        "payload GB/s (in+out)",
        wire_bytes as f64 / seconds.max(1e-9) / 1e9
    );
    if let Some(router) = router {
        router.flush();
        println!("server: {}", router.metrics().report());
    }

    let complete = opened == connections && errs == 0 && miss == 0 && reqs >= opened as u64;
    if !complete {
        b64simd::log_error!("loadgen", "FAILED (dropped/unanswered/mismatched traffic above)");
        std::process::exit(1);
    }
    println!("loadgen: OK — all {connections} concurrent connections served verified traffic");
}
