//! file_codec: encode/decode the Table 3 corpus with every codec,
//! reporting throughput per file — the interactive companion to
//! `benches/table3.rs`.
//!
//! ```sh
//! cargo run --release --example file_codec [-- --fast]
//! ```

use b64simd::base64::{block::BlockCodec, scalar::ScalarCodec, swar::SwarCodec, Alphabet, Codec};
use b64simd::util::bench::{bench, opts_from_env, BenchOpts};
use b64simd::workload::table3_corpus;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = if fast {
        BenchOpts { reps: 3, min_rep_time: std::time::Duration::from_millis(2), warmup: std::time::Duration::from_millis(2) }
    } else {
        opts_from_env()
    };
    let alphabet = Alphabet::standard();
    let codecs: Vec<(&str, Box<dyn Codec>)> = vec![
        ("scalar", Box::new(ScalarCodec::new(alphabet.clone()))),
        ("swar", Box::new(SwarCodec::new(alphabet.clone()))),
        ("block", Box::new(BlockCodec::new(alphabet.clone()))),
    ];
    println!("Table 3 workload (synthetic, size-matched — DESIGN.md §2)");
    println!("{:<20}{:>12}  {}", "source", "bytes", "decode GB/s per codec (+memcpy)");
    for file in table3_corpus() {
        let encoded = codecs[2].1.encode(&file.data);
        print!("{:<20}{:>12}  ", file.name, file.bytes);
        // memcpy reference (same buffer size as the base64 text, like the paper).
        let mut dst = vec![0u8; encoded.len()];
        let r = bench("memcpy", encoded.len(), &opts, || {
            dst.copy_from_slice(std::hint::black_box(&encoded));
            std::hint::black_box(&dst);
        });
        print!("memcpy={:.2} ", r.gbps);
        for (name, codec) in &codecs {
            let mut out = Vec::with_capacity(file.bytes + 3);
            let r = bench(*name, encoded.len(), &opts, || {
                out.clear();
                codec.decode_into(std::hint::black_box(&encoded), &mut out).unwrap();
                std::hint::black_box(&out);
            });
            print!("{name}={:.2} ", r.gbps);
        }
        let (mc, chrome, avx2, avx512) = file.paper_gbps;
        println!("| paper: memcpy={mc} chrome={chrome} avx2={avx2} avx512={avx512}");
        // Correctness guard: roundtrip every file once.
        assert_eq!(codecs[2].1.decode(&encoded).unwrap(), file.data);
    }
    println!("\nSpeeds are GB/s relative to base64 bytes (paper §4 convention).");
    Ok(())
}
