//! Property-test toolkit: deterministic generators + a forall driver.
//!
//! proptest is not available offline; this provides the subset the test
//! suite needs — seeded random inputs over a size sweep, with the failing
//! case's (seed, length) reported so a regression test can pin it.

use crate::workload::Rng64;

/// Run `cases` property checks over random byte strings of length
/// `0..=max_len` (biased toward boundary lengths), panicking with the
/// reproducing parameters on the first failure.
pub fn forall_bytes(cases: usize, max_len: usize, seed: u64, prop: impl Fn(&[u8]) -> Result<(), String>) {
    let mut rng = Rng64::new(seed);
    // Boundary lengths first: the paper's block geometry edges (48/64),
    // the cache-line ±1 edges of the store subsystem's alignment peel,
    // and the ±1 edges of its staging granule (3072 raw bytes → 4096
    // staged chars — see base64::stores).
    let boundaries = [
        0usize, 1, 2, 3, 4, 47, 48, 49, 63, 64, 65, 95, 96, 97, 127, 128,
        3071, 3072, 3073, 4095, 4096, 4097,
    ];
    let run = |rng: &mut Rng64, len: usize, case: usize| {
        let mut data = vec![0u8; len];
        rng.fill(&mut data);
        if let Err(msg) = prop(&data) {
            panic!("property failed (case {case}, len {len}, seed {seed}): {msg}");
        }
    };
    let mut case = 0;
    for &len in boundaries.iter().filter(|&&l| l <= max_len) {
        run(&mut rng, len, case);
        case += 1;
    }
    while case < cases {
        let len = (rng.below(max_len as u64 + 1)) as usize;
        run(&mut rng, len, case);
        case += 1;
    }
}

/// Like [`forall_bytes`] but the input is valid base64 of the standard
/// alphabet (unpadded multiple of 4).
pub fn forall_base64(cases: usize, max_quads: usize, seed: u64, prop: impl Fn(&[u8]) -> Result<(), String>) {
    let alphabet = crate::base64::Alphabet::standard();
    let chars = alphabet.chars();
    let mut rng = Rng64::new(seed);
    for case in 0..cases {
        let quads = rng.below(max_quads as u64 + 1) as usize;
        let data: Vec<u8> = (0..quads * 4).map(|_| chars[rng.below(64) as usize]).collect();
        if let Err(msg) = prop(&data) {
            panic!("property failed (case {case}, quads {quads}, seed {seed}): {msg}");
        }
    }
}

/// Check helper: equality with context.
pub fn check_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, what: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{what}: {a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall_bytes(50, 100, 1, |data| check_eq(data.len(), data.len(), "len"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall_bytes(50, 100, 2, |data| {
            if data.len() == 48 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn forall_base64_generates_valid_input() {
        use crate::base64::{block::BlockCodec, Alphabet, Codec};
        let codec = BlockCodec::new(Alphabet::standard());
        forall_base64(30, 64, 3, |b64| {
            codec.decode(b64).map(|_| ()).map_err(|e| e.to_string())
        });
    }
}
