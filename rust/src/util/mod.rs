//! In-tree utilities: a minimal JSON parser for the artifact manifest, a
//! benchmark statistics harness mirroring the paper's methodology
//! (median of 10), and a deterministic property-test toolkit.

pub mod bench;
pub mod json;
pub mod prop;
