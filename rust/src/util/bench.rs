//! Benchmark harness mirroring the paper's methodology (§4): "To measure
//! the speed, we take 10 measures, compute the median time. Our timings
//! include some fixed overhead costs such as the function call."
//!
//! criterion is not available offline, so this is the in-tree equivalent:
//! warmup, N timed repetitions (each running the closure enough times to
//! exceed a minimum window), median + MAD, GB/s relative to a caller-
//! declared byte count (the paper uses *base64* bytes as the reference).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Series label.
    pub name: String,
    /// Reference byte count per closure call (base64 bytes, per paper).
    pub bytes: usize,
    /// Median per-call wall time.
    pub median: Duration,
    /// Median absolute deviation (robust spread).
    pub mad: Duration,
    /// Throughput over the reference byte count.
    pub gbps: f64,
    /// 50th-percentile per-call wall time (nearest rank over the
    /// repetition samples — coarse at the paper's 10 reps, but monotone
    /// and stable enough to track in artifacts).
    pub p50: Duration,
    /// 90th-percentile per-call wall time.
    pub p90: Duration,
    /// 99th-percentile per-call wall time (the max at < 100 reps).
    pub p99: Duration,
}

impl BenchResult {
    /// Format as one aligned table row.
    pub fn row(&self) -> String {
        format!(
            "{:<28}{:>12}B {:>12.3?} ±{:>9.3?} {:>9.3} GB/s",
            self.name, self.bytes, self.median, self.mad, self.gbps
        )
    }

    /// The result as one JSON object for [`emit_json`] artifacts:
    /// throughput plus the per-repetition latency percentiles in
    /// nanoseconds (the schema `bench::tests::json_obj_schema` pins).
    pub fn json_obj(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"bytes\":{},\"median_ns\":{},\"mad_ns\":{},\"gbps\":{:.4},\"p50_ns\":{},\"p90_ns\":{},\"p99_ns\":{}}}",
            self.name,
            self.bytes,
            self.median.as_nanos(),
            self.mad.as_nanos(),
            self.gbps,
            self.p50.as_nanos(),
            self.p90.as_nanos(),
            self.p99.as_nanos()
        )
    }
}

/// Nearest-rank percentile over sorted samples (`q` in (0, 1]).
fn percentile(sorted: &[Duration], q: f64) -> Duration {
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Harness options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Timed repetitions (the paper uses 10).
    pub reps: usize,
    /// Minimum wall time per repetition; the closure is looped to reach it.
    pub min_rep_time: Duration,
    /// Untimed warmup before the first repetition.
    pub warmup: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            reps: 10,
            min_rep_time: Duration::from_millis(10),
            warmup: Duration::from_millis(50),
        }
    }
}

/// Quick-mode options for CI (`B64SIMD_BENCH_FAST=1`).
pub fn opts_from_env() -> BenchOpts {
    if std::env::var_os("B64SIMD_BENCH_FAST").is_some() {
        BenchOpts {
            reps: 5,
            min_rep_time: Duration::from_millis(2),
            warmup: Duration::from_millis(5),
        }
    } else {
        BenchOpts::default()
    }
}

/// Run one benchmark: `f` processes `bytes` reference bytes per call.
pub fn bench(name: impl Into<String>, bytes: usize, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        f();
    }
    // Calibrate inner loop count.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let inner = (opts.min_rep_time.as_nanos() / once.as_nanos()).clamp(1, 1 << 24) as usize;
    // Timed repetitions.
    let mut samples: Vec<Duration> = (0..opts.reps.max(1))
        .map(|_| {
            let t = Instant::now();
            for _ in 0..inner {
                f();
            }
            t.elapsed() / inner as u32
        })
        .collect();
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|&s| if s > median { s - median } else { median - s })
        .collect();
    devs.sort_unstable();
    let mad = devs[devs.len() / 2];
    let gbps = bytes as f64 / median.as_nanos().max(1) as f64;
    BenchResult {
        name: name.into(),
        bytes,
        median,
        mad,
        gbps,
        p50: percentile(&samples, 0.50),
        p90: percentile(&samples, 0.90),
        p99: percentile(&samples, 0.99),
    }
}

/// Simple aligned table printer for a series of results.
pub fn print_results(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    for r in results {
        println!("{}", r.row());
    }
}

/// Write a machine-readable bench artifact next to the human table.
///
/// `B64SIMD_BENCH_JSON` turns it on: `1` writes `BENCH_<name>.json`
/// into the working directory, any other value names the target
/// directory. CI uploads these as run artifacts so the perf trajectory
/// becomes tracked files rather than scrollback.
pub fn emit_json(name: &str, json: &str) {
    let Some(v) = std::env::var_os("B64SIMD_BENCH_JSON") else { return };
    let dir = if v == "1" { std::path::PathBuf::from(".") } else { std::path::PathBuf::from(&v) };
    let path = dir.join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, json) {
        Ok(()) => crate::log_info!("bench", "wrote {}", path.display()),
        Err(e) => crate::log_warn!("bench", "could not write {}: {e}", path.display()),
    }
}

/// Format a series as CSV (size, gbps) for figure regeneration.
pub fn to_csv(results: &[BenchResult]) -> String {
    let mut out = String::from("name,bytes,median_ns,gbps\n");
    for r in results {
        out.push_str(&format!("{},{},{},{:.4}\n", r.name, r.bytes, r.median.as_nanos(), r.gbps));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> BenchOpts {
        BenchOpts {
            reps: 3,
            min_rep_time: Duration::from_micros(200),
            warmup: Duration::from_micros(100),
        }
    }

    #[test]
    fn measures_a_memcpy() {
        let src = vec![1u8; 64 << 10];
        let mut dst = vec![0u8; 64 << 10];
        let r = bench("memcpy", src.len(), &fast_opts(), || {
            dst.copy_from_slice(std::hint::black_box(&src));
            std::hint::black_box(&dst);
        });
        assert!(r.gbps > 0.5, "memcpy measured at {} GB/s", r.gbps);
        assert!(r.median > Duration::ZERO);
    }

    #[test]
    fn csv_format() {
        let r = BenchResult {
            name: "x".into(),
            bytes: 10,
            median: Duration::from_nanos(100),
            mad: Duration::ZERO,
            gbps: 0.1,
            p50: Duration::from_nanos(100),
            p90: Duration::from_nanos(120),
            p99: Duration::from_nanos(150),
        };
        let csv = to_csv(&[r]);
        assert!(csv.starts_with("name,bytes"));
        assert!(csv.contains("x,10,100,0.1000"));
    }

    /// Schema check for the artifact rows: every `json_obj` parses as
    /// JSON, carries the throughput and percentile fields the CI
    /// artifacts track, and the percentiles are monotone.
    #[test]
    fn json_obj_schema() {
        let data = vec![3u8; 4 << 10];
        let r = bench("schema", data.len(), &fast_opts(), || {
            std::hint::black_box(data.iter().map(|&b| b as u64).sum::<u64>());
        });
        let parsed = crate::util::json::Value::parse(&r.json_obj()).expect("row must be JSON");
        let obj = match parsed {
            crate::util::json::Value::Object(m) => m,
            other => panic!("row must be an object, got {other:?}"),
        };
        for key in ["name", "bytes", "median_ns", "mad_ns", "gbps", "p50_ns", "p90_ns", "p99_ns"] {
            assert!(obj.contains_key(key), "missing {key} in {obj:?}");
        }
        let num = |key: &str| match &obj[key] {
            crate::util::json::Value::Number(n) => *n,
            other => panic!("{key} must be a number, got {other:?}"),
        };
        assert!(num("p50_ns") > 0.0);
        assert!(num("p50_ns") <= num("p90_ns"));
        assert!(num("p90_ns") <= num("p99_ns"));
        assert_eq!(num("bytes"), data.len() as f64);
    }

    #[test]
    fn faster_code_scores_higher() {
        let data = vec![7u8; 32 << 10];
        let fast = bench("sum", data.len(), &fast_opts(), || {
            std::hint::black_box(data.iter().map(|&b| b as u64).sum::<u64>());
        });
        let slow = bench("sum3", data.len(), &fast_opts(), || {
            for _ in 0..3 {
                std::hint::black_box(data.iter().map(|&b| b as u64).sum::<u64>());
            }
        });
        assert!(fast.gbps > slow.gbps);
    }
}
