//! A minimal recursive-descent JSON parser.
//!
//! The artifact manifest is the only JSON this crate reads, but the build
//! is fully offline so we parse it in-tree instead of pulling serde_json.
//! Supports the complete JSON grammar (RFC 8259) except that numbers are
//! surfaced as `f64` (the manifest only carries small integers).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (surfaced as `f64`).
    Number(f64),
    /// A string.
    String(String),
    /// An array.
    Array(Vec<Value>),
    /// An object (key-sorted).
    Object(BTreeMap<String, Value>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the failure in the input (0 for schema errors).
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(input: &str) -> Result<Value, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer, if exact.
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        (n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64).then_some(n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` + type projection, with a descriptive error for manifests.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| JsonError { offset: 0, message: format!("missing string field '{key}'") })
    }

    /// [`Value::req_str`]'s integer sibling.
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| JsonError { offset: 0, message: format!("missing integer field '{key}'") })
    }

    /// [`Value::req_str`]'s array sibling.
    pub fn req_array(&self, key: &str) -> Result<&[Value], JsonError> {
        self.get(key)
            .and_then(Value::as_array)
            .ok_or_else(|| JsonError { offset: 0, message: format!("missing array field '{key}'") })
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let lo_hex = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(lo_hex)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let c = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input was &str: valid UTF-8).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let v = Value::parse(
            r#"{"format":"hlo-text","row_classes":[16,64],"artifacts":[{"name":"e","rows":16}]}"#,
        )
        .unwrap();
        assert_eq!(v.req_str("format").unwrap(), "hlo-text");
        let classes: Vec<usize> =
            v.req_array("row_classes").unwrap().iter().filter_map(Value::as_usize).collect();
        assert_eq!(classes, [16, 64]);
        assert_eq!(v.req_array("artifacts").unwrap()[0].req_usize("rows").unwrap(), 16);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Number(-1250.0));
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::String("a\nb".into()));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Value::parse(r#""é😀""#).unwrap(),
            Value::String("é😀".into())
        );
        assert_eq!(Value::parse("\"héllo\"").unwrap(), Value::String("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("\"abc").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse(r#""\uD800x""#).is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Value::parse(r#"[[1,2],[3,[4,{"a":[]}]]]"#).unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].as_array().unwrap()[1].as_usize(), Some(2));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("{}").unwrap(), Value::Object(Default::default()));
        assert_eq!(Value::parse("[]").unwrap(), Value::Array(vec![]));
    }
}
