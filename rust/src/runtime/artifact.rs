//! `artifacts/manifest.json` schema — the contract with `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::util::json::Value;

/// What a compiled computation does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(rows,48) u8, (64,) u8 table -> (rows,64) u8`.
    Encode,
    /// `(rows,64) u8, (128,) u8 table -> ((rows,48) u8, (rows,1) u8 err)`.
    Decode,
    /// `(rows,64) u8, (128,) u8 table -> (rows,1) u8 err`.
    Validate,
    /// `(rows,48) u8, tables -> ((rows,48) u8, (rows,1) u8)` self-check.
    Roundtrip,
}

impl ArtifactKind {
    fn from_str(s: &str) -> Option<Self> {
        match s {
            "encode" => Some(Self::Encode),
            "decode" => Some(Self::Decode),
            "validate" => Some(Self::Validate),
            "roundtrip" => Some(Self::Roundtrip),
            _ => None,
        }
    }
}

impl std::fmt::Display for ArtifactKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Encode => "encode",
            Self::Decode => "decode",
            Self::Validate => "validate",
            Self::Roundtrip => "roundtrip",
        };
        f.write_str(s)
    }
}

/// One compiled HLO module.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Unique artifact name (e.g. `encode_r64`).
    pub name: String,
    /// HLO text filename relative to the manifest directory.
    pub file: String,
    /// What computation the module performs.
    pub kind: ArtifactKind,
    /// Row-count size class this executable was compiled for.
    pub rows: usize,
    /// Input tensor shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes.
    pub outputs: Vec<Vec<usize>>,
    /// First 16 hex chars of the HLO file's SHA-256 (staleness check).
    pub sha256_16: String,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Interchange format tag (must be `hlo-text`).
    pub format: String,
    /// Element dtype of the block tensors (must be `u8`).
    pub dtype: String,
    /// Rows per Pallas tile the kernels were compiled with.
    pub tile_rows: usize,
    /// Compiled row-count size classes, ascending.
    pub row_classes: Vec<usize>,
    /// Every compiled module.
    pub artifacts: Vec<Artifact>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

/// Manifest loading errors.
#[derive(Debug)]
pub enum ManifestError {
    /// The manifest file could not be read.
    Io(std::io::Error),
    /// The manifest JSON did not parse or lacked fields.
    Parse(String),
    /// The manifest declares a format/dtype this runtime cannot run.
    Unsupported(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "reading manifest: {e} (run `make artifacts` first?)"),
            Self::Parse(m) => write!(f, "parsing manifest: {m}"),
            Self::Unsupported(m) => write!(f, "unsupported manifest: {m}"),
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

fn shape_list(v: &Value, key: &str) -> Result<Vec<Vec<usize>>, ManifestError> {
    v.req_array(key)
        .map_err(|e| ManifestError::Parse(e.to_string()))?
        .iter()
        .map(|shape| {
            shape
                .as_array()
                .ok_or_else(|| ManifestError::Parse(format!("{key}: expected array of arrays")))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| ManifestError::Parse(format!("{key}: non-integer dim")))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Parse a manifest document (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, ManifestError> {
        let root = Value::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let p = |e: crate::util::json::JsonError| ManifestError::Parse(e.to_string());
        let format = root.req_str("format").map_err(p)?.to_string();
        let dtype = root.req_str("dtype").map_err(p)?.to_string();
        if format != "hlo-text" {
            return Err(ManifestError::Unsupported(format!("format={format}")));
        }
        if dtype != "u8" {
            return Err(ManifestError::Unsupported(format!("dtype={dtype}")));
        }
        let tile_rows = root.req_usize("tile_rows").map_err(p)?;
        let row_classes = root
            .req_array("row_classes")
            .map_err(p)?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| ManifestError::Parse("bad row class".into())))
            .collect::<Result<Vec<_>, _>>()?;
        if row_classes.is_empty() {
            return Err(ManifestError::Unsupported("empty row_classes".into()));
        }
        let artifacts = root
            .req_array("artifacts")
            .map_err(p)?
            .iter()
            .map(|a| {
                let kind_str = a.req_str("kind").map_err(p)?;
                let kind = ArtifactKind::from_str(kind_str)
                    .ok_or_else(|| ManifestError::Unsupported(format!("kind={kind_str}")))?;
                Ok(Artifact {
                    name: a.req_str("name").map_err(p)?.to_string(),
                    file: a.req_str("file").map_err(p)?.to_string(),
                    kind,
                    rows: a.req_usize("rows").map_err(p)?,
                    inputs: shape_list(a, "inputs")?,
                    outputs: shape_list(a, "outputs")?,
                    sha256_16: a
                        .get("sha256_16")
                        .and_then(Value::as_str)
                        .unwrap_or_default()
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>, ManifestError>>()?;
        Ok(Self { format, dtype, tile_rows, row_classes, artifacts, dir })
    }

    /// Load `<dir>/manifest.json` and validate the format announcement.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Default artifact directory: `$B64SIMD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("B64SIMD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find the artifact for (kind, rows).
    pub fn find(&self, kind: ArtifactKind, rows: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.kind == kind && a.rows == rows)
    }

    /// Smallest row class that fits `rows` blocks (else the largest class,
    /// to be used repeatedly).
    pub fn row_class_for(&self, rows: usize) -> usize {
        self.row_classes
            .iter()
            .copied()
            .find(|&c| c >= rows)
            .unwrap_or_else(|| *self.row_classes.last().expect("non-empty row classes"))
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let json = r#"{
            "format": "hlo-text", "dtype": "u8", "tile_rows": 16,
            "row_classes": [16, 64, 256, 1024],
            "artifacts": [
                {"name": "encode_r16", "file": "encode_r16.hlo.txt", "kind": "encode",
                 "rows": 16, "inputs": [[16,48],[64]], "outputs": [[16,64]]},
                {"name": "decode_r64", "file": "decode_r64.hlo.txt", "kind": "decode",
                 "rows": 64, "inputs": [[64,64],[128]], "outputs": [[64,48],[64,1]]}
            ]
        }"#;
        Manifest::parse(json, PathBuf::from("/tmp/a")).unwrap()
    }

    #[test]
    fn find_by_kind_and_rows() {
        let m = sample();
        assert!(m.find(ArtifactKind::Encode, 16).is_some());
        assert!(m.find(ArtifactKind::Encode, 64).is_none());
        assert!(m.find(ArtifactKind::Decode, 64).is_some());
        assert_eq!(m.find(ArtifactKind::Decode, 64).unwrap().outputs.len(), 2);
    }

    #[test]
    fn row_class_selection() {
        let m = sample();
        assert_eq!(m.row_class_for(1), 16);
        assert_eq!(m.row_class_for(16), 16);
        assert_eq!(m.row_class_for(17), 64);
        assert_eq!(m.row_class_for(300), 1024);
        assert_eq!(m.row_class_for(5000), 1024);
    }

    #[test]
    fn rejects_unknown_format() {
        let json = r#"{"format": "proto", "dtype": "u8", "tile_rows": 16,
                       "row_classes": [16], "artifacts": []}"#;
        assert!(matches!(
            Manifest::parse(json, PathBuf::new()),
            Err(ManifestError::Unsupported(_))
        ));
    }

    #[test]
    fn rejects_unknown_kind() {
        let json = r#"{"format": "hlo-text", "dtype": "u8", "tile_rows": 16,
            "row_classes": [16],
            "artifacts": [{"name":"x","file":"x","kind":"mystery","rows":16,
                           "inputs":[],"outputs":[]}]}"#;
        assert!(matches!(
            Manifest::parse(json, PathBuf::new()),
            Err(ManifestError::Unsupported(_))
        ));
    }

    #[test]
    fn path_of_joins_dir() {
        let m = sample();
        let a = m.find(ArtifactKind::Encode, 16).unwrap();
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/a/encode_r16.hlo.txt"));
    }
}
