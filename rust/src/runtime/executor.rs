//! Typed block-level execution over the PJRT runtime.
//!
//! The executor owns the byte marshalling: flat `&[u8]` input buffers are
//! wrapped as u8 literals of the executable's static shape (padding the
//! final partial batch with zeros — padded rows are discarded on output),
//! and outputs are copied back into plain `Vec<u8>`. The per-row error
//! bytes of the decode graph come back alongside the payload so the
//! coordinator can perform the paper's single end-of-stream check.

use std::sync::Arc;

use super::artifact::ArtifactKind;
use super::client::{Loaded, Runtime};
use crate::base64::{B64_BLOCK, RAW_BLOCK};

/// Result of a batched block decode.
pub struct BlockDecodeOutput {
    /// `rows * 48` decoded bytes (padded rows already trimmed).
    pub data: Vec<u8>,
    /// One error byte per row; MSB set = row contained an invalid char.
    pub err: Vec<u8>,
}

/// Encode/decode whole 48/64-byte blocks through the compiled artifacts.
pub struct BlockExecutor {
    runtime: Arc<Runtime>,
}

fn u8_literal(dims: &[usize], data: &[u8]) -> anyhow::Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
        .map_err(|e| anyhow::anyhow!("creating u8 literal {dims:?}: {e:?}"))
}

impl BlockExecutor {
    /// Wrap a runtime handle.
    pub fn new(runtime: Arc<Runtime>) -> Self {
        Self { runtime }
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Pick the executable row class for a block count.
    pub fn row_class_for(&self, rows: usize) -> usize {
        self.runtime.manifest().row_class_for(rows)
    }

    fn run(&self, loaded: &Loaded, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = loaded
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", loaded.artifact.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e:?}", loaded.artifact.name))?;
        // aot.py lowers with return_tuple=True: always a tuple, any arity.
        literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {}: {e:?}", loaded.artifact.name))
    }

    /// Encode `rows` 48-byte blocks (`input.len() == rows * 48`) with the
    /// given 64-byte alphabet table. Returns `rows * 64` base64 chars.
    ///
    /// `rows` may be smaller than the executable class; the batch is
    /// zero-padded and the padded rows are trimmed from the output.
    pub fn encode_blocks(&self, input: &[u8], table: &[u8; 64]) -> anyhow::Result<Vec<u8>> {
        assert!(input.len() % RAW_BLOCK == 0, "input must be whole 48-byte blocks");
        let rows = input.len() / RAW_BLOCK;
        let class = self.row_class_for(rows);
        let loaded = self.runtime.load(ArtifactKind::Encode, class)?;
        // The table literal is identical for every chunk: create it once.
        let t = u8_literal(&[64], table)?;
        let mut out = Vec::with_capacity(rows * B64_BLOCK);
        for chunk in input.chunks(class * RAW_BLOCK) {
            let chunk_rows = chunk.len() / RAW_BLOCK;
            let padded;
            let chunk = if chunk_rows < class {
                padded = {
                    let mut p = chunk.to_vec();
                    p.resize(class * RAW_BLOCK, 0);
                    p
                };
                &padded[..]
            } else {
                chunk
            };
            let x = u8_literal(&[class, RAW_BLOCK], chunk)?;
            let outputs = self.run(&loaded, &[x, t.clone()])?;
            let chars: Vec<u8> = outputs[0]
                .to_vec::<u8>()
                .map_err(|e| anyhow::anyhow!("encode output: {e:?}"))?;
            out.extend_from_slice(&chars[..chunk_rows * B64_BLOCK]);
        }
        Ok(out)
    }

    /// Decode `rows` 64-char blocks with the 128-byte decode table.
    /// Padded rows are trimmed from both outputs. Note zero-padding is
    /// *invalid* base64, so padded rows flag errors — the caller must
    /// only inspect the first `rows` error bytes (this method already
    /// trims them).
    pub fn decode_blocks(&self, input: &[u8], dtable: &[u8; 128]) -> anyhow::Result<BlockDecodeOutput> {
        assert!(input.len() % B64_BLOCK == 0, "input must be whole 64-char blocks");
        let rows = input.len() / B64_BLOCK;
        let class = self.row_class_for(rows);
        let loaded = self.runtime.load(ArtifactKind::Decode, class)?;
        let t = u8_literal(&[128], dtable)?;
        let mut data = Vec::with_capacity(rows * RAW_BLOCK);
        let mut err = Vec::with_capacity(rows);
        for chunk in input.chunks(class * B64_BLOCK) {
            let chunk_rows = chunk.len() / B64_BLOCK;
            let padded;
            let chunk = if chunk_rows < class {
                padded = {
                    let mut p = chunk.to_vec();
                    p.resize(class * B64_BLOCK, 0);
                    p
                };
                &padded[..]
            } else {
                chunk
            };
            let x = u8_literal(&[class, B64_BLOCK], chunk)?;
            let outputs = self.run(&loaded, &[x, t.clone()])?;
            let blocks: Vec<u8> = outputs[0]
                .to_vec::<u8>()
                .map_err(|e| anyhow::anyhow!("decode output: {e:?}"))?;
            let flags: Vec<u8> = outputs[1]
                .to_vec::<u8>()
                .map_err(|e| anyhow::anyhow!("decode err output: {e:?}"))?;
            data.extend_from_slice(&blocks[..chunk_rows * RAW_BLOCK]);
            err.extend_from_slice(&flags[..chunk_rows]);
        }
        Ok(BlockDecodeOutput { data, err })
    }

    /// Validate-only: per-row error bytes for `rows` 64-char blocks.
    pub fn validate_blocks(&self, input: &[u8], dtable: &[u8; 128]) -> anyhow::Result<Vec<u8>> {
        assert!(input.len() % B64_BLOCK == 0);
        let rows = input.len() / B64_BLOCK;
        let class = self.row_class_for(rows);
        let loaded = self.runtime.load(ArtifactKind::Validate, class)?;
        let t = u8_literal(&[128], dtable)?;
        let mut err = Vec::with_capacity(rows);
        for chunk in input.chunks(class * B64_BLOCK) {
            let chunk_rows = chunk.len() / B64_BLOCK;
            let padded;
            let chunk = if chunk_rows < class {
                padded = {
                    let mut p = chunk.to_vec();
                    p.resize(class * B64_BLOCK, 0);
                    p
                };
                &padded[..]
            } else {
                chunk
            };
            let x = u8_literal(&[class, B64_BLOCK], chunk)?;
            let outputs = self.run(&loaded, &[x, t.clone()])?;
            let flags: Vec<u8> = outputs[0]
                .to_vec::<u8>()
                .map_err(|e| anyhow::anyhow!("validate output: {e:?}"))?;
            err.extend_from_slice(&flags[..chunk_rows]);
        }
        Ok(err)
    }

    /// Run the roundtrip self-check artifact (encode ∘ decode == identity).
    pub fn selftest(&self) -> anyhow::Result<bool> {
        let manifest = self.runtime.manifest();
        let rows = manifest
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Roundtrip)
            .map(|a| a.rows)
            .ok_or_else(|| anyhow::anyhow!("no roundtrip artifact"))?;
        let loaded = self.runtime.load(ArtifactKind::Roundtrip, rows)?;
        let input: Vec<u8> = (0..rows * RAW_BLOCK).map(|i| (i * 131 % 256) as u8).collect();
        let alphabet = crate::base64::Alphabet::standard();
        let x = u8_literal(&[rows, RAW_BLOCK], &input)?;
        let t = u8_literal(&[64], alphabet.encode_table().as_bytes())?;
        let d = u8_literal(&[128], alphabet.decode_table().as_bytes())?;
        let outputs = self.run(&loaded, &[x, t, d])?;
        let back: Vec<u8> = outputs[0].to_vec::<u8>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let err: Vec<u8> = outputs[1].to_vec::<u8>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(back == input && err.iter().all(|&e| e & 0x80 == 0))
    }
}
