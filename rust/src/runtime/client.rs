//! PJRT client wrapper: compile-once, execute-many.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::artifact::{Artifact, ArtifactKind, Manifest};

/// A loaded-and-compiled executable plus its manifest entry.
pub struct Loaded {
    /// The manifest entry this executable was compiled from.
    pub artifact: Artifact,
    /// The compiled PJRT executable.
    pub exe: xla::PjRtLoadedExecutable,
}

/// The process-wide PJRT runtime: one CPU client and a cache of compiled
/// executables keyed by artifact name. Compilation happens lazily on first
/// use (or eagerly via [`Runtime::warmup`]) and is thread-safe.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Loaded>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Load from [`Manifest::default_dir`].
    pub fn from_env() -> anyhow::Result<Self> {
        Self::new(Manifest::default_dir())
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for (kind, rows).
    pub fn load(&self, kind: ArtifactKind, rows: usize) -> anyhow::Result<Arc<Loaded>> {
        let artifact = self
            .manifest
            .find(kind, rows)
            .ok_or_else(|| anyhow::anyhow!("no artifact for kind={kind} rows={rows}"))?
            .clone();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(l) = cache.get(&artifact.name) {
                return Ok(l.clone());
            }
        }
        // Compile outside the lock: compiles of different artifacts can
        // proceed concurrently; a duplicate compile of the same artifact
        // is benign (last insert wins).
        let path = self.manifest.path_of(&artifact);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", artifact.name))?;
        let loaded = Arc::new(Loaded { artifact: artifact.clone(), exe });
        self.cache.lock().unwrap().insert(artifact.name.clone(), loaded.clone());
        Ok(loaded)
    }

    /// Eagerly compile every artifact of the given kinds (service startup).
    pub fn warmup(&self, kinds: &[ArtifactKind]) -> anyhow::Result<usize> {
        let mut n = 0;
        let entries: Vec<(ArtifactKind, usize)> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| kinds.contains(&a.kind))
            .map(|a| (a.kind, a.rows))
            .collect();
        for (kind, rows) in entries {
            self.load(kind, rows)?;
            n += 1;
        }
        Ok(n)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
