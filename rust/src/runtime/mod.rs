//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire bridge to the compiled computations at serve time:
//!
//! * [`artifact`] — `artifacts/manifest.json` schema and discovery;
//! * [`client`] — `xla` crate wrapper: one [`xla::PjRtClient`], an
//!   executable cache keyed by artifact name;
//! * [`executor`] — typed encode/decode entry points marshalling `&[u8]`
//!   to/from u8 literals (zero format conversion on the hot path).
//!
//! The interchange format is HLO *text*: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactKind, Manifest};
pub use client::Runtime;
pub use executor::{BlockDecodeOutput, BlockExecutor};
