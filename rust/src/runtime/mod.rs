//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire bridge to the compiled computations at serve time:
//!
//! * [`artifact`] — `artifacts/manifest.json` schema and discovery;
//! * `client` — `xla` crate wrapper: one `xla::PjRtClient`, an
//!   executable cache keyed by artifact name;
//! * `executor` — typed encode/decode entry points marshalling `&[u8]`
//!   to/from u8 literals (zero format conversion on the hot path).
//!
//! The interchange format is HLO *text*: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Feature gating
//!
//! The `xla` bindings are only present behind the `pjrt` cargo feature
//! (the default offline build cannot fetch them). Without the feature,
//! [`Runtime`] and [`BlockExecutor`] are API-compatible stubs whose
//! construction fails cleanly, so every caller that probes with
//! `Runtime::new(..).ok()` falls back to the native SIMD tiers.

pub mod artifact;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;

#[cfg(not(feature = "pjrt"))]
mod stub;

pub use artifact::{ArtifactKind, Manifest};

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use executor::{BlockDecodeOutput, BlockExecutor};

#[cfg(not(feature = "pjrt"))]
pub use stub::{BlockDecodeOutput, BlockExecutor, Runtime};
