//! API-compatible stand-in for the PJRT runtime when the `pjrt` feature
//! is disabled (the default in the offline build, where the `xla`
//! bindings cannot be fetched).
//!
//! [`Runtime::new`] still validates the artifact manifest — so missing
//! artifacts report the same "run `make artifacts`" error as the real
//! runtime — but construction always fails with a feature-gate message
//! afterwards, and every executor entry point is unreachable by
//! construction. Callers that probe with `Runtime::new(..).ok()` (the
//! benches, the coordinator's `pjrt_factory`) degrade gracefully to the
//! native tiers.

use std::path::Path;
use std::sync::Arc;

use super::artifact::{ArtifactKind, Manifest};
use crate::base64::{B64_BLOCK, RAW_BLOCK};

/// Stub of the process-wide PJRT runtime.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Validates the manifest, then reports the missing feature.
    pub fn new(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = Self { manifest };
        Err(anyhow::anyhow!(
            "b64simd was built without the `pjrt` feature; the compiled \
             artifacts cannot be executed (use the native backend instead)"
        ))
    }

    /// Load from [`Manifest::default_dir`].
    pub fn from_env() -> anyhow::Result<Self> {
        Self::new(Manifest::default_dir())
    }

    /// The validated manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform name (always `"stub"`).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Eagerly compile artifacts (unreachable: construction always fails).
    pub fn warmup(&self, _kinds: &[ArtifactKind]) -> anyhow::Result<usize> {
        anyhow::bail!("pjrt feature disabled")
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        0
    }
}

/// Result of a batched block decode (mirrors the real executor).
pub struct BlockDecodeOutput {
    /// `rows * 48` decoded bytes.
    pub data: Vec<u8>,
    /// One error byte per row; MSB set = row contained an invalid char.
    pub err: Vec<u8>,
}

/// Stub of the typed block executor. Constructible in type terms only —
/// a [`Runtime`] can never actually be obtained without the feature.
pub struct BlockExecutor {
    runtime: Arc<Runtime>,
}

impl BlockExecutor {
    /// Wrap a runtime handle.
    pub fn new(runtime: Arc<Runtime>) -> Self {
        Self { runtime }
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Smallest compiled row class holding `rows`.
    pub fn row_class_for(&self, rows: usize) -> usize {
        self.runtime.manifest().row_class_for(rows)
    }

    /// Batched block encode (unreachable without the `pjrt` feature).
    pub fn encode_blocks(&self, input: &[u8], _table: &[u8; 64]) -> anyhow::Result<Vec<u8>> {
        assert!(input.len() % RAW_BLOCK == 0, "input must be whole 48-byte blocks");
        anyhow::bail!("pjrt feature disabled")
    }

    /// Batched block decode (unreachable without the `pjrt` feature).
    pub fn decode_blocks(&self, input: &[u8], _dtable: &[u8; 128]) -> anyhow::Result<BlockDecodeOutput> {
        assert!(input.len() % B64_BLOCK == 0, "input must be whole 64-char blocks");
        anyhow::bail!("pjrt feature disabled")
    }

    /// Batched block validation (unreachable without the `pjrt` feature).
    pub fn validate_blocks(&self, input: &[u8], _dtable: &[u8; 128]) -> anyhow::Result<Vec<u8>> {
        assert!(input.len() % B64_BLOCK == 0);
        anyhow::bail!("pjrt feature disabled")
    }

    /// Round-trip self-check (unreachable without the `pjrt` feature).
    pub fn selftest(&self) -> anyhow::Result<bool> {
        anyhow::bail!("pjrt feature disabled")
    }
}
