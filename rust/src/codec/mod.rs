//! Multi-codec engine: tiered hex and base32 kernels plus the
//! name↔id registry behind wire-level codec negotiation.
//!
//! The base64 engine stays where it is (`crate::base64`); this module
//! generalizes the surrounding machinery — tier dispatch, store
//! policies, whitespace stripping, streaming carries — to the other
//! RFC 4648 encodings. The same `vpermb`/multishift toolbox the paper
//! builds for base64 drives the AVX-512 hex and base32 kernels, with
//! SWAR and scalar fallbacks sharing one set of reference semantics.
//!
//! [`CodecSel`] is the routing currency: the coordinator resolves a
//! wire codec name through a per-connection [`CodecRegistry`] into a
//! `CodecSel` and hands it to the router, which picks the matching
//! kernel family without the reply paths caring which codec ran.

pub mod base32;
pub mod hex;
pub mod registry;
pub mod stream;

pub use base32::{Base32Codec, Base32Variant};
pub use hex::HexCodec;
pub use registry::{CodecRegistry, RegisterError, DYNAMIC_BASE};
pub use stream::{CodecStreamDecoder, CodecStreamEncoder};

use crate::base64::Alphabet;

/// A resolved codec selection: which encoding family a request runs,
/// carrying the family-specific configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecSel {
    /// Base64 with the given alphabet (built-in or custom-registered).
    Base64(Alphabet),
    /// Base16 (hex): uppercase encode, case-insensitive decode.
    Hex,
    /// Base32 in the given variant (standard or extended-hex).
    Base32(Base32Variant),
}

impl CodecSel {
    /// Canonical wire name for this selection.
    pub fn name(&self) -> &'static str {
        match self {
            CodecSel::Base64(a) => a.name(),
            CodecSel::Hex => "hex",
            CodecSel::Base32(v) => v.name(),
        }
    }

    /// Exact encoded size of `n` raw bytes under this codec.
    pub fn encoded_len(&self, n: usize) -> usize {
        match self {
            CodecSel::Base64(_) => n.div_ceil(3) * 4,
            CodecSel::Hex => hex::encoded_len(n),
            CodecSel::Base32(_) => base32::encoded_len(n),
        }
    }

    /// Upper bound on the decoded size of `n` encoded bytes.
    pub fn decoded_len_upper(&self, n: usize) -> usize {
        match self {
            CodecSel::Base64(_) => n.div_ceil(4) * 3,
            CodecSel::Hex => hex::decoded_len(n),
            CodecSel::Base32(_) => base32::decoded_len_upper(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sel_len_helpers() {
        let b64 = CodecSel::Base64(Alphabet::standard());
        assert_eq!(b64.encoded_len(3), 4);
        assert_eq!(b64.encoded_len(4), 8);
        assert_eq!(b64.decoded_len_upper(8), 6);
        assert_eq!(CodecSel::Hex.encoded_len(5), 10);
        assert_eq!(CodecSel::Hex.decoded_len_upper(10), 5);
        let b32 = CodecSel::Base32(Base32Variant::Std);
        assert_eq!(b32.encoded_len(5), 8);
        assert_eq!(b32.encoded_len(6), 16);
        assert_eq!(b32.decoded_len_upper(8), 5);
        assert_eq!(b64.name(), "standard");
        assert_eq!(CodecSel::Hex.name(), "hex");
        assert_eq!(b32.name(), "base32");
    }
}
