//! Bidirectional name↔id codec registry — the per-connection map
//! behind the wire protocol's `CodecHello`/`CodecRegister` negotiation.
//!
//! Built-in codecs occupy the low id space; dynamically registered
//! custom base64 alphabets start at [`DYNAMIC_BASE`]. Both directions
//! of the mapping are kept (name→id for request resolution, id→name
//! for the `RespCodecs` listing), mirroring the `CodecMapper` design
//! the negotiation extension is modeled on. The registry is
//! per-connection state: one client's custom alphabet never leaks into
//! another connection's namespace.

use std::collections::HashMap;

use super::{Base32Variant, CodecSel};
use crate::base64::alphabet::AlphabetError;
use crate::base64::Alphabet;

/// First id handed to a dynamically registered codec; ids below this
/// are reserved for built-ins.
pub const DYNAMIC_BASE: u16 = 64;

/// Per-connection cap on dynamic registrations (bounds session memory).
const MAX_DYNAMIC: u16 = 64;

/// Why a `CodecRegister` was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegisterError {
    /// Empty, oversized (> 255 bytes) or non-graphic-ASCII name.
    InvalidName,
    /// The name is already taken (built-in alias or earlier dynamic).
    DuplicateName(String),
    /// The per-connection dynamic-codec budget is exhausted.
    Full,
    /// The 64-char table failed [`Alphabet::new`] validation.
    Alphabet(AlphabetError),
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidName => write!(f, "invalid codec name"),
            Self::DuplicateName(name) => write!(f, "codec name already registered: {name}"),
            Self::Full => write!(f, "codec registry full"),
            Self::Alphabet(e) => write!(f, "invalid alphabet: {e}"),
        }
    }
}

impl std::error::Error for RegisterError {}

/// Bidirectional name↔id codec map with dynamic registration.
pub struct CodecRegistry {
    by_name: HashMap<String, u16>,
    by_id: HashMap<u16, (String, CodecSel)>,
    next_id: u16,
}

impl Default for CodecRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl CodecRegistry {
    /// A registry holding the built-in codecs and their aliases.
    pub fn new() -> Self {
        let mut r = Self { by_name: HashMap::new(), by_id: HashMap::new(), next_id: DYNAMIC_BASE };
        let builtins: [(u16, &str, CodecSel); 6] = [
            (0, "standard", CodecSel::Base64(Alphabet::standard())),
            (1, "url", CodecSel::Base64(Alphabet::url())),
            (2, "imap", CodecSel::Base64(Alphabet::imap())),
            (3, "hex", CodecSel::Hex),
            (4, "base32", CodecSel::Base32(Base32Variant::Std)),
            (5, "base32hex", CodecSel::Base32(Base32Variant::Hex)),
        ];
        for (id, name, sel) in builtins {
            r.by_name.insert(name.to_string(), id);
            r.by_id.insert(id, (name.to_string(), sel));
        }
        // Aliases resolve but don't occupy ids of their own.
        r.by_name.insert("base64".to_string(), 0);
        r.by_name.insert("base64url".to_string(), 1);
        r.by_name.insert("base16".to_string(), 3);
        r
    }

    /// Resolve a codec by wire name (built-in, alias or dynamic).
    pub fn resolve(&self, name: &str) -> Option<CodecSel> {
        let id = *self.by_name.get(name)?;
        Some(self.by_id[&id].1.clone())
    }

    /// Resolve a codec by id.
    pub fn resolve_id(&self, id: u16) -> Option<CodecSel> {
        self.by_id.get(&id).map(|(_, sel)| sel.clone())
    }

    /// The id a name maps to (aliases resolve to the canonical id).
    pub fn id_of(&self, name: &str) -> Option<u16> {
        self.by_name.get(name).copied()
    }

    /// The canonical name for an id.
    pub fn name_of(&self, id: u16) -> Option<&str> {
        self.by_id.get(&id).map(|(name, _)| name.as_str())
    }

    /// Register a custom base64 alphabet under `name`, returning the
    /// assigned id. The table is validated exactly like any other
    /// [`Alphabet`]; the name must be 1–255 bytes of graphic ASCII and
    /// not already taken.
    pub fn register(
        &mut self,
        name: &str,
        chars: &[u8; 64],
        pad: u8,
    ) -> Result<u16, RegisterError> {
        if name.is_empty() || name.len() > 255 || !name.bytes().all(|b| b.is_ascii_graphic()) {
            return Err(RegisterError::InvalidName);
        }
        if self.by_name.contains_key(name) {
            return Err(RegisterError::DuplicateName(name.to_string()));
        }
        if self.next_id >= DYNAMIC_BASE + MAX_DYNAMIC {
            return Err(RegisterError::Full);
        }
        // Dynamic names are runtime strings; `Alphabet` carries a
        // static display name, so all customs share one. The registry
        // keeps the real name for the listing.
        let alphabet = Alphabet::new("custom", *chars, pad).map_err(RegisterError::Alphabet)?;
        let id = self.next_id;
        self.next_id += 1;
        self.by_name.insert(name.to_string(), id);
        self.by_id.insert(id, (name.to_string(), CodecSel::Base64(alphabet)));
        Ok(id)
    }

    /// All registered codecs as `(id, name)`, ordered by id (aliases
    /// are not listed separately).
    pub fn list(&self) -> Vec<(u16, String)> {
        let mut v: Vec<(u16, String)> =
            self.by_id.iter().map(|(&id, (name, _))| (id, name.clone())).collect();
        v.sort_unstable_by_key(|&(id, _)| id);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_and_aliases_resolve() {
        let r = CodecRegistry::new();
        assert!(matches!(r.resolve("standard"), Some(CodecSel::Base64(_))));
        assert_eq!(r.id_of("base64"), Some(0));
        assert_eq!(r.id_of("base64url"), Some(1));
        assert_eq!(r.id_of("base16"), r.id_of("hex"));
        assert!(matches!(r.resolve("hex"), Some(CodecSel::Hex)));
        assert!(matches!(r.resolve("base32"), Some(CodecSel::Base32(Base32Variant::Std))));
        assert!(matches!(r.resolve("base32hex"), Some(CodecSel::Base32(Base32Variant::Hex))));
        assert!(r.resolve("nope").is_none());
        assert_eq!(r.list().len(), 6);
    }

    #[test]
    fn register_and_resolve_custom() {
        let mut r = CodecRegistry::new();
        let mut chars = *Alphabet::standard().chars();
        chars.swap(0, 1); // distinct table, still valid
        let id = r.register("swapped", &chars, b'=').unwrap();
        assert_eq!(id, DYNAMIC_BASE);
        assert_eq!(r.name_of(id), Some("swapped"));
        let Some(CodecSel::Base64(a)) = r.resolve("swapped") else { panic!() };
        assert_eq!(a.chars(), &chars);
        assert_eq!(r.list().len(), 7);
        // Ids keep increasing.
        let mut chars2 = chars;
        chars2.swap(2, 3);
        assert_eq!(r.register("swapped2", &chars2, b'=').unwrap(), DYNAMIC_BASE + 1);
    }

    #[test]
    fn register_rejections() {
        let mut r = CodecRegistry::new();
        let chars = *Alphabet::standard().chars();
        assert_eq!(r.register("", &chars, b'='), Err(RegisterError::InvalidName));
        assert_eq!(r.register("has space", &chars, b'='), Err(RegisterError::InvalidName));
        assert!(matches!(
            r.register("standard", &chars, b'='),
            Err(RegisterError::DuplicateName(_))
        ));
        assert!(matches!(
            r.register("base64", &chars, b'='),
            Err(RegisterError::DuplicateName(_))
        ));
        // Duplicate char in the table.
        let mut bad = chars;
        bad[1] = bad[0];
        assert!(matches!(r.register("dup", &bad, b'='), Err(RegisterError::Alphabet(_))));
        // Pad colliding with a table char.
        assert!(matches!(r.register("padclash", &chars, b'A'), Err(RegisterError::Alphabet(_))));
    }

    #[test]
    fn registry_fills_up() {
        let mut r = CodecRegistry::new();
        let base = *Alphabet::standard().chars();
        for i in 0..MAX_DYNAMIC {
            let mut chars = base;
            chars.swap(0, 1 + (i as usize % 60));
            r.register(&format!("c{i}"), &chars, b'=').unwrap();
        }
        assert_eq!(r.register("one-too-many", &base, b'='), Err(RegisterError::Full));
    }
}
