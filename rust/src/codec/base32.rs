//! Base32 (RFC 4648 §6 standard, §7 extended-hex) with tiered kernels.
//!
//! The 40-bit group geometry (5 raw bytes ↔ 8 chars) slots into the
//! same shape as the base64 engine: a scalar reference, a
//! word-at-a-time SWAR path with deferred validation, and an AVX-512
//! VBMI pipeline built from the `vpermb`/`vpmultishiftqb`/`vpmaddubsw`
//! idioms in `base64::avx512` (40 raw bytes ↔ 64 chars per vector).
//! The AVX2 tier aliases the SWAR path — without `vpermb` the 5-byte
//! group shuffles don't beat the word kernels. Decoding accepts the
//! uppercase RFC alphabets only (matching GNU `base32 -d`); strict mode
//! enforces canonical `=` padding and zero trailing bits exactly like
//! the base64 engine's tail rules.

use crate::base64::engine::detected_tier;
use crate::base64::stores::{copy_for, fence, CopyFn};
use crate::base64::validate::rebase_ws_error;
use crate::base64::{DecodeError, Mode, StorePolicy, Tier, Whitespace};

/// Which RFC 4648 base32 alphabet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Base32Variant {
    /// §6 standard alphabet `A–Z2–7`.
    Std,
    /// §7 "extended hex" alphabet `0–9A–V` (preserves raw sort order).
    Hex,
}

impl Base32Variant {
    /// The 32-char alphabet.
    pub fn chars(self) -> &'static [u8; 32] {
        match self {
            Base32Variant::Std => b"ABCDEFGHIJKLMNOPQRSTUVWXYZ234567",
            Base32Variant::Hex => b"0123456789ABCDEFGHIJKLMNOPQRSTUV",
        }
    }

    /// Wire/CLI name (`base32` / `base32hex`).
    pub fn name(self) -> &'static str {
        match self {
            Base32Variant::Std => "base32",
            Base32Variant::Hex => "base32hex",
        }
    }

    fn tables(self) -> &'static Tables {
        match self {
            Base32Variant::Std => &STD_TABLES,
            Base32Variant::Hex => &HEX_TABLES,
        }
    }
}

/// Exact encoded length (including padding) for `n` raw bytes.
pub const fn encoded_len(n: usize) -> usize {
    n.div_ceil(5) * 8
}

/// Upper bound on decoded bytes for `n` base32 chars.
pub const fn decoded_len_upper(n: usize) -> usize {
    n.div_ceil(8) * 5
}

/// Per-variant lookup tables, const-built from the 32-char alphabet.
struct Tables {
    /// value → char.
    enc: [u8; 32],
    /// char → value, `0xFF` invalid (uppercase only).
    dec: [u8; 256],
    /// Low half of `dec` with the AVX-512 `0x80` invalid sentinel, laid
    /// out for a two-register `vpermi2b` lookup.
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    dec128: [u8; 128],
}

const fn build_tables(chars: &[u8; 32]) -> Tables {
    let mut dec = [0xFFu8; 256];
    let mut dec128 = [0x80u8; 128];
    let mut i = 0;
    while i < 32 {
        dec[chars[i] as usize] = i as u8;
        dec128[chars[i] as usize] = i as u8;
        i += 1;
    }
    Tables { enc: *chars, dec, dec128 }
}

static STD_TABLES: Tables = build_tables(Base32Variant::Std.chars_const());
static HEX_TABLES: Tables = build_tables(Base32Variant::Hex.chars_const());

impl Base32Variant {
    /// `chars()` usable in const context (match in const position).
    const fn chars_const(self) -> &'static [u8; 32] {
        match self {
            Base32Variant::Std => b"ABCDEFGHIJKLMNOPQRSTUVWXYZ234567",
            Base32Variant::Hex => b"0123456789ABCDEFGHIJKLMNOPQRSTUV",
        }
    }
}

/// Bulk encoder over a 5-multiple of raw bytes (no padding involved).
type EncodeFn = fn(&[u8], &mut [u8], &Tables);
/// Bulk decoder over whole pad-free 8-char groups; returns `false` on
/// any invalid byte (deferred — the caller re-scans for the offset).
type DecodeFn = fn(&[u8], &mut [u8], &Tables) -> bool;

/// Tier-dispatched base32 codec with the engine's policy-aware API.
pub struct Base32Codec {
    variant: Base32Variant,
    tier: Tier,
    tables: &'static Tables,
    encode_bulk: EncodeFn,
    decode_bulk: DecodeFn,
    nt_copy: CopyFn,
}

impl Base32Codec {
    /// Codec on the detected tier (`B64SIMD_TIER` honored).
    pub fn new(variant: Base32Variant) -> Self {
        Self::with_tier(variant, detected_tier())
    }

    /// Codec pinned to `tier`, clamped to what the host supports; the
    /// AVX2 tier clamps to SWAR (see the module docs).
    pub fn with_tier(variant: Base32Variant, tier: Tier) -> Self {
        let tier = if tier.available() { tier } else { Tier::Swar };
        let (encode_bulk, decode_bulk): (EncodeFn, DecodeFn) = match tier {
            #[cfg(target_arch = "x86_64")]
            Tier::Avx512 => (encode_avx512, decode_avx512),
            Tier::Scalar => (encode_scalar, decode_scalar),
            _ => (encode_swar, decode_swar),
        };
        Self { variant, tier, tables: variant.tables(), encode_bulk, decode_bulk, nt_copy: copy_for(tier) }
    }

    /// The variant this codec encodes/decodes.
    pub fn variant(&self) -> Base32Variant {
        self.variant
    }

    /// The tier this codec dispatches to.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// Encode `input` into `out[..encoded_len(input.len())]` (padded);
    /// returns the count.
    pub fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        self.encode_slice_policy(input, out, StorePolicy::Temporal)
    }

    /// [`Self::encode_slice`] with an explicit store policy.
    pub fn encode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        policy: StorePolicy,
    ) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let bulk = input.len() / 5 * 5;
        let bulk_out = bulk / 5 * 8;
        if !policy.use_nontemporal(total) {
            (self.encode_bulk)(&input[..bulk], &mut out[..bulk_out], self.tables);
        } else {
            // Stage in L1, stream out with non-temporal stores.
            const STAGE_RAW: usize = 2560; // 512 groups → 4 KiB of chars
            let mut stage = [0u8; STAGE_RAW / 5 * 8];
            let mut done = 0;
            while done < bulk {
                let n = (bulk - done).min(STAGE_RAW);
                let m = n / 5 * 8;
                (self.encode_bulk)(&input[done..done + n], &mut stage[..m], self.tables);
                (self.nt_copy)(&mut out[done / 5 * 8..done / 5 * 8 + m], &stage[..m]);
                done += n;
            }
            fence();
        }
        if bulk < input.len() {
            encode_group(&input[bulk..], &mut out[bulk_out..bulk_out + 8], &self.tables.enc);
        }
        total
    }

    /// Decode `input` into `out`; returns the byte count. Strict mode
    /// requires canonical padding to a multiple of 8 chars and zero
    /// trailing bits in the final data char; forgiving mode accepts
    /// unpadded input.
    pub fn decode_slice(
        &self,
        input: &[u8],
        out: &mut [u8],
        mode: Mode,
    ) -> Result<usize, DecodeError> {
        self.decode_slice_policy(input, out, mode, StorePolicy::Temporal)
    }

    /// [`Self::decode_slice`] with an explicit store policy.
    pub fn decode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        mode: Mode,
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail32(input, mode)?;
        let body_out = body.len() / 8 * 5;
        assert!(out.len() >= decoded_len_upper(input.len()), "output buffer too small");
        let clean = if !policy.use_nontemporal(body_out) {
            (self.decode_bulk)(body, &mut out[..body_out], self.tables)
        } else {
            const STAGE_CHARS: usize = 6400; // 800 groups → 4000 output bytes
            let mut stage = [0u8; STAGE_CHARS / 8 * 5];
            let mut clean = true;
            let mut done = 0;
            while clean && done < body.len() {
                let n = (body.len() - done).min(STAGE_CHARS);
                let m = n / 8 * 5;
                clean = (self.decode_bulk)(&body[done..done + n], &mut stage[..m], self.tables);
                (self.nt_copy)(&mut out[done / 8 * 5..done / 8 * 5 + m], &stage[..m]);
                done += n;
            }
            // The sfence contract holds on the error path too.
            fence();
            clean
        };
        if !clean {
            return Err(first_invalid(body, self.tables));
        }
        let n = decode_tail(tail, mode, body.len(), self.tables, &mut out[body_out..])?;
        Ok(body_out + n)
    }

    /// Decode with a whitespace policy: skipped bytes are stripped once
    /// (SWAR word scan) and error offsets rebased onto the original
    /// payload, matching the base64 engine's `decode_slice_ws` contract.
    pub fn decode_slice_ws(
        &self,
        input: &[u8],
        out: &mut [u8],
        ws: Whitespace,
        mode: Mode,
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        if ws == Whitespace::None {
            return self.decode_slice_policy(input, out, mode, policy);
        }
        let mut stripped = vec![0u8; input.len()];
        let (_, n) = crate::base64::swar::compact_ws(input, &mut stripped, ws);
        stripped.truncate(n);
        self.decode_slice_policy(&stripped, out, mode, policy)
            .map_err(|e| rebase_ws_error(e, input, ws))
    }

    /// Encode to a fresh `Vec`.
    pub fn encode(&self, input: &[u8]) -> Vec<u8> {
        let mut v = vec![0u8; encoded_len(input.len())];
        self.encode_slice(input, &mut v);
        v
    }

    /// Decode to a fresh `Vec`.
    pub fn decode(&self, input: &[u8], mode: Mode) -> Result<Vec<u8>, DecodeError> {
        let mut v = vec![0u8; decoded_len_upper(input.len())];
        let n = self.decode_slice(input, &mut v, mode)?;
        v.truncate(n);
        Ok(v)
    }
}

/// Raw-byte count produced by a final group with `data` significant
/// chars (1, 3 and 6 cannot close out on a byte boundary).
const TAIL_BYTES: [usize; 9] = [0, usize::MAX, 1, usize::MAX, 2, 3, usize::MAX, 4, 5];

/// Bits of the final data char that must be zero in strict mode, by
/// data-char count.
const TAIL_EXCESS: [u32; 9] = [0, 0, 2, 0, 4, 1, 0, 3, 0];

/// Encode a final 1–5 byte group into exactly 8 chars with padding.
fn encode_group(group: &[u8], out: &mut [u8], enc: &[u8; 32]) {
    debug_assert!(!group.is_empty() && group.len() <= 5);
    let mut v = 0u64;
    for (i, &b) in group.iter().enumerate() {
        v |= (b as u64) << (32 - 8 * i);
    }
    let data = match group.len() {
        1 => 2,
        2 => 4,
        3 => 5,
        4 => 7,
        _ => 8,
    };
    for (k, slot) in out.iter_mut().take(8).enumerate() {
        *slot = if k < data { enc[((v >> (35 - 5 * k)) & 31) as usize] } else { b'=' };
    }
}

/// Split a decode payload into pad-free whole groups and a final
/// (possibly padded) group, mirroring `base64::validate::split_tail`.
fn split_tail32(input: &[u8], mode: Mode) -> Result<(&[u8], &[u8]), DecodeError> {
    match mode {
        Mode::Strict => {
            if input.len() % 8 != 0 {
                return Err(DecodeError::InvalidLength { len: input.len() });
            }
            if input.is_empty() {
                return Ok((input, &[]));
            }
            let last = &input[input.len() - 8..];
            if last.contains(&b'=') {
                Ok((&input[..input.len() - 8], last))
            } else {
                Ok((input, &[]))
            }
        }
        Mode::Forgiving => {
            let body_len = match input.iter().position(|&c| c == b'=') {
                Some(p) => p / 8 * 8,
                None => input.len() / 8 * 8,
            };
            Ok((&input[..body_len], &input[body_len..]))
        }
    }
}

/// Decode the final group (0–8 data chars, possibly padded); writes
/// the 0–5 raw bytes at `out[0..]` and returns the count.
/// `base_offset` positions error reports in the stripped input.
fn decode_tail(
    tail: &[u8],
    mode: Mode,
    base_offset: usize,
    t: &Tables,
    out: &mut [u8],
) -> Result<usize, DecodeError> {
    if tail.is_empty() {
        return Ok(0);
    }
    let data_len = tail.iter().position(|&c| c == b'=').unwrap_or(tail.len());
    let data = &tail[..data_len];
    let padding = &tail[data_len..];
    // Everything after the first pad must be pad, and strict mode
    // requires the padding to complete exactly one 8-char group.
    if !padding.iter().all(|&c| c == b'=') {
        return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
    }
    if mode == Mode::Strict {
        if !padding.is_empty() && tail.len() != 8 {
            return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
        }
        if padding.len() > 6 {
            return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
        }
    }
    let mut v = 0u64;
    for (i, &c) in data.iter().enumerate() {
        let x = t.dec[c as usize];
        if x == 0xFF {
            return Err(DecodeError::InvalidByte { offset: base_offset + i, byte: c });
        }
        v = (v << 5) | x as u64;
    }
    if data.is_empty() {
        return Ok(0);
    }
    let written = TAIL_BYTES[data.len()];
    if written == usize::MAX {
        return Err(DecodeError::InvalidLength { len: base_offset + data.len() });
    }
    if mode == Mode::Strict && v & ((1u64 << TAIL_EXCESS[data.len()]) - 1) != 0 {
        return Err(DecodeError::TrailingBits { offset: base_offset + data.len() - 1 });
    }
    // Left-align the 5·data bits into the 40-bit group and take the
    // whole raw bytes off the top.
    let full = v << (40 - 5 * data.len());
    assert!(out.len() >= written, "output buffer too small for the decoded tail");
    out[..written].copy_from_slice(&full.to_be_bytes()[3..3 + written]);
    Ok(written)
}

/// Decode a final (possibly padded) group with carry-relative error
/// offsets — the streaming decoder's tail path (`codec::stream`).
pub(crate) fn decode_tail_group(
    tail: &[u8],
    mode: Mode,
    variant: Base32Variant,
    out: &mut [u8],
) -> Result<usize, DecodeError> {
    decode_tail(tail, mode, 0, variant.tables(), out)
}

/// Cold path: exact position of the first invalid byte in `body`.
fn first_invalid(body: &[u8], t: &Tables) -> DecodeError {
    for (i, &c) in body.iter().enumerate() {
        if t.dec[c as usize] == 0xFF {
            return DecodeError::InvalidByte { offset: i, byte: c };
        }
    }
    unreachable!("decode kernel flagged an error but every byte is valid base32")
}

fn encode_scalar(input: &[u8], out: &mut [u8], t: &Tables) {
    debug_assert_eq!(input.len() % 5, 0);
    for (g, ch) in input.chunks_exact(5).enumerate() {
        let v = ((ch[0] as u64) << 32)
            | ((ch[1] as u64) << 24)
            | ((ch[2] as u64) << 16)
            | ((ch[3] as u64) << 8)
            | ch[4] as u64;
        let o = &mut out[g * 8..g * 8 + 8];
        for (k, slot) in o.iter_mut().enumerate() {
            *slot = t.enc[((v >> (35 - 5 * k)) & 31) as usize];
        }
    }
}

/// Word-at-a-time encode: one 8-byte big-endian load covers a whole
/// 5-byte group (the final group falls back to the scalar assembly to
/// stay inside the slice).
fn encode_swar(input: &[u8], out: &mut [u8], t: &Tables) {
    debug_assert_eq!(input.len() % 5, 0);
    let groups = input.len() / 5;
    let mut g = 0;
    while g < groups && g * 5 + 8 <= input.len() {
        let v = u64::from_be_bytes(input[g * 5..g * 5 + 8].try_into().unwrap()) >> 24;
        let o = &mut out[g * 8..g * 8 + 8];
        o[0] = t.enc[((v >> 35) & 31) as usize];
        o[1] = t.enc[((v >> 30) & 31) as usize];
        o[2] = t.enc[((v >> 25) & 31) as usize];
        o[3] = t.enc[((v >> 20) & 31) as usize];
        o[4] = t.enc[((v >> 15) & 31) as usize];
        o[5] = t.enc[((v >> 10) & 31) as usize];
        o[6] = t.enc[((v >> 5) & 31) as usize];
        o[7] = t.enc[(v & 31) as usize];
        g += 1;
    }
    encode_scalar(&input[g * 5..], &mut out[g * 8..], t);
}

fn decode_scalar(input: &[u8], out: &mut [u8], t: &Tables) -> bool {
    debug_assert_eq!(input.len() % 8, 0);
    for (g, ch) in input.chunks_exact(8).enumerate() {
        let mut v = 0u64;
        for &c in ch {
            let x = t.dec[c as usize];
            if x == 0xFF {
                return false;
            }
            v = (v << 5) | x as u64;
        }
        out[g * 5..g * 5 + 5].copy_from_slice(&v.to_be_bytes()[3..8]);
    }
    true
}

/// Word-at-a-time decode with the deferred validity accumulator.
fn decode_swar(input: &[u8], out: &mut [u8], t: &Tables) -> bool {
    debug_assert_eq!(input.len() % 8, 0);
    let mut bad = 0u8;
    for (g, ch) in input.chunks_exact(8).enumerate() {
        let mut v = 0u64;
        for &c in ch {
            let x = t.dec[c as usize];
            bad |= x;
            v = (v << 5) | (x & 0x1F) as u64;
        }
        out[g * 5..g * 5 + 5].copy_from_slice(&v.to_be_bytes()[3..8]);
    }
    bad & 0x80 == 0
}

#[cfg(target_arch = "x86_64")]
fn encode_avx512(input: &[u8], out: &mut [u8], t: &Tables) {
    debug_assert_eq!(input.len() % 5, 0);
    let chunks = input.len() / 40 * 40;
    // Safety: selected only when Tier::Avx512 is available
    // (avx512f + avx512bw + avx512vbmi).
    unsafe { avx512::encode(&input[..chunks], out, &t.enc) };
    encode_swar(&input[chunks..], &mut out[chunks / 5 * 8..], t);
}

#[cfg(target_arch = "x86_64")]
fn decode_avx512(input: &[u8], out: &mut [u8], t: &Tables) -> bool {
    debug_assert_eq!(input.len() % 8, 0);
    let chunks = input.len() / 64 * 64;
    // Safety: selected only when Tier::Avx512 is available.
    let clean = unsafe { avx512::decode(&input[..chunks], out, &t.dec128) };
    clean && decode_swar(&input[chunks..], &mut out[chunks / 8 * 5..], t)
}

/// AVX-512 VBMI kernels: 40 raw bytes ↔ 64 chars per vector, using the
/// same shuffle/multishift/madd toolbox as `base64::avx512`.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use core::arch::x86_64::*;

    /// `vpermb` index building one big-endian 40-bit group per output
    /// qword: qword `j` gets bytes `in[5j+4] … in[5j]` (LSB→MSB); the
    /// three spare byte slots point at the masked-load zero tail.
    const GROUP_BE: [u8; 64] = {
        let mut t = [63u8; 64];
        let mut j = 0;
        while j < 8 {
            let mut k = 0;
            while k < 5 {
                t[8 * j + k] = (5 * j + (4 - k)) as u8;
                k += 1;
            }
            j += 1;
        }
        t
    };

    /// Per-qword `vpmultishiftqb` controls extracting the eight 5-bit
    /// fields of the 40-bit group, MSB field first.
    const ENC_SHIFTS: [u8; 8] = [35, 30, 25, 20, 15, 10, 5, 0];

    /// Per-qword controls slicing the reassembled 40-bit value into its
    /// five big-endian raw bytes (spare slots are dropped by the gather).
    const DEC_SHIFTS: [u8; 8] = [32, 24, 16, 8, 0, 0, 0, 0];

    /// `vpermb` index compacting the five live bytes of each qword into
    /// 40 contiguous output bytes.
    const PACK: [u8; 64] = {
        let mut t = [0u8; 64];
        let mut m = 0;
        while m < 40 {
            t[m] = (8 * (m / 5) + m % 5) as u8;
            m += 1;
        }
        t
    };

    /// Encode 40 raw bytes → 64 chars per iteration; `input` must be a
    /// multiple of 40 bytes.
    ///
    /// # Safety
    /// Requires avx512f, avx512bw and avx512vbmi.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub(super) unsafe fn encode(input: &[u8], out: &mut [u8], enc: &[u8; 32]) {
        debug_assert_eq!(input.len() % 40, 0);
        let group = _mm512_loadu_si512(GROUP_BE.as_ptr() as *const i32);
        let shifts = _mm512_set1_epi64(i64::from_le_bytes(ENC_SHIFTS));
        let lut = _mm512_maskz_loadu_epi8(0xFFFF_FFFF, enc.as_ptr() as *const i8);
        let low = _mm512_set1_epi8(0x1F);
        for (i, ch) in input.chunks_exact(40).enumerate() {
            let src = _mm512_maskz_loadu_epi8((1u64 << 40) - 1, ch.as_ptr() as *const i8);
            let grouped = _mm512_permutexvar_epi8(group, src);
            let fields = _mm512_and_si512(_mm512_multishift_epi64_epi8(shifts, grouped), low);
            let chars = _mm512_permutexvar_epi8(fields, lut);
            _mm512_storeu_si512(out.as_mut_ptr().add(64 * i) as *mut i32, chars);
        }
    }

    /// Decode 64 chars → 40 raw bytes per iteration with deferred
    /// validation; `input` must be a multiple of 64 chars. Returns
    /// `false` if any byte was invalid (caller re-scans for the offset).
    ///
    /// # Safety
    /// Requires avx512f, avx512bw and avx512vbmi.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub(super) unsafe fn decode(input: &[u8], out: &mut [u8], dec128: &[u8; 128]) -> bool {
        debug_assert_eq!(input.len() % 64, 0);
        let lut_lo = _mm512_loadu_si512(dec128.as_ptr() as *const i32);
        let lut_hi = _mm512_loadu_si512(dec128.as_ptr().add(64) as *const i32);
        let pack = _mm512_loadu_si512(PACK.as_ptr() as *const i32);
        // Per 16-bit lane: first char value * 32 + second.
        let madd1 = _mm512_set1_epi16(0x0120);
        // Per 32-bit lane: first 10-bit pair * 1024 + second.
        let madd2 = _mm512_set1_epi32(0x0001_0400);
        let shifts = _mm512_set1_epi64(i64::from_le_bytes(DEC_SHIFTS));
        let mask32 = _mm512_set1_epi64(0xFFFF_FFFF);
        let mut error = _mm512_setzero_si512();
        for (i, ch) in input.chunks_exact(64).enumerate() {
            let chars = _mm512_loadu_si512(ch.as_ptr() as *const i32);
            let vals = _mm512_permutex2var_epi8(lut_lo, chars, lut_hi);
            // error |= chars | vals — flags bit 7 for non-ASCII input
            // and for the 0x80 invalid sentinel.
            error = _mm512_ternarylogic_epi32(error, chars, vals, 0xFE);
            let words = _mm512_maddubs_epi16(vals, madd1);
            let dwords = _mm512_madd_epi16(words, madd2);
            // Each qword holds two 20-bit halves (chars 0–3 in the low
            // dword); fuse them into the 40-bit group value.
            let v40 = _mm512_or_si512(
                _mm512_slli_epi64::<20>(_mm512_and_si512(dwords, mask32)),
                _mm512_srli_epi64::<32>(dwords),
            );
            let bytes = _mm512_multishift_epi64_epi8(shifts, v40);
            let packed = _mm512_permutexvar_epi8(pack, bytes);
            _mm512_mask_storeu_epi8(
                out.as_mut_ptr().add(40 * i) as *mut i8,
                (1u64 << 40) - 1,
                packed,
            );
        }
        _mm512_movepi8_mask(error) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(len: usize) -> Vec<u8> {
        (0..len).map(|i| (i * 131 % 256) as u8).collect()
    }

    /// Group-by-group reference built only from the tail encoder.
    fn reference_encode(input: &[u8], variant: Base32Variant) -> Vec<u8> {
        let enc = variant.chars();
        let mut out = vec![0u8; encoded_len(input.len())];
        for (g, group) in input.chunks(5).enumerate() {
            encode_group(group, &mut out[g * 8..g * 8 + 8], enc);
        }
        out
    }

    #[test]
    fn rfc4648_vectors_std() {
        let c = Base32Codec::new(Base32Variant::Std);
        for (raw, b32) in [
            (&b""[..], &b""[..]),
            (b"f", b"MY======"),
            (b"fo", b"MZXQ===="),
            (b"foo", b"MZXW6==="),
            (b"foob", b"MZXW6YQ="),
            (b"fooba", b"MZXW6YTB"),
            (b"foobar", b"MZXW6YTBOI======"),
        ] {
            assert_eq!(c.encode(raw), b32);
            assert_eq!(c.decode(b32, Mode::Strict).unwrap(), raw);
        }
    }

    #[test]
    fn rfc4648_vectors_hex() {
        let c = Base32Codec::new(Base32Variant::Hex);
        for (raw, b32) in [
            (&b""[..], &b""[..]),
            (b"f", b"CO======"),
            (b"fo", b"CPNG===="),
            (b"foo", b"CPNMU==="),
            (b"foob", b"CPNMUOG="),
            (b"fooba", b"CPNMUOJ1"),
            (b"foobar", b"CPNMUOJ1E8======"),
        ] {
            assert_eq!(c.encode(raw), b32);
            assert_eq!(c.decode(b32, Mode::Strict).unwrap(), raw);
        }
    }

    #[test]
    fn all_tiers_match_scalar() {
        for variant in [Base32Variant::Std, Base32Variant::Hex] {
            for tier in Tier::supported() {
                let c = Base32Codec::with_tier(variant, tier);
                for len in [0usize, 1, 4, 5, 6, 39, 40, 41, 100, 1000, 5003] {
                    let raw = data(len);
                    let enc = c.encode(&raw);
                    assert_eq!(
                        enc,
                        reference_encode(&raw, variant),
                        "variant={variant:?} tier={tier:?} len={len}"
                    );
                    assert_eq!(
                        c.decode(&enc, Mode::Strict).unwrap(),
                        raw,
                        "variant={variant:?} tier={tier:?} len={len}"
                    );
                }
            }
        }
    }

    #[test]
    fn policies_match_temporal() {
        for tier in Tier::supported() {
            let c = Base32Codec::with_tier(Base32Variant::Std, tier);
            for policy in [StorePolicy::Temporal, StorePolicy::NonTemporal, StorePolicy::auto()] {
                for len in [0usize, 100, 2559, 2560, 2561, 6399, 6400, 50_000] {
                    let raw = data(len);
                    let mut enc = vec![0u8; encoded_len(len)];
                    let n = c.encode_slice_policy(&raw, &mut enc, policy);
                    assert_eq!(n, encoded_len(len));
                    assert_eq!(enc, reference_encode(&raw, Base32Variant::Std), "tier={tier:?} len={len}");
                    let mut dec = vec![0u8; decoded_len_upper(enc.len())];
                    let n = c.decode_slice_policy(&enc, &mut dec, Mode::Strict, policy).unwrap();
                    assert_eq!(&dec[..n], raw, "tier={tier:?} len={len}");
                }
            }
        }
    }

    #[test]
    fn error_offsets_match_across_tiers() {
        let raw = data(400); // 640 chars, no padding
        let reference = reference_encode(&raw, Base32Variant::Std);
        for pos in [0usize, 1, 63, 64, 65, 300, 639] {
            let mut bad = reference.clone();
            bad[pos] = b'!';
            for tier in Tier::supported() {
                let c = Base32Codec::with_tier(Base32Variant::Std, tier);
                match c.decode(&bad, Mode::Strict) {
                    Err(DecodeError::InvalidByte { offset, byte }) => {
                        assert_eq!((offset, byte), (pos, b'!'), "tier={tier:?} pos={pos}")
                    }
                    other => panic!("tier={tier:?} pos={pos}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn strict_rejects_trailing_bits() {
        let c = Base32Codec::new(Base32Variant::Std);
        // "MY======" is canonical for "f"; 'Z' = 0b11001 leaks 2 bits.
        match c.decode(b"MZ======", Mode::Strict) {
            Err(DecodeError::TrailingBits { offset: 1 }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(c.decode(b"MZ======", Mode::Forgiving).unwrap(), b"f");
    }

    #[test]
    fn strict_rejects_bad_lengths_and_padding() {
        let c = Base32Codec::new(Base32Variant::Std);
        assert!(matches!(
            c.decode(b"MZXW6", Mode::Strict),
            Err(DecodeError::InvalidLength { len: 5 })
        ));
        // 7 pads can never be canonical.
        assert!(matches!(
            c.decode(b"M=======", Mode::Strict),
            Err(DecodeError::InvalidPadding { .. })
        ));
        // Data resumed after padding.
        assert!(matches!(
            c.decode(b"MY====Y=", Mode::Strict),
            Err(DecodeError::InvalidPadding { offset: 2 })
        ));
        // Lowercase is not accepted (GNU base32 -d parity).
        assert!(matches!(
            c.decode(b"mzxw6ytb", Mode::Strict),
            Err(DecodeError::InvalidByte { offset: 0, byte: b'm' })
        ));
    }

    #[test]
    fn forgiving_accepts_unpadded() {
        let c = Base32Codec::new(Base32Variant::Std);
        assert_eq!(c.decode(b"MZXW6", Mode::Forgiving).unwrap(), b"foo");
        assert_eq!(c.decode(b"MZXW6YTBOI", Mode::Forgiving).unwrap(), b"foobar");
        // 1/3/6 dangling data chars never close a byte boundary.
        assert!(matches!(
            c.decode(b"MZXW6YTBO", Mode::Forgiving),
            Err(DecodeError::InvalidLength { .. })
        ));
    }

    #[test]
    fn ws_decode_rebases_offsets() {
        let c = Base32Codec::new(Base32Variant::Std);
        let mut out = vec![0u8; 16];
        let n = c
            .decode_slice_ws(
                b"MZXW\r\n6YTB",
                &mut out,
                Whitespace::CrLf,
                Mode::Strict,
                StorePolicy::Temporal,
            )
            .unwrap();
        assert_eq!(&out[..n], b"fooba");
        match c.decode_slice_ws(
            b"MZXW\r\n6YT!",
            &mut out,
            Whitespace::CrLf,
            Mode::Strict,
            StorePolicy::Temporal,
        ) {
            Err(DecodeError::InvalidByte { offset: 9, byte: b'!' }) => {}
            other => panic!("{other:?}"),
        }
    }
}
