//! Streaming hex/base32: chunked encode/decode with sub-group carry.
//!
//! Mirrors the conventions of `base64::streaming` so the session layer
//! treats every codec's streams identically: bulk chunks run on the
//! tiered kernels, sub-group remainders (at most 4 raw bytes encoding,
//! 7 chars decoding) carry between chunks, padding may only appear at
//! stream end, and decode error offsets index the original
//! (whitespace-bearing) stream.

use super::base32::{self, Base32Codec, Base32Variant};
use super::hex::{self, HexCodec};
use crate::base64::{DecodeError, Mode, Whitespace};

/// Which non-base64 codec a stream runs (base64 streams keep using
/// `base64::streaming` directly).
enum Kind {
    Hex(HexCodec),
    Base32(Base32Codec),
}

impl Kind {
    /// Chars per decode group.
    fn group(&self) -> usize {
        match self {
            Kind::Hex(_) => 2,
            Kind::Base32(_) => 8,
        }
    }
}

/// Chunked encoder for hex and base32 payloads.
pub struct CodecStreamEncoder {
    kind: Kind,
    /// Raw bytes not yet filling a base32 group (hex carries nothing).
    carry: [u8; 5],
    carry_len: usize,
    consumed: u64,
}

impl CodecStreamEncoder {
    /// A hex encode stream on the detected tier.
    pub fn hex() -> Self {
        Self { kind: Kind::Hex(HexCodec::new()), carry: [0; 5], carry_len: 0, consumed: 0 }
    }

    /// A base32 encode stream on the detected tier.
    pub fn base32(variant: Base32Variant) -> Self {
        Self {
            kind: Kind::Base32(Base32Codec::new(variant)),
            carry: [0; 5],
            carry_len: 0,
            consumed: 0,
        }
    }

    /// Encode `chunk`, appending complete output to `out`; raw bytes
    /// that do not close a 5-byte base32 group carry to the next call.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) {
        self.consumed += chunk.len() as u64;
        match &self.kind {
            Kind::Hex(c) => {
                let start = out.len();
                out.resize(start + hex::encoded_len(chunk.len()), 0);
                c.encode_slice(chunk, &mut out[start..]);
            }
            Kind::Base32(c) => {
                let mut chunk = chunk;
                if self.carry_len > 0 {
                    let take = (5 - self.carry_len).min(chunk.len());
                    self.carry[self.carry_len..self.carry_len + take]
                        .copy_from_slice(&chunk[..take]);
                    self.carry_len += take;
                    chunk = &chunk[take..];
                    if self.carry_len < 5 {
                        return;
                    }
                    let group = self.carry;
                    self.carry_len = 0;
                    let start = out.len();
                    out.resize(start + 8, 0);
                    c.encode_slice(&group, &mut out[start..]);
                }
                // Whole groups produce no padding; the remainder carries.
                let whole = chunk.len() / 5 * 5;
                let start = out.len();
                out.resize(start + base32::encoded_len(whole), 0);
                c.encode_slice(&chunk[..whole], &mut out[start..]);
                self.carry[..chunk.len() - whole].copy_from_slice(&chunk[whole..]);
                self.carry_len = chunk.len() - whole;
            }
        }
    }

    /// Flush the final (padded) group; returns raw bytes consumed.
    pub fn finish(mut self, out: &mut Vec<u8>) -> u64 {
        if self.carry_len > 0 {
            if let Kind::Base32(c) = &self.kind {
                let start = out.len();
                out.resize(start + 8, 0);
                c.encode_slice(&self.carry[..self.carry_len], &mut out[start..]);
            }
            self.carry_len = 0;
        }
        self.consumed
    }
}

/// Chunked decoder for hex and base32 payloads.
pub struct CodecStreamDecoder {
    kind: Kind,
    mode: Mode,
    ws: Whitespace,
    /// Significant chars not yet closing a group, with their absolute
    /// offsets in the raw stream (for exact error reporting).
    carry: [u8; 8],
    carry_off: [u64; 8],
    carry_len: usize,
    /// Raw bytes consumed so far (including skipped whitespace).
    raw_offset: u64,
    /// Significant chars seen so far (length-error reporting).
    stripped: u64,
    saw_pad: bool,
}

impl CodecStreamDecoder {
    /// A hex decode stream (no padding; strict/forgiving don't differ).
    pub fn hex(ws: Whitespace) -> Self {
        Self::build(Kind::Hex(HexCodec::new()), Mode::Strict, ws)
    }

    /// A base32 decode stream.
    pub fn base32(variant: Base32Variant, mode: Mode, ws: Whitespace) -> Self {
        Self::build(Kind::Base32(Base32Codec::new(variant)), mode, ws)
    }

    fn build(kind: Kind, mode: Mode, ws: Whitespace) -> Self {
        Self {
            kind,
            mode,
            ws,
            carry: [0; 8],
            carry_off: [0; 8],
            carry_len: 0,
            raw_offset: 0,
            stripped: 0,
            saw_pad: false,
        }
    }

    /// Decode `chunk`, appending raw bytes to `out`. Groups spanning
    /// chunk boundaries are carried; whitespace is skipped per the
    /// policy; padding may only appear at stream end.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) -> Result<(), DecodeError> {
        let group = self.kind.group();
        let base = self.raw_offset;
        let mut rel = 0usize;
        while rel < chunk.len() {
            let c = chunk[rel];
            if self.ws.skips(c) {
                rel += 1;
                continue;
            }
            let abs = base + rel as u64;
            let is_pad = group == 8 && c == b'=';
            if !is_pad && self.saw_pad {
                // Data resumed after padding.
                return Err(DecodeError::InvalidPadding { offset: abs as usize });
            }
            if is_pad {
                self.saw_pad = true;
                if self.carry_len == 8 {
                    // The one-shot forgiving path accepts surplus pad
                    // runs (they decode to nothing); the carry caps at
                    // one group, so drop them. Strict mode rejects.
                    if self.mode == Mode::Strict {
                        return Err(DecodeError::InvalidPadding { offset: abs as usize });
                    }
                    self.stripped += 1;
                    rel += 1;
                    continue;
                }
            } else if self.carry_len == 0 {
                // Bulk fast path: whole pad-free groups straight through
                // the tiered kernels.
                let run_len = chunk[rel..]
                    .iter()
                    .position(|&c| self.ws.skips(c) || (group == 8 && c == b'='))
                    .unwrap_or(chunk.len() - rel);
                let whole = run_len / group * group;
                if whole > 0 {
                    let run = &chunk[rel..rel + whole];
                    let start = out.len();
                    let result = match &self.kind {
                        Kind::Hex(h) => {
                            out.resize(start + hex::decoded_len(whole), 0);
                            h.decode_slice(run, &mut out[start..]).map(|_| ())
                        }
                        Kind::Base32(b) => {
                            out.resize(start + whole / 8 * 5, 0);
                            b.decode_slice(run, &mut out[start..], Mode::Strict).map(|_| ())
                        }
                    };
                    result.map_err(|e| e.map_offset(|o| (abs + o as u64) as usize))?;
                    self.stripped += whole as u64;
                    rel += whole;
                    continue;
                }
            }
            self.carry[self.carry_len] = c;
            self.carry_off[self.carry_len] = abs;
            self.carry_len += 1;
            self.stripped += 1;
            rel += 1;
            if self.carry_len == group && !self.saw_pad {
                let grp = self.carry;
                let offs = self.carry_off;
                self.carry_len = 0;
                self.flush_group(&grp[..group], &offs, out)?;
            }
        }
        self.raw_offset += chunk.len() as u64;
        Ok(())
    }

    fn flush_group(
        &mut self,
        grp: &[u8],
        offs: &[u64; 8],
        out: &mut Vec<u8>,
    ) -> Result<(), DecodeError> {
        let start = out.len();
        let result = match &self.kind {
            Kind::Hex(h) => {
                out.resize(start + 1, 0);
                h.decode_slice(grp, &mut out[start..]).map(|_| ())
            }
            Kind::Base32(b) => {
                out.resize(start + 5, 0);
                b.decode_slice(grp, &mut out[start..], Mode::Strict).map(|_| ())
            }
        };
        result.map_err(|e| e.map_offset(|o| offs[o] as usize))
    }

    /// Close the stream: resolve the final (possibly padded) group.
    /// Returns raw bytes consumed.
    pub fn finish(mut self, out: &mut Vec<u8>) -> Result<u64, DecodeError> {
        if self.carry_len == 0 {
            return Ok(self.raw_offset);
        }
        let n = self.carry_len;
        self.carry_len = 0;
        match &self.kind {
            Kind::Hex(_) => {
                // A dangling nibble can never complete.
                Err(DecodeError::InvalidLength { len: self.stripped as usize })
            }
            Kind::Base32(b) => {
                if self.mode == Mode::Strict && !self.saw_pad {
                    return Err(DecodeError::InvalidLength { len: self.stripped as usize });
                }
                let start = out.len();
                out.resize(start + 5, 0);
                match base32::decode_tail_group(
                    &self.carry[..n],
                    self.mode,
                    b.variant(),
                    &mut out[start..],
                ) {
                    Ok(w) => {
                        out.truncate(start + w);
                        Ok(self.raw_offset)
                    }
                    Err(DecodeError::InvalidLength { .. }) => {
                        Err(DecodeError::InvalidLength { len: self.stripped as usize })
                    }
                    Err(e) => Err(e.map_offset(|o| self.carry_off[o] as usize)),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::StorePolicy;

    fn data(len: usize) -> Vec<u8> {
        (0..len).map(|i| (i * 37 % 256) as u8).collect()
    }

    #[test]
    fn hex_stream_matches_one_shot() {
        let one_shot = HexCodec::new();
        for chunk_len in [1usize, 2, 3, 7, 64, 1000] {
            let raw = data(500);
            let mut enc = CodecStreamEncoder::hex();
            let mut got = Vec::new();
            for ch in raw.chunks(chunk_len) {
                enc.update(ch, &mut got);
            }
            enc.finish(&mut got);
            assert_eq!(got, one_shot.encode(&raw), "chunk_len={chunk_len}");

            let mut dec = CodecStreamDecoder::hex(Whitespace::None);
            let mut back = Vec::new();
            for ch in got.chunks(chunk_len) {
                dec.update(ch, &mut back).unwrap();
            }
            dec.finish(&mut back).unwrap();
            assert_eq!(back, raw, "chunk_len={chunk_len}");
        }
    }

    #[test]
    fn base32_stream_matches_one_shot() {
        let one_shot = Base32Codec::new(Base32Variant::Std);
        for chunk_len in [1usize, 2, 4, 5, 7, 8, 9, 63, 1000] {
            let raw = data(501); // padded tail
            let mut enc = CodecStreamEncoder::base32(Base32Variant::Std);
            let mut got = Vec::new();
            for ch in raw.chunks(chunk_len) {
                enc.update(ch, &mut got);
            }
            enc.finish(&mut got);
            assert_eq!(got, one_shot.encode(&raw), "chunk_len={chunk_len}");

            let mut dec = CodecStreamDecoder::base32(
                Base32Variant::Std,
                Mode::Strict,
                Whitespace::None,
            );
            let mut back = Vec::new();
            for ch in got.chunks(chunk_len) {
                dec.update(ch, &mut back).unwrap();
            }
            dec.finish(&mut back).unwrap();
            assert_eq!(back, raw, "chunk_len={chunk_len}");
        }
    }

    #[test]
    fn decode_error_offsets_are_absolute() {
        // Whitespace counts toward the reported offset.
        let mut dec = CodecStreamDecoder::base32(
            Base32Variant::Std,
            Mode::Strict,
            Whitespace::CrLf,
        );
        let mut out = Vec::new();
        dec.update(b"MZXW\r\n6Y", &mut out).unwrap();
        match dec.update(b"T!", &mut out) {
            Err(DecodeError::InvalidByte { offset: 9, byte: b'!' }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn strict_unpadded_tail_is_rejected_at_finish() {
        let mut dec =
            CodecStreamDecoder::base32(Base32Variant::Std, Mode::Strict, Whitespace::None);
        let mut out = Vec::new();
        dec.update(b"MZXW6", &mut out).unwrap();
        assert!(matches!(
            dec.finish(&mut out),
            Err(DecodeError::InvalidLength { len: 5 })
        ));
        // Forgiving accepts the same tail.
        let mut dec =
            CodecStreamDecoder::base32(Base32Variant::Std, Mode::Forgiving, Whitespace::None);
        let mut out = Vec::new();
        dec.update(b"MZXW6", &mut out).unwrap();
        dec.finish(&mut out).unwrap();
        assert_eq!(out, b"foo");
    }

    #[test]
    fn data_after_padding_is_rejected() {
        let mut dec =
            CodecStreamDecoder::base32(Base32Variant::Std, Mode::Strict, Whitespace::None);
        let mut out = Vec::new();
        dec.update(b"MY======", &mut out).unwrap();
        assert!(matches!(
            dec.update(b"MY", &mut out),
            Err(DecodeError::InvalidPadding { offset: 8 })
        ));
    }

    #[test]
    fn padded_group_split_across_chunks() {
        for split in 1..8 {
            let enc = b"MZXW6YQ="; // "foob"
            let mut dec =
                CodecStreamDecoder::base32(Base32Variant::Std, Mode::Strict, Whitespace::None);
            let mut out = Vec::new();
            dec.update(&enc[..split], &mut out).unwrap();
            dec.update(&enc[split..], &mut out).unwrap();
            dec.finish(&mut out).unwrap();
            assert_eq!(out, b"foob", "split={split}");
        }
    }

    #[test]
    fn hex_dangling_nibble_rejected() {
        let mut dec = CodecStreamDecoder::hex(Whitespace::None);
        let mut out = Vec::new();
        dec.update(b"666", &mut out).unwrap();
        assert!(matches!(
            dec.finish(&mut out),
            Err(DecodeError::InvalidLength { len: 3 })
        ));
    }

    #[test]
    fn nt_policy_unused_but_codec_tiers_agree_with_stream() {
        // The stream uses the detected tier; cross-check a policy decode
        // of the streamed output for good measure.
        let raw = data(4096);
        let mut enc = CodecStreamEncoder::base32(Base32Variant::Std);
        let mut got = Vec::new();
        enc.update(&raw, &mut got);
        enc.finish(&mut got);
        let c = Base32Codec::new(Base32Variant::Std);
        let mut out = vec![0u8; base32::decoded_len_upper(got.len())];
        let n = c
            .decode_slice_policy(&got, &mut out, Mode::Strict, StorePolicy::NonTemporal)
            .unwrap();
        assert_eq!(&out[..n], raw);
    }
}
