//! Hexadecimal (base16, RFC 4648 §8) with the base64 engine's toolbox.
//!
//! Encoding emits the uppercase digits RFC 4648 §10 prints; decoding
//! accepts both cases. The kernels reuse the idioms of the base64
//! engine one layer down: a branchless SWAR nibble→ASCII word trick, an
//! AVX2 `vpshufb` nibble LUT, and an AVX-512 VBMI
//! `vpermb`+`vpmultishiftqb` pipeline mirroring `base64::avx512`, with
//! deferred error detection and a cold re-scan for the exact offending
//! offset. The policy-aware `_slice_policy` entry points stage through
//! an L1-resident buffer and stream out with the same non-temporal copy
//! kernels ([`crate::base64::stores`]) the base64 engine uses, so large
//! replies can bypass the cache on the way to a socket buffer.

use crate::base64::engine::detected_tier;
use crate::base64::stores::{copy_for, fence, CopyFn};
use crate::base64::validate::rebase_ws_error;
use crate::base64::{DecodeError, StorePolicy, Tier, Whitespace};

/// RFC 4648 §8 digit set (§10 prints base16 vectors uppercase).
const ENCODE: &[u8; 16] = b"0123456789ABCDEF";

/// Case-insensitive nibble values; `0xFF` marks an invalid byte.
const DECODE: [u8; 256] = decode_table();

const fn decode_table() -> [u8; 256] {
    let mut t = [0xFFu8; 256];
    let mut i = 0;
    while i < 10 {
        t[b'0' as usize + i] = i as u8;
        i += 1;
    }
    let mut i = 0;
    while i < 6 {
        t[b'A' as usize + i] = 10 + i as u8;
        t[b'a' as usize + i] = 10 + i as u8;
        i += 1;
    }
    t
}

/// Low half of [`DECODE`] with the AVX-512 sentinel convention: invalid
/// entries carry `0x80`, so a single `vpternlogd` OR-accumulation over
/// (chars | values) flags both non-ASCII input and non-hex ASCII.
#[cfg(target_arch = "x86_64")]
const DECODE128: [u8; 128] = decode_table_128();

#[cfg(target_arch = "x86_64")]
const fn decode_table_128() -> [u8; 128] {
    let mut t = [0x80u8; 128];
    let mut i = 0;
    while i < 128 {
        if DECODE[i] != 0xFF {
            t[i] = DECODE[i];
        }
        i += 1;
    }
    t
}

/// Exact encoded length for `n` raw bytes.
pub const fn encoded_len(n: usize) -> usize {
    n * 2
}

/// Exact decoded length for `n` hex digits (`n` must be even to decode).
pub const fn decoded_len(n: usize) -> usize {
    n / 2
}

/// Bulk encoder: writes `input.len() * 2` chars.
type EncodeFn = fn(&[u8], &mut [u8]);
/// Bulk decoder over an even-length char slice: writes `len / 2` bytes,
/// returns `false` if any byte was invalid (deferred — caller re-scans
/// for the exact offset on the cold path).
type DecodeFn = fn(&[u8], &mut [u8]) -> bool;

/// Tier-dispatched hex codec with the engine's policy-aware slice API.
pub struct HexCodec {
    tier: Tier,
    encode_bulk: EncodeFn,
    decode_bulk: DecodeFn,
    nt_copy: CopyFn,
}

impl Default for HexCodec {
    fn default() -> Self {
        Self::new()
    }
}

impl HexCodec {
    /// Codec on the detected tier (`B64SIMD_TIER` honored).
    pub fn new() -> Self {
        Self::with_tier(detected_tier())
    }

    /// Codec pinned to `tier`, clamped to what the host supports. The
    /// AVX2 tier uses the `vpshufb` LUT for encode and the SWAR path
    /// for decode (the table lookups dominate either way).
    pub fn with_tier(tier: Tier) -> Self {
        let tier = if tier.available() { tier } else { Tier::Swar };
        let (encode_bulk, decode_bulk): (EncodeFn, DecodeFn) = match tier {
            #[cfg(target_arch = "x86_64")]
            Tier::Avx512 => (encode_avx512, decode_avx512),
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => (encode_avx2, decode_swar),
            Tier::Swar => (encode_swar, decode_swar),
            _ => (encode_scalar, decode_scalar),
        };
        Self { tier, encode_bulk, decode_bulk, nt_copy: copy_for(tier) }
    }

    /// The tier this codec dispatches to.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// Encode `input` into `out[..input.len() * 2]`; returns the count.
    pub fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        self.encode_slice_policy(input, out, StorePolicy::Temporal)
    }

    /// [`Self::encode_slice`] with an explicit store policy.
    pub fn encode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        policy: StorePolicy,
    ) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        if !policy.use_nontemporal(total) {
            (self.encode_bulk)(input, &mut out[..total]);
            return total;
        }
        // Stage in L1, stream to `out` with non-temporal stores.
        const STAGE_RAW: usize = 2048;
        let mut stage = [0u8; STAGE_RAW * 2];
        let mut done = 0;
        while done < input.len() {
            let n = (input.len() - done).min(STAGE_RAW);
            (self.encode_bulk)(&input[done..done + n], &mut stage[..n * 2]);
            (self.nt_copy)(&mut out[done * 2..(done + n) * 2], &stage[..n * 2]);
            done += n;
        }
        fence();
        total
    }

    /// Decode `input` into `out[..input.len() / 2]`; returns the count.
    /// Odd input lengths are always `InvalidLength` (there is no
    /// forgiving nibble-drop mode).
    pub fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        self.decode_slice_policy(input, out, StorePolicy::Temporal)
    }

    /// [`Self::decode_slice`] with an explicit store policy.
    pub fn decode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        if input.len() % 2 != 0 {
            return Err(DecodeError::InvalidLength { len: input.len() });
        }
        let total = decoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let clean = if !policy.use_nontemporal(total) {
            (self.decode_bulk)(input, &mut out[..total])
        } else {
            const STAGE_CHARS: usize = 8192;
            let mut stage = [0u8; STAGE_CHARS / 2];
            let mut clean = true;
            let mut done = 0;
            while clean && done < input.len() {
                let n = (input.len() - done).min(STAGE_CHARS);
                clean = (self.decode_bulk)(&input[done..done + n], &mut stage[..n / 2]);
                (self.nt_copy)(&mut out[done / 2..(done + n) / 2], &stage[..n / 2]);
                done += n;
            }
            // The sfence contract holds on the error path too.
            fence();
            clean
        };
        if clean {
            Ok(total)
        } else {
            Err(first_invalid(input))
        }
    }

    /// Decode with a whitespace policy: skipped bytes are stripped once
    /// (SWAR word scan), and error offsets are rebased onto the original
    /// payload, matching the base64 engine's `decode_slice_ws` contract.
    pub fn decode_slice_ws(
        &self,
        input: &[u8],
        out: &mut [u8],
        ws: Whitespace,
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        if ws == Whitespace::None {
            return self.decode_slice_policy(input, out, policy);
        }
        let mut stripped = vec![0u8; input.len()];
        let (_, n) = crate::base64::swar::compact_ws(input, &mut stripped, ws);
        stripped.truncate(n);
        self.decode_slice_policy(&stripped, out, policy)
            .map_err(|e| rebase_ws_error(e, input, ws))
    }

    /// Encode to a fresh `Vec`.
    pub fn encode(&self, input: &[u8]) -> Vec<u8> {
        let mut v = vec![0u8; encoded_len(input.len())];
        self.encode_slice(input, &mut v);
        v
    }

    /// Decode to a fresh `Vec`.
    pub fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        let mut v = vec![0u8; decoded_len(input.len())];
        let n = self.decode_slice(input, &mut v)?;
        v.truncate(n);
        Ok(v)
    }
}

/// Cold path: exact position of the first non-hex byte.
fn first_invalid(input: &[u8]) -> DecodeError {
    for (i, &c) in input.iter().enumerate() {
        if DECODE[c as usize] == 0xFF {
            return DecodeError::InvalidByte { offset: i, byte: c };
        }
    }
    unreachable!("decode kernel flagged an error but every byte is valid hex")
}

fn encode_scalar(input: &[u8], out: &mut [u8]) {
    for (i, &b) in input.iter().enumerate() {
        out[2 * i] = ENCODE[(b >> 4) as usize];
        out[2 * i + 1] = ENCODE[(b & 0x0F) as usize];
    }
}

/// Branchless packed nibble→ASCII over eight lanes: digits land on
/// `'0' + n`; lanes holding 10–15 carry out of `n + 6` into bit 4,
/// selecting the extra `'A' - '9' - 1 = 7` hop over the punctuation.
fn nibbles_to_ascii(n: u64) -> u64 {
    let mask = ((n + 0x0606_0606_0606_0606) & 0x1010_1010_1010_1010) >> 4;
    n + 0x3030_3030_3030_3030 + mask * 0x07
}

fn encode_swar(input: &[u8], out: &mut [u8]) {
    const LOW_NIBBLES: u64 = 0x0F0F_0F0F_0F0F_0F0F;
    let mut chunks = input.chunks_exact(8);
    let mut o = 0;
    for ch in &mut chunks {
        let v = u64::from_le_bytes(ch.try_into().unwrap());
        let ha = nibbles_to_ascii((v >> 4) & LOW_NIBBLES).to_le_bytes();
        let la = nibbles_to_ascii(v & LOW_NIBBLES).to_le_bytes();
        for i in 0..8 {
            out[o + 2 * i] = ha[i];
            out[o + 2 * i + 1] = la[i];
        }
        o += 16;
    }
    encode_scalar(chunks.remainder(), &mut out[o..]);
}

fn decode_scalar(input: &[u8], out: &mut [u8]) -> bool {
    debug_assert_eq!(input.len() % 2, 0);
    let mut bad = 0u8;
    for (i, pair) in input.chunks_exact(2).enumerate() {
        let h = DECODE[pair[0] as usize];
        let l = DECODE[pair[1] as usize];
        bad |= h | l;
        out[i] = (h << 4) | (l & 0x0F);
    }
    bad & 0x80 == 0
}

/// Word-at-a-time decode: eight output bytes assembled per iteration
/// with one deferred validity accumulator.
fn decode_swar(input: &[u8], out: &mut [u8]) -> bool {
    debug_assert_eq!(input.len() % 2, 0);
    let mut bad = 0u8;
    let mut o = 0;
    let mut chunks = input.chunks_exact(16);
    for ch in &mut chunks {
        let mut w = 0u64;
        for i in 0..8 {
            let h = DECODE[ch[2 * i] as usize];
            let l = DECODE[ch[2 * i + 1] as usize];
            bad |= h | l;
            w |= ((((h << 4) | (l & 0x0F)) as u64) & 0xFF) << (8 * i);
        }
        out[o..o + 8].copy_from_slice(&w.to_le_bytes());
        o += 8;
    }
    bad & 0x80 == 0 && decode_scalar(chunks.remainder(), &mut out[o..])
}

#[cfg(target_arch = "x86_64")]
fn encode_avx2(input: &[u8], out: &mut [u8]) {
    let chunks = input.len() / 16 * 16;
    // Safety: selected only when Tier::Avx2 is available on this host.
    unsafe { avx2::encode(&input[..chunks], out) };
    encode_scalar(&input[chunks..], &mut out[chunks * 2..]);
}

#[cfg(target_arch = "x86_64")]
fn encode_avx512(input: &[u8], out: &mut [u8]) {
    let chunks = input.len() / 32 * 32;
    // Safety: selected only when Tier::Avx512 is available
    // (avx512f + avx512bw + avx512vbmi).
    unsafe { avx512::encode(&input[..chunks], out) };
    encode_scalar(&input[chunks..], &mut out[chunks * 2..]);
}

#[cfg(target_arch = "x86_64")]
fn decode_avx512(input: &[u8], out: &mut [u8]) -> bool {
    debug_assert_eq!(input.len() % 2, 0);
    let chunks = input.len() / 64 * 64;
    // Safety: selected only when Tier::Avx512 is available.
    let clean = unsafe { avx512::decode(&input[..chunks], out) };
    clean && decode_swar(&input[chunks..], &mut out[chunks / 2..])
}

/// AVX2 (128-bit `vpshufb`) nibble LUT encode.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::ENCODE;
    use core::arch::x86_64::*;

    /// Encode 16 raw bytes → 32 hex chars per iteration; `input` must
    /// be a multiple of 16 bytes.
    ///
    /// # Safety
    /// Requires AVX2 (the 128-bit ops compile to VEX forms).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn encode(input: &[u8], out: &mut [u8]) {
        debug_assert_eq!(input.len() % 16, 0);
        let lut = _mm_loadu_si128(ENCODE.as_ptr() as *const __m128i);
        let low = _mm_set1_epi8(0x0F);
        for (i, ch) in input.chunks_exact(16).enumerate() {
            let v = _mm_loadu_si128(ch.as_ptr() as *const __m128i);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), low);
            let lo = _mm_and_si128(v, low);
            let hc = _mm_shuffle_epi8(lut, hi);
            let lc = _mm_shuffle_epi8(lut, lo);
            let dst = out.as_mut_ptr().add(32 * i);
            _mm_storeu_si128(dst as *mut __m128i, _mm_unpacklo_epi8(hc, lc));
            _mm_storeu_si128(dst.add(16) as *mut __m128i, _mm_unpackhi_epi8(hc, lc));
        }
    }
}

/// AVX-512 VBMI kernels, mirroring the structure of `base64::avx512`:
/// `vpermb` shuffles, `vpmultishiftqb` bit-field extraction, a
/// two-register `vpermi2b` decode table with `0x80` sentinels, and one
/// deferred `vpternlogd`-accumulated error check per stream.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use super::{DECODE128, ENCODE};
    use core::arch::x86_64::*;

    /// `vpermb` index duplicating each input byte into a char pair.
    const DUP: [u8; 64] = {
        let mut t = [0u8; 64];
        let mut i = 0;
        while i < 64 {
            t[i] = (i / 2) as u8;
            i += 1;
        }
        t
    };

    /// Per-qword `vpmultishiftqb` controls: with byte pairs
    /// `in[2j] in[2j]` along each qword, offsets 4/8 (then +16) land the
    /// high and low nibble of each source byte in the low 4 bits of the
    /// right output char slot.
    const ENC_SHIFTS: [u8; 8] = [4, 8, 20, 24, 36, 40, 52, 56];

    /// `vpermb` index gathering the low byte of each 16-bit madd lane.
    const EVEN: [u8; 64] = {
        let mut t = [0u8; 64];
        let mut i = 0;
        while i < 32 {
            t[i] = (2 * i) as u8;
            i += 1;
        }
        t
    };

    /// Encode 32 raw bytes → 64 hex chars per iteration; `input` must
    /// be a multiple of 32 bytes.
    ///
    /// # Safety
    /// Requires avx512f, avx512bw and avx512vbmi.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub(super) unsafe fn encode(input: &[u8], out: &mut [u8]) {
        debug_assert_eq!(input.len() % 32, 0);
        let dup = _mm512_loadu_si512(DUP.as_ptr() as *const i32);
        let shifts = _mm512_set1_epi64(i64::from_le_bytes(ENC_SHIFTS));
        let lut = _mm512_maskz_loadu_epi8(0xFFFF, ENCODE.as_ptr() as *const i8);
        let low = _mm512_set1_epi8(0x0F);
        for (i, ch) in input.chunks_exact(32).enumerate() {
            let src = _mm512_maskz_loadu_epi8(0xFFFF_FFFF, ch.as_ptr() as *const i8);
            let pairs = _mm512_permutexvar_epi8(dup, src);
            let nibbles = _mm512_and_si512(_mm512_multishift_epi64_epi8(shifts, pairs), low);
            let chars = _mm512_permutexvar_epi8(nibbles, lut);
            _mm512_storeu_si512(out.as_mut_ptr().add(64 * i) as *mut i32, chars);
        }
    }

    /// Decode 64 hex chars → 32 raw bytes per iteration with deferred
    /// validation; `input` must be a multiple of 64 chars. Returns
    /// `false` if any byte was invalid (caller re-scans for the offset).
    ///
    /// # Safety
    /// Requires avx512f, avx512bw and avx512vbmi.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub(super) unsafe fn decode(input: &[u8], out: &mut [u8]) -> bool {
        debug_assert_eq!(input.len() % 64, 0);
        let lut_lo = _mm512_loadu_si512(DECODE128.as_ptr() as *const i32);
        let lut_hi = _mm512_loadu_si512(DECODE128.as_ptr().add(64) as *const i32);
        let gather = _mm512_loadu_si512(EVEN.as_ptr() as *const i32);
        // Per 16-bit lane: high-nibble char value * 16 + low-nibble value.
        let madd = _mm512_set1_epi16(0x0110);
        let mut error = _mm512_setzero_si512();
        for (i, ch) in input.chunks_exact(64).enumerate() {
            let chars = _mm512_loadu_si512(ch.as_ptr() as *const i32);
            let vals = _mm512_permutex2var_epi8(lut_lo, chars, lut_hi);
            // error |= chars | vals — flags bit 7 for non-ASCII input
            // and for the 0x80 invalid sentinel.
            error = _mm512_ternarylogic_epi32(error, chars, vals, 0xFE);
            let words = _mm512_maddubs_epi16(vals, madd);
            let packed = _mm512_permutexvar_epi8(gather, words);
            _mm512_mask_storeu_epi8(
                out.as_mut_ptr().add(32 * i) as *mut i8,
                0xFFFF_FFFF,
                packed,
            );
        }
        _mm512_movepi8_mask(error) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference_encode(input: &[u8]) -> Vec<u8> {
        let mut v = vec![0u8; encoded_len(input.len())];
        encode_scalar(input, &mut v);
        v
    }

    fn data(len: usize) -> Vec<u8> {
        (0..len).map(|i| (i * 89 % 256) as u8).collect()
    }

    #[test]
    fn rfc4648_vectors() {
        let c = HexCodec::new();
        for (raw, hex) in [
            (&b""[..], &b""[..]),
            (b"f", b"66"),
            (b"fo", b"666F"),
            (b"foo", b"666F6F"),
            (b"foob", b"666F6F62"),
            (b"fooba", b"666F6F6261"),
            (b"foobar", b"666F6F626172"),
        ] {
            assert_eq!(c.encode(raw), hex);
            assert_eq!(c.decode(hex).unwrap(), raw);
        }
    }

    #[test]
    fn lowercase_accepted() {
        let c = HexCodec::new();
        assert_eq!(c.decode(b"666f6f626172").unwrap(), b"foobar");
        assert_eq!(c.decode(b"deadBEEF").unwrap(), [0xDE, 0xAD, 0xBE, 0xEF]);
    }

    #[test]
    fn all_tiers_match_scalar() {
        for tier in Tier::supported() {
            let c = HexCodec::with_tier(tier);
            for len in [0usize, 1, 7, 8, 15, 16, 31, 32, 33, 63, 64, 100, 1000, 5000] {
                let raw = data(len);
                let enc = c.encode(&raw);
                assert_eq!(enc, reference_encode(&raw), "tier={tier:?} len={len}");
                assert_eq!(c.decode(&enc).unwrap(), raw, "tier={tier:?} len={len}");
            }
        }
    }

    #[test]
    fn policies_match_temporal() {
        for tier in Tier::supported() {
            let c = HexCodec::with_tier(tier);
            for policy in [StorePolicy::Temporal, StorePolicy::NonTemporal, StorePolicy::auto()] {
                for len in [0usize, 100, 2047, 2048, 2049, 8191, 8192, 50_000] {
                    let raw = data(len);
                    let mut enc = vec![0u8; encoded_len(len)];
                    let n = c.encode_slice_policy(&raw, &mut enc, policy);
                    assert_eq!(n, encoded_len(len));
                    assert_eq!(enc, reference_encode(&raw), "tier={tier:?} len={len}");
                    let mut dec = vec![0u8; decoded_len(enc.len())];
                    let n = c.decode_slice_policy(&enc, &mut dec, policy).unwrap();
                    assert_eq!(&dec[..n], raw, "tier={tier:?} len={len}");
                }
            }
        }
    }

    #[test]
    fn error_offsets_match_across_tiers() {
        let raw = data(700);
        let enc = reference_encode(&raw);
        for pos in [0usize, 1, 63, 64, 65, 700, 1399] {
            let mut bad = enc.clone();
            bad[pos] = b'!';
            for tier in Tier::supported() {
                let c = HexCodec::with_tier(tier);
                match c.decode(&bad) {
                    Err(DecodeError::InvalidByte { offset, byte }) => {
                        assert_eq!((offset, byte), (pos, b'!'), "tier={tier:?} pos={pos}")
                    }
                    other => panic!("tier={tier:?} pos={pos}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn odd_length_rejected() {
        let c = HexCodec::new();
        assert!(matches!(c.decode(b"ABC"), Err(DecodeError::InvalidLength { len: 3 })));
    }

    #[test]
    fn ws_decode_rebases_offsets() {
        let c = HexCodec::new();
        let mut out = vec![0u8; 16];
        let n = c
            .decode_slice_ws(b"66 6F\r\n6F", &mut out, Whitespace::All, StorePolicy::Temporal)
            .unwrap();
        assert_eq!(&out[..n], b"foo");
        match c.decode_slice_ws(b"66 6!", &mut out, Whitespace::All, StorePolicy::Temporal) {
            Err(DecodeError::InvalidByte { offset: 4, byte: b'!' }) => {}
            other => panic!("{other:?}"),
        }
    }
}
