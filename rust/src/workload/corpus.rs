//! The paper's evaluation workloads, synthesized.
//!
//! Table 3 benchmarks four real files (lena.jpg, mandril.jpg, the Google
//! logo PNG, a large zip). We do not ship those binaries; instead we
//! generate size-matched, entropy-matched stand-ins. The substitution is
//! justified by the paper itself: "We do not expect the vectorized codecs
//! (AVX2 and AVX-512) to be sensitive to the content of the input,
//! keeping the size constant" (§4) — and its Table 3 confirms content
//! insensitivity. What matters is the *size relative to the cache
//! hierarchy*, which we match byte-exactly. Compressed image/zip payloads
//! are ~uniform random at the byte level, which is what we generate.

use super::rng::random_bytes;

/// One synthetic corpus file (a Table 3 row).
pub struct CorpusFile {
    /// Paper's label, e.g. "lena [jpg]".
    pub name: &'static str,
    /// Raw (decoded) size in bytes — matches the paper's "bytes" column.
    pub bytes: usize,
    /// Synthesized contents.
    pub data: Vec<u8>,
    /// Paper's reported decoding speeds for this file (GB/s), for the
    /// EXPERIMENTS.md comparison: (memcpy, chrome, avx2, avx512).
    pub paper_gbps: (f64, f64, f64, f64),
}

/// The Table 3 corpus, sizes straight from the paper.
pub fn table3_corpus() -> Vec<CorpusFile> {
    vec![
        CorpusFile {
            name: "lena [jpg]",
            bytes: 141_020,
            data: random_bytes(141_020, 0x1e4a),
            paper_gbps: (25.0, 2.6, 14.0, 32.0),
        },
        CorpusFile {
            name: "mandril [jpg]",
            bytes: 247_222,
            data: random_bytes(247_222, 0x2a4d),
            paper_gbps: (18.0, 2.6, 14.0, 25.0),
        },
        CorpusFile {
            name: "Google logo [png]",
            bytes: 2_357,
            data: random_bytes(2_357, 0x60061e),
            paper_gbps: (44.0, 2.6, 14.0, 42.0),
        },
        CorpusFile {
            name: "large [zip]",
            bytes: 34_904_444,
            data: random_bytes(34_904_444, 0x21b),
            paper_gbps: (9.5, 2.6, 8.3, 9.5),
        },
    ]
}

/// Fig. 4's x-axis: base64 sizes from 1 kB to 64 kB (the paper sweeps
/// powers of two plus intermediate points; we use powers of two and the
/// 1.5× midpoints for the same resolution).
pub fn fig4_sizes() -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut s = 1024usize;
    while s <= 65536 {
        sizes.push(s);
        if s + s / 2 <= 65536 {
            sizes.push(s + s / 2);
        }
        s *= 2;
    }
    sizes.sort_unstable();
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_sizes_match_paper() {
        let c = table3_corpus();
        assert_eq!(c.len(), 4);
        assert_eq!(c[0].bytes, 141_020);
        assert_eq!(c[1].bytes, 247_222);
        assert_eq!(c[2].bytes, 2_357);
        assert_eq!(c[3].bytes, 34_904_444);
        for f in &c {
            assert_eq!(f.data.len(), f.bytes);
        }
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = table3_corpus();
        let b = table3_corpus();
        assert_eq!(a[0].data, b[0].data);
    }

    #[test]
    fn fig4_sizes_span_1k_to_64k() {
        let s = fig4_sizes();
        assert_eq!(*s.first().unwrap(), 1024);
        assert_eq!(*s.last().unwrap(), 65536);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.len() >= 10);
    }
}
