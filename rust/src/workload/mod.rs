//! Workload generation: deterministic random payloads, the Fig. 4 size
//! sweep and the Table 3 corpus (synthetic stand-ins for the paper's
//! files — see DESIGN.md §2 for the substitution argument).

mod corpus;
mod rng;

pub use corpus::{fig4_sizes, table3_corpus, CorpusFile};
pub use rng::{random_base64, random_bytes, Rng64};
