//! Deterministic pseudo-random data (xoshiro256**, seeded) — no external
//! RNG crates, reproducible across runs and platforms.

/// xoshiro256** generator.
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Seed via splitmix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`.
    pub fn below(&mut self, bound: u64) -> u64 {
        // Lemire's multiply-shift rejection-free approximation is fine for
        // workload generation; bias is < 2^-32 for our bounds.
        ((self.next_u64() >> 32).wrapping_mul(bound)) >> 32
    }

    /// Fill `buf` with random bytes.
    pub fn fill(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rest = chunks.into_remainder();
        if !rest.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rest.copy_from_slice(&b[..rest.len()]);
        }
    }
}

/// `n` uniform random bytes (the paper's "random binary data", §4).
pub fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng64::new(seed);
    let mut out = vec![0u8; n];
    rng.fill(&mut out);
    out
}

/// `n` valid base64 chars of the given alphabet (uniform over values),
/// length rounded down to a multiple of 4; no padding.
pub fn random_base64(n: usize, seed: u64, alphabet: &crate::base64::Alphabet) -> Vec<u8> {
    let n = n & !3;
    let chars = alphabet.chars();
    let mut rng = Rng64::new(seed);
    (0..n).map(|_| chars[rng.below(64) as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(random_bytes(64, 42), random_bytes(64, 42));
        assert_ne!(random_bytes(64, 42), random_bytes(64, 43));
    }

    #[test]
    fn fill_handles_remainders() {
        for n in [0usize, 1, 7, 8, 9, 63] {
            assert_eq!(random_bytes(n, 1).len(), n);
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng64::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(64) < 64);
        }
    }

    #[test]
    fn random_base64_is_decodable() {
        use crate::base64::{block::BlockCodec, Alphabet, Codec};
        let a = Alphabet::standard();
        let payload = random_base64(1000, 9, &a);
        assert_eq!(payload.len(), 1000);
        BlockCodec::new(a).decode(&payload).unwrap();
    }

    #[test]
    fn bytes_look_uniform() {
        // Crude sanity: all 256 values appear in 64 kB.
        let data = random_bytes(65536, 3);
        let mut seen = [false; 256];
        for &b in &data {
            seen[b as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
