//! Connection deadlines for the readiness loops.
//!
//! Each reactor shard owns one [`TimerWheel`] holding `(deadline,
//! token)` pairs — one live entry per open connection (idle, read-stall
//! or write-stall deadline, whichever is nearest, or a coarse heartbeat
//! when none applies). The wheel's next deadline becomes the shard's
//! `epoll_wait` timeout, so an idle server still blocks indefinitely
//! and a loaded one wakes exactly when the earliest deadline is due.
//!
//! Deadlines only ever move *later* (activity on a connection does not
//! touch the wheel): when an entry pops, the loop re-evaluates the
//! connection's actual state and either acts on a due deadline or
//! re-inserts the entry at the recomputed time. Entries for closed
//! connections are recognized as stale by the slab-epoch token and
//! dropped on pop. This keeps every wheel operation O(log n) with no
//! deletion support needed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Min-heap of `(deadline, token)` pairs.
pub(crate) struct TimerWheel {
    heap: BinaryHeap<Reverse<(Instant, u64)>>,
}

impl TimerWheel {
    pub fn new() -> TimerWheel {
        TimerWheel { heap: BinaryHeap::new() }
    }

    /// Insert an entry. Duplicates for a token are allowed — stale ones
    /// are filtered by the caller's epoch check on pop.
    pub fn schedule(&mut self, deadline: Instant, token: u64) {
        self.heap.push(Reverse((deadline, token)));
    }

    /// Milliseconds until the earliest deadline, as an `epoll_wait`
    /// timeout: `-1` (block indefinitely) when empty, `0` when the
    /// earliest entry is already due (the wait must poll, not sleep —
    /// a ≥1 ms floor here made every timeout pass on a loaded shard
    /// oversleep past a due deadline), else the rounded-up remaining
    /// time (≥ 1, capped to `i32::MAX`).
    pub fn next_timeout_ms(&self, now: Instant) -> i32 {
        match self.heap.peek() {
            None => -1,
            Some(Reverse((deadline, _))) => {
                let remaining = deadline.saturating_duration_since(now);
                if remaining.is_zero() {
                    return 0;
                }
                // Round *future* deadlines up so the wait never wakes
                // before the deadline and spins on a not-yet-due entry.
                let ms = remaining.as_millis().saturating_add(1);
                ms.min(i32::MAX as u128) as i32
            }
        }
    }

    /// Pop the next entry whose deadline is at or before `now`.
    pub fn pop_due(&mut self, now: Instant) -> Option<u64> {
        match self.heap.peek() {
            Some(Reverse((deadline, _))) if *deadline <= now => {
                let Reverse((_, token)) = self.heap.pop().unwrap();
                Some(token)
            }
            _ => None,
        }
    }

    /// Entries currently scheduled (live + stale).
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pops_in_deadline_order() {
        let mut w = TimerWheel::new();
        let base = Instant::now();
        w.schedule(base + Duration::from_millis(30), 3);
        w.schedule(base + Duration::from_millis(10), 1);
        w.schedule(base + Duration::from_millis(20), 2);
        let later = base + Duration::from_millis(25);
        assert_eq!(w.pop_due(later), Some(1));
        assert_eq!(w.pop_due(later), Some(2));
        assert_eq!(w.pop_due(later), None, "entry 3 not yet due");
        assert_eq!(w.len(), 1);
        assert_eq!(w.pop_due(base + Duration::from_millis(31)), Some(3));
    }

    #[test]
    fn timeout_reflects_earliest_entry() {
        let mut w = TimerWheel::new();
        let now = Instant::now();
        assert_eq!(w.next_timeout_ms(now), -1, "empty wheel blocks indefinitely");
        w.schedule(now + Duration::from_millis(500), 7);
        let ms = w.next_timeout_ms(now);
        assert!((1..=502).contains(&ms), "got {ms}");
        // A due (or past-due) entry must yield a zero timeout — the
        // wait polls and the deadline is acted on immediately. The old
        // behaviour returned ≥ 1 ms here, oversleeping a due deadline
        // on every pass.
        assert_eq!(w.next_timeout_ms(now + Duration::from_millis(500)), 0, "due entry polls");
        assert_eq!(w.next_timeout_ms(now + Duration::from_secs(1)), 0, "past-due entry polls");
    }

    #[test]
    fn future_deadlines_round_up_never_zero() {
        let mut w = TimerWheel::new();
        let now = Instant::now();
        w.schedule(now + Duration::from_micros(300), 1);
        let ms = w.next_timeout_ms(now);
        assert!((1..=2).contains(&ms), "sub-ms future deadline rounds up to ≥1, got {ms}");
    }

    #[test]
    fn duplicate_tokens_coexist() {
        let mut w = TimerWheel::new();
        let now = Instant::now();
        w.schedule(now, 9);
        w.schedule(now, 9);
        assert_eq!(w.pop_due(now), Some(9));
        assert_eq!(w.pop_due(now), Some(9));
        assert_eq!(w.pop_due(now), None);
    }
}
