//! Reusable byte-buffer pool for the readiness loop.
//!
//! Every connection needs a frame-accumulation buffer and a write
//! queue; with thousands of mostly-idle connections, allocating them
//! per connection and freeing on close would churn the allocator on
//! every accept. Each reactor shard owns its own pool and its loop is
//! single-threaded, so the pool is a plain free list — no locks.
//! Buffers that ballooned while carrying a large frame are dropped
//! rather than retained, bounding the pool's resident footprint at
//! `max_buffers * retain_cap`. The zero-copy reply path also feeds the
//! pool: when a write queue adopts a finished reply buffer, the spare
//! buffer from the swap is parked here.

/// A lock-free-because-single-threaded pool of `Vec<u8>` buffers.
pub struct BufferPool {
    free: Vec<Vec<u8>>,
    /// Most buffers kept on the free list.
    max_buffers: usize,
    /// Buffers whose capacity grew beyond this are dropped on `put`.
    retain_cap: usize,
}

impl BufferPool {
    /// A pool keeping at most `max_buffers` buffers, dropping any whose
    /// capacity grew past `retain_cap` bytes.
    pub fn new(max_buffers: usize, retain_cap: usize) -> BufferPool {
        BufferPool { free: Vec::with_capacity(max_buffers.min(64)), max_buffers, retain_cap }
    }

    /// Take a cleared buffer (recycled when one is available). An empty
    /// free list is not an error — callers get a fresh allocation — so
    /// the `faults` feature exercises pool exhaustion by pretending the
    /// list is empty: correctness must not depend on recycling.
    pub fn get(&mut self) -> Vec<u8> {
        #[cfg(feature = "faults")]
        if crate::net::faults::pool_exhausted() {
            return Vec::new();
        }
        self.free.pop().unwrap_or_default()
    }

    /// Return a buffer. Oversized or surplus buffers are dropped.
    pub fn put(&mut self, mut buf: Vec<u8>) {
        if buf.capacity() > self.retain_cap || self.free.len() >= self.max_buffers {
            return;
        }
        buf.clear();
        self.free.push(buf);
    }

    /// Buffers currently parked on the free list.
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_buffers() {
        let mut pool = BufferPool::new(4, 1 << 20);
        let mut a = pool.get();
        a.extend_from_slice(b"hello");
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.get();
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert_eq!(b.capacity(), cap, "same allocation reused");
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn drops_oversized_and_surplus() {
        let mut pool = BufferPool::new(2, 64);
        pool.put(Vec::with_capacity(1024)); // over retain_cap
        assert_eq!(pool.idle(), 0);
        pool.put(Vec::with_capacity(16));
        pool.put(Vec::with_capacity(16));
        pool.put(Vec::with_capacity(16)); // over max_buffers
        assert_eq!(pool.idle(), 2);
    }
}
