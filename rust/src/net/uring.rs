//! The io_uring transport: completion-driven reactor shards on
//! submission/completion rings, sharing everything above the syscall
//! boundary with the epoll loop — [`super::driver`]'s worker pool,
//! `WorkItem`/`Completion` hand-off, framing, buffer pool, connection
//! limiter and timer wheel — so the two backends answer byte-identical
//! traffic and differ only in how bytes cross the kernel boundary.
//!
//! ```text
//!   clients ─► SO_REUSEPORT ─► [uring shard 0] ──┐
//!              (kernel hash)   [uring shard 1] ──┤ WorkItem ─► [workers] ─► Router
//!                              [uring shard N] ──┘    ▲            │
//!                 eventfd READ ◄── Completion ──────────────────◄──┘
//!                 (one armed op per shard)
//! ```
//!
//! Where the epoll loop pays a `read`/`write` syscall pair per ready
//! connection plus the `epoll_wait`, a uring shard pays one
//! `io_uring_enter` per loop iteration, amortized over every ready
//! connection: accepts arrive through a multishot ACCEPT op (one SQE,
//! many completions; pre-5.19 kernels report `-EINVAL` and the shard
//! silently re-arms single-shot), reads complete directly into a
//! kernel-registered buffer arena (`IORING_REGISTER_BUFFERS` +
//! `READ_FIXED`, so the kernel skips the per-op page lookup; if
//! registration is refused — `RLIMIT_MEMLOCK` — the shard degrades to
//! plain `READ` on the same arena), and replies are swapped out of the
//! `WriteQueue` whole ([`WriteQueue::take_pending`]) and written with
//! one in-flight WRITE op per connection, which also preserves wire
//! order without SQE links.
//!
//! ## Ownership across the syscall boundary
//!
//! The kernel holds raw pointers into the read arena, into a
//! connection's swapped-out write buffer and into the shard's eventfd
//! scratch word for as long as an op is in flight. Three rules keep
//! that sound: a connection close *initiates* (cancels its in-flight
//! ops) and only *finishes* — freeing the slot, pooling the buffers,
//! bumping the epoch — once both ops have completed; an arena page is
//! released only after its completion's bytes have been copied into
//! the connection's frame accumulator; and shard teardown reaps until
//! every op has completed, leaking the arena and any stuck write
//! buffers (with a logged warning) rather than freeing memory the
//! kernel might still write.
//!
//! Stale completions are fenced the same way the epoll loop fences
//! stale readiness: every `user_data` token carries the slot's epoch,
//! and the epoch only advances when the slot is truly vacated.
//!
//! [`WriteQueue::take_pending`]: super::frame::WriteQueue::take_pending

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, FromRawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::buffer::BufferPool;
use super::conn::{Conn, Inbound, Job, Machine, WRITE_HIGH_WATER};
use super::driver::{
    http_error_status, lock_clean, peer_ip, refuse_busy_http, token, token_parts, worker_loop,
    Completion, NetServer, WorkItem, DRAIN_POLL_MS, HEARTBEAT,
};
use super::frame::FrameMachine;
use super::http::{timeout_response, HttpMachine, Protocol};
use super::sys::{Cqe, EventFd, IoUring, IoVec, Sqe, ECANCELED, EINVAL, IORING_CQE_F_MORE};
use super::timer::TimerWheel;
use crate::coordinator::backpressure::{ConnLimiter, RateLimiter};
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::{Metrics, Router};
use crate::obs::recorder::{EventKind, FlightRecorder};
use crate::server::service::{
    idle_timeout_frame, refuse_busy, stall_timeout_frame, ServerConfig,
};

/// One registered read page per in-flight read.
const READ_PAGE: usize = 16 << 10;
/// Submission ring size; `IoUring::push` flushes when full, so this
/// bounds batching, not correctness.
const SQ_ENTRIES: u32 = 256;
/// Completion ring size. `IORING_FEAT_NODROP` (required by the probe)
/// buffers overflow kernel-side, so this is a fast-path size, not a cap.
const CQ_ENTRIES: u32 = 4096;

/// Transient errno values a read/write op retries instead of closing.
const EAGAIN: i32 = 11;
const EINTR: i32 = 4;

// user_data layout: | op:3 | page:12 | epoch:29 | idx:20 |
//
// Reads carry their arena page so the completion can both locate the
// bytes and release the page even when the connection is already gone
// (a stale epoch must not leak the page). The epoch is the connection
// slot generation truncated to 29 bits — truncation is safe because a
// slot's in-flight ops always complete (or cancel) before the slot is
// vacated and its epoch advances, so no two *concurrently live* tokens
// for one slot can differ by a multiple of 2^29.
const OP_READ: u64 = 0;
const OP_WRITE: u64 = 1;
const OP_ACCEPT: u64 = 2;
const OP_WAKE: u64 = 3;
const OP_CANCEL: u64 = 4;
const EPOCH_MASK: u32 = 0x1FFF_FFFF;

fn utoken(op: u64, page: usize, epoch: u32, idx: usize) -> u64 {
    (op << 61)
        | (((page as u64) & 0xFFF) << 49)
        | ((u64::from(epoch & EPOCH_MASK)) << 20)
        | ((idx as u64) & 0xF_FFFF)
}

fn utoken_parts(tok: u64) -> (u64, usize, u32, usize) {
    (tok >> 61, ((tok >> 49) & 0xFFF) as usize, ((tok >> 20) & 0x1FFF_FFFF) as u32, (tok & 0xF_FFFF) as usize)
}

const ACCEPT_TOKEN: u64 = OP_ACCEPT << 61;
const WAKE_TOKEN: u64 = OP_WAKE << 61;
const CANCEL_TOKEN: u64 = OP_CANCEL << 61;

/// Spawn one uring shard per listener plus the shared worker pool —
/// [`super::driver::spawn`]'s contract on a different syscall engine.
/// The caller must have checked [`super::sys::uring_supported`]; ring
/// construction can still fail per shard (e.g. locked-memory limits on
/// the rings themselves), which unwinds every thread spawned so far.
pub(crate) fn spawn(
    router: Arc<Router>,
    config: &ServerConfig,
    listeners: Vec<(TcpListener, Protocol)>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) -> std::io::Result<NetServer> {
    let limiter = ConnLimiter::new(config.max_connections);
    // One token table across every shard, as in the epoll transport.
    let rate = RateLimiter::new(config.rate_limit);
    let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let metrics = router.metrics().clone();
    metrics.reset_shards();

    let mut threads = Vec::new();
    let mut wakes: Vec<Arc<EventFd>> = Vec::new();
    let mut built = Ok(());
    for (shard_id, listener) in listeners.into_iter().enumerate() {
        let spawned = spawn_shard(
            shard_id, listener, config, &metrics, &limiter, &rate, &work_tx, &stop, &drain,
        );
        match spawned {
            Ok((thread, wake)) => {
                threads.push(thread);
                wakes.push(wake);
            }
            Err(e) => {
                built = Err(e);
                break;
            }
        }
    }
    drop(work_tx);
    let zero_copy = config.zero_copy;
    if built.is_ok() {
        for i in 0..config.net_workers.max(1) {
            let rx = work_rx.clone();
            let router = router.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("b64simd-net-worker-{i}"))
                .spawn(move || worker_loop(rx, router, zero_copy));
            match spawned {
                Ok(t) => threads.push(t),
                Err(e) => {
                    built = Err(e);
                    break;
                }
            }
        }
    }
    if let Err(e) = built {
        stop.store(true, Ordering::SeqCst);
        for w in &wakes {
            w.signal();
        }
        for t in threads {
            let _ = t.join();
        }
        return Err(e);
    }
    Ok(NetServer { threads, wakes })
}

/// Set up one uring shard: its ring, registered read arena, wake fd,
/// completion queue and loop thread.
#[allow(clippy::too_many_arguments)]
fn spawn_shard(
    shard_id: usize,
    listener: (TcpListener, Protocol),
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    limiter: &Arc<ConnLimiter>,
    rate: &Option<Arc<RateLimiter>>,
    work_tx: &mpsc::Sender<WorkItem>,
    stop: &Arc<AtomicBool>,
    drain: &Arc<AtomicBool>,
) -> std::io::Result<(JoinHandle<()>, Arc<EventFd>)> {
    let (listener, protocol) = listener;
    let wake = Arc::new(EventFd::new()?);
    let ring = IoUring::new(SQ_ENTRIES, CQ_ENTRIES)?;
    // One read page per possible connection, capped so the pinned
    // arena stays modest under RLIMIT_MEMLOCK (256 pages = 4 MiB).
    let pages = config.max_connections.clamp(64, 256);
    let mut arena = vec![0u8; pages * READ_PAGE];
    let iovs: Vec<IoVec> = (0..pages)
        .map(|p| IoVec { base: arena[p * READ_PAGE..].as_mut_ptr().cast(), len: READ_PAGE })
        .collect();
    let fixed = match ring.register_buffers(&iovs) {
        Ok(()) => true,
        Err(e) => {
            crate::log_warn!(
                "uring",
                "shard {shard_id}: buffer registration failed ({e}); \
                 degrading to unregistered reads"
            );
            false
        }
    };
    let recorder = Arc::new(FlightRecorder::new(format!("uring-{shard_id}")));
    crate::obs::recorder::register(&recorder);
    let lp = ULoop {
        ring,
        listener: Some(listener),
        protocol,
        recorder,
        rate: rate.clone(),
        wake: wake.clone(),
        wake_buf: Box::new(0),
        wake_armed: false,
        metrics: metrics.clone(),
        shard: metrics.register_shard(),
        limiter: limiter.clone(),
        max_streams: config.max_streams_per_connection,
        zero_copy: config.zero_copy,
        conns: Vec::new(),
        epochs: Vec::new(),
        free: Vec::new(),
        pool: BufferPool::new(2048, 256 << 10),
        work_tx: work_tx.clone(),
        completions: Arc::new(Mutex::new(Vec::new())),
        stop: stop.clone(),
        drain: drain.clone(),
        draining: false,
        shutting: false,
        drain_deadline: None,
        wheel: TimerWheel::new(),
        idle_timeout: config.idle_timeout,
        read_timeout: config.read_timeout,
        write_timeout: config.write_timeout,
        drain_grace: config.drain_grace,
        arena,
        fixed,
        free_pages: (0..pages).rev().collect(),
        read_waiters: VecDeque::new(),
        multishot: true,
        accept_armed: false,
        accept_errors: 0,
        accept_rearm_pending: false,
    };
    let thread = std::thread::Builder::new()
        .name(format!("b64simd-uring-loop-{shard_id}"))
        .spawn(move || lp.run())?;
    Ok((thread, wake))
}

/// Per-connection uring state wrapped around the transport-agnostic
/// [`Conn`].
struct UConn {
    conn: Conn,
    /// A READ op referencing `read_page` is in flight.
    read_inflight: bool,
    read_page: usize,
    /// Queued in `read_waiters` for a free arena page.
    read_waiting: bool,
    /// A WRITE op referencing `wbuf[wpos..]` is in flight.
    write_inflight: bool,
    /// Reply bytes swapped out of the `WriteQueue` for the kernel:
    /// address-stable for the life of the WRITE op.
    wbuf: Option<Vec<u8>>,
    wpos: usize,
    /// Close initiated; the slot is vacated once in-flight ops drain.
    closing: bool,
}

impl UConn {
    /// Reply bytes not yet on the wire: queued plus swapped-out.
    fn out_pending(&self) -> usize {
        self.conn.write.pending() + self.wbuf.as_ref().map_or(0, |b| b.len() - self.wpos)
    }

    /// [`Conn::drained`] extended over the swapped-out write buffer.
    fn is_drained(&self) -> bool {
        self.conn.drained() && self.wbuf.is_none() && !self.write_inflight
    }
}

/// One single-threaded completion loop (a uring reactor shard).
struct ULoop {
    ring: IoUring,
    /// Dropped when drain begins (its ACCEPT op is cancelled first).
    listener: Option<TcpListener>,
    /// Wire protocol of every connection accepted from this listener.
    protocol: Protocol,
    /// This shard's flight recorder (registered in the process-wide
    /// registry for `/debug/trace` and SIGUSR1 dumps).
    recorder: Arc<FlightRecorder>,
    /// Per-client token buckets for the HTTP gateway (`None` = off or a
    /// native shard); shared across shards.
    rate: Option<Arc<RateLimiter>>,
    wake: Arc<EventFd>,
    /// Heap word the armed wake READ lands in (stable address).
    wake_buf: Box<u64>,
    wake_armed: bool,
    metrics: Arc<Metrics>,
    shard: Arc<ShardMetrics>,
    limiter: Arc<ConnLimiter>,
    max_streams: usize,
    zero_copy: bool,
    conns: Vec<Option<UConn>>,
    epochs: Vec<u32>,
    free: Vec<usize>,
    pool: BufferPool,
    work_tx: mpsc::Sender<WorkItem>,
    completions: Arc<Mutex<Vec<Completion>>>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    draining: bool,
    /// Final teardown: reap-only, nothing re-arms.
    shutting: bool,
    drain_deadline: Option<Instant>,
    wheel: TimerWheel,
    idle_timeout: Duration,
    read_timeout: Duration,
    write_timeout: Duration,
    drain_grace: Duration,
    /// Read landing area; pages pinned by the kernel when `fixed`.
    arena: Vec<u8>,
    /// Registered-buffer reads (`READ_FIXED`) vs the plain-`READ`
    /// degradation.
    fixed: bool,
    free_pages: Vec<usize>,
    /// Connections waiting for an arena page, woken FIFO.
    read_waiters: VecDeque<usize>,
    /// Multishot accept believed supported (cleared on `-EINVAL`).
    multishot: bool,
    accept_armed: bool,
    accept_errors: u32,
    /// Error-storm backoff: re-arm accept on the next loop pass
    /// instead of inline.
    accept_rearm_pending: bool,
}

impl ULoop {
    fn run(mut self) {
        crate::obs::recorder::set_thread_recorder(Some(self.recorder.clone()));
        self.arm_wake();
        self.arm_accept();
        let mut cqes: Vec<Cqe> = Vec::with_capacity(CQ_ENTRIES as usize);
        'events: loop {
            let now = Instant::now();
            let mut timeout = self.wheel.next_timeout_ms(now);
            if self.draining {
                timeout = if timeout < 0 { DRAIN_POLL_MS } else { timeout.min(DRAIN_POLL_MS) };
            }
            let wait = if timeout < 0 { None } else { Some(Duration::from_millis(timeout as u64)) };
            if let Err(e) = self.ring.submit_and_wait(1, wait) {
                crate::log_error!("uring", "uring loop failed: {e}");
                break 'events;
            }
            if self.stop.load(Ordering::SeqCst) {
                break 'events;
            }
            if !self.draining && self.drain.load(Ordering::SeqCst) {
                self.begin_drain();
            }
            cqes.clear();
            self.ring.reap(&mut cqes);
            for cqe in cqes.drain(..) {
                self.handle_cqe(cqe);
            }
            // Belt and braces: a worker may have pushed between the
            // wake completing and this pass; the queue take is cheap.
            self.drain_completions();
            if self.accept_rearm_pending {
                self.accept_rearm_pending = false;
                if !self.draining && !self.accept_armed {
                    self.arm_accept();
                }
            }
            self.service_timers();
            if self.draining {
                if self.drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    for idx in 0..self.conns.len() {
                        if self.conns[idx].is_some() {
                            self.close(idx);
                        }
                    }
                }
                if self.conns.iter().all(|c| c.is_none()) {
                    break 'events;
                }
            }
        }
        self.teardown();
    }

    fn handle_cqe(&mut self, cqe: Cqe) {
        let (op, page, epoch, idx) = utoken_parts(cqe.user_data);
        match op {
            OP_WAKE => self.on_wake(),
            OP_ACCEPT => self.on_accept(cqe),
            OP_READ => self.on_read(idx, epoch, page, cqe.res),
            OP_WRITE => self.on_write(idx, epoch, cqe.res),
            // The cancel op's own completion carries nothing to do:
            // the *cancelled* op completes separately with -ECANCELED.
            _ => {}
        }
    }

    fn on_wake(&mut self) {
        self.wake_armed = false;
        // The 8-byte READ consumed the eventfd counter; drain() covers
        // the race where a signal lands after the read completed but
        // before re-arming (the counter would otherwise satisfy the
        // next READ instantly, which is harmless but noisy).
        self.wake.drain();
        self.drain_completions();
        if !self.shutting {
            self.arm_wake();
        }
    }

    fn arm_wake(&mut self) {
        if self.wake_armed {
            return;
        }
        let buf: *mut u8 = (&mut *self.wake_buf as *mut u64).cast();
        if self.ring.push(Sqe::read(self.wake.raw(), buf, 8, WAKE_TOKEN)).is_ok() {
            self.wake_armed = true;
        }
    }

    fn arm_accept(&mut self) {
        let Some(listener) = self.listener.as_ref() else { return };
        let sqe = Sqe::accept(listener.as_raw_fd(), self.multishot, ACCEPT_TOKEN);
        if self.ring.push(sqe).is_ok() {
            self.accept_armed = true;
        }
    }

    fn on_accept(&mut self, cqe: Cqe) {
        if cqe.flags & IORING_CQE_F_MORE == 0 {
            // Single-shot, or a multishot run ending: the SQE is gone.
            self.accept_armed = false;
        }
        if cqe.res < 0 {
            let err = -cqe.res;
            if self.multishot && err == EINVAL {
                // Pre-5.19 kernel: multishot accept unsupported. Fall
                // back to re-armed single-shot for the shard's life.
                self.multishot = false;
                self.accept_errors = 0;
                if !self.draining && !self.shutting {
                    self.arm_accept();
                }
                return;
            }
            if self.draining || self.shutting || err == ECANCELED {
                return;
            }
            // Transient (ECONNABORTED, EINTR) or hard (EMFILE) — both
            // need a re-arm, but an error storm is paced to one re-arm
            // per loop pass so the shard cannot spin on accept errors.
            self.accept_errors += 1;
            if self.accept_errors > 64 {
                self.accept_rearm_pending = true;
                self.accept_errors = 0;
            } else if !self.accept_armed {
                self.arm_accept();
            }
            return;
        }
        self.accept_errors = 0;
        // Own the fd immediately so every exit path below closes it.
        let stream = unsafe { TcpStream::from_raw_fd(cqe.res) };
        if self.draining || self.shutting {
            drop(stream);
            return;
        }
        self.admit(stream);
        if !self.accept_armed {
            self.arm_accept();
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        let Some(permit) = self.limiter.try_acquire() else {
            Metrics::inc(&self.metrics.conns_refused, 1);
            match self.protocol {
                Protocol::Native => refuse_busy(stream, &self.limiter),
                Protocol::Http => refuse_busy_http(stream, &self.limiter),
            }
            return;
        };
        // No set_nonblocking: uring ops never block the submitter, and
        // socket ops poll internally regardless of the fd's flags.
        stream.set_nodelay(true).ok();
        let idx = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.epochs.push(0);
            self.conns.len() - 1
        });
        let epoch = self.epochs[idx];
        let machine = match self.protocol {
            Protocol::Native => Machine::Native(FrameMachine::new(self.pool.get())),
            Protocol::Http => Machine::Http(Box::new(HttpMachine::new(
                self.pool.get(),
                self.rate.clone(),
                peer_ip(&stream),
            ))),
        };
        let conn = Conn::new(stream, epoch, self.max_streams, &mut self.pool, permit, machine);
        Metrics::inc(&self.metrics.conns_accepted, 1);
        Metrics::inc(&self.metrics.conns_open, 1);
        Metrics::inc(&self.shard.conns_accepted, 1);
        Metrics::inc(&self.shard.conns_open, 1);
        self.recorder.record(
            EventKind::Accept,
            token(idx, epoch),
            self.shard.conns_open.load(Ordering::Relaxed),
        );
        self.conns[idx] = Some(UConn {
            conn,
            read_inflight: false,
            read_page: 0,
            read_waiting: false,
            write_inflight: false,
            wbuf: None,
            wpos: 0,
            closing: false,
        });
        self.reschedule(idx, Instant::now());
        self.advance(idx);
    }

    /// Drive one connection as far as completions allow: parse what the
    /// last read delivered, dispatch if idle, keep a write and a read
    /// armed, and close once a finished peer is fully answered. The
    /// epoll `pump` loops against the socket; here each stage runs once
    /// per completion — the next CQE re-enters.
    fn advance(&mut self, idx: usize) {
        let now = Instant::now();
        let mut send_failed = false;
        {
            let Some(uc) = self.conns[idx].as_mut() else { return };
            if uc.closing {
                return;
            }
            // 1. Peel complete frames into the inbox.
            if !uc.conn.corrupt && !self.draining {
                match uc.conn.parse_into_inbox() {
                    Ok(parsed) => {
                        if parsed > 0 {
                            Metrics::inc(&self.metrics.frames_in, parsed as u64);
                            Metrics::inc(&self.shard.frames_in, parsed as u64);
                            self.recorder.record(
                                EventKind::Frame,
                                token(idx, uc.conn.epoch),
                                parsed as u64,
                            );
                        }
                        // Frame-granularity read-stall clock, exactly as
                        // in the epoll loop.
                        if uc.conn.machine.buffered() == 0 {
                            uc.conn.frame_start = None;
                        } else if parsed > 0 || uc.conn.frame_start.is_none() {
                            uc.conn.frame_start = Some(now);
                        }
                    }
                    Err(_) => {
                        uc.conn.corrupt = true;
                        uc.conn.eof = true;
                    }
                }
            }
            // 2. Dispatch the next request if none is in flight.
            if !uc.conn.busy {
                if let Some(Inbound { mut job, clock }) = uc.conn.inbox.pop_front() {
                    // Sample the drain flag as the job leaves the
                    // inbox, exactly as in the epoll loop.
                    if let Job::Http(w) = &mut job {
                        w.draining = self.draining;
                    }
                    uc.conn.busy = true;
                    self.recorder
                        .record(EventKind::Dispatch, token(idx, uc.conn.epoch), 0);
                    let pooled = self.zero_copy || uc.conn.is_http();
                    let buf = if pooled { self.pool.get() } else { Vec::new() };
                    let item = WorkItem {
                        token: token(idx, uc.conn.epoch),
                        job,
                        session: uc.conn.session.clone(),
                        done: self.completions.clone(),
                        wake: self.wake.clone(),
                        buf,
                        clock,
                    };
                    if self.work_tx.send(item).is_err() {
                        send_failed = true; // shutting down
                    }
                }
            }
        }
        if send_failed {
            return self.close(idx);
        }
        // 3. Keep the kernel busy.
        self.arm_write(idx);
        self.arm_read(idx);
        // 4. Close a finished peer once fully answered. No in-flight
        //    exemption: close() cancels a read still armed against a
        //    peer that will never send again.
        let finished = {
            let Some(uc) = self.conns[idx].as_ref() else { return };
            (uc.conn.eof || self.draining) && uc.is_drained()
        };
        if finished {
            self.close(idx);
        }
    }

    /// Arm one READ into a free arena page, or queue for a page.
    fn arm_read(&mut self, idx: usize) {
        let (fd, epoch, page) = {
            let Some(uc) = self.conns[idx].as_mut() else { return };
            if uc.closing || uc.read_inflight || uc.read_waiting || self.draining {
                return;
            }
            if !uc.conn.wants_read() || uc.out_pending() >= WRITE_HIGH_WATER {
                return;
            }
            let Some(page) = self.free_pages.pop() else {
                uc.read_waiting = true;
                self.read_waiters.push_back(idx);
                return;
            };
            uc.read_inflight = true;
            uc.read_page = page;
            (uc.conn.stream.as_raw_fd(), uc.conn.epoch, page)
        };
        let buf = unsafe { self.arena.as_mut_ptr().add(page * READ_PAGE) };
        #[allow(unused_mut)]
        let mut len = READ_PAGE as u32;
        #[cfg(feature = "faults")]
        {
            len = crate::net::faults::short_cqe(len);
        }
        let tok = utoken(OP_READ, page, epoch, idx);
        let sqe = if self.fixed {
            Sqe::read_fixed(fd, buf, len, page as u16, tok)
        } else {
            Sqe::read(fd, buf, len, tok)
        };
        if self.ring.push(sqe).is_err() {
            if let Some(uc) = self.conns[idx].as_mut() {
                uc.read_inflight = false;
            }
            self.free_pages.push(page);
            self.close(idx);
        }
    }

    fn on_read(&mut self, idx: usize, epoch: u32, page: usize, res: i32) {
        // A stale completion still owned its page: release it either way.
        if idx >= self.conns.len()
            || (self.epochs[idx] & EPOCH_MASK) != epoch
            || self.conns[idx].is_none()
        {
            self.free_pages.push(page);
            self.wake_read_waiter();
            return;
        }
        let mut must_close = false;
        let mut finishing = false;
        {
            let uc = self.conns[idx].as_mut().expect("checked above");
            uc.read_inflight = false;
            if uc.closing {
                finishing = true;
            } else if res < 0 {
                let err = -res;
                // EAGAIN/EINTR: spurious, advance() re-arms.
                if !(err == EAGAIN || err == EINTR || err == ECANCELED) {
                    must_close = true;
                }
            } else if res == 0 {
                uc.conn.eof = true;
            } else {
                // Copy into the frame accumulator BEFORE the page is
                // released: the free list must never hold a page whose
                // bytes are still unconsumed.
                let n = res as usize;
                let start = page * READ_PAGE;
                Metrics::inc(&self.metrics.net_bytes_in, n as u64);
                uc.conn.machine.push(&self.arena[start..start + n]);
                uc.conn.last_activity = Instant::now();
            }
        }
        self.free_pages.push(page);
        self.wake_read_waiter();
        if finishing {
            return self.maybe_finish_close(idx);
        }
        if must_close {
            return self.close(idx);
        }
        self.advance(idx);
    }

    /// Hand a freed arena page to the longest-waiting connection.
    fn wake_read_waiter(&mut self) {
        while let Some(widx) = self.read_waiters.pop_front() {
            let live = match self.conns[widx].as_mut() {
                Some(uc) if uc.read_waiting => {
                    uc.read_waiting = false;
                    true
                }
                _ => false, // closed (or re-armed) while queued
            };
            if live {
                self.arm_read(widx);
                return;
            }
        }
    }

    /// Arm one WRITE for the connection: continue the in-flight
    /// buffer's remainder, or swap the queue's backlog out whole. One
    /// write in flight per connection preserves wire order (the role
    /// SQE links would otherwise play) and keeps exactly one buffer
    /// pinned.
    fn arm_write(&mut self, idx: usize) {
        let (fd, epoch, ptr, len) = {
            let Some(uc) = self.conns[idx].as_mut() else { return };
            if uc.closing || uc.write_inflight {
                return;
            }
            if uc.wbuf.is_none() {
                if uc.conn.write.pending() == 0 {
                    return;
                }
                // The pooled replacement becomes the live queue buffer;
                // the swapped-out buffer returns to the pool when its
                // last byte is written — the pool stays balanced.
                let replacement = self.pool.get();
                let (buf, pos) = uc.conn.write.take_pending(replacement);
                uc.wbuf = Some(buf);
                uc.wpos = pos;
            }
            let buf = uc.wbuf.as_ref().expect("just installed");
            let remaining = buf.len() - uc.wpos;
            if remaining == 0 {
                let mut b = uc.wbuf.take().expect("checked some");
                b.clear();
                self.pool.put(b);
                uc.wpos = 0;
                return;
            }
            uc.write_inflight = true;
            (
                uc.conn.stream.as_raw_fd(),
                uc.conn.epoch,
                buf[uc.wpos..].as_ptr(),
                remaining.min(1 << 30) as u32,
            )
        };
        let tok = utoken(OP_WRITE, 0, epoch, idx);
        if self.ring.push(Sqe::write(fd, ptr, len, tok)).is_err() {
            if let Some(uc) = self.conns[idx].as_mut() {
                uc.write_inflight = false;
            }
            self.close(idx);
        }
    }

    fn on_write(&mut self, idx: usize, epoch: u32, res: i32) {
        if idx >= self.conns.len()
            || (self.epochs[idx] & EPOCH_MASK) != epoch
            || self.conns[idx].is_none()
        {
            return;
        }
        let mut must_close = false;
        let mut finishing = false;
        {
            let uc = self.conns[idx].as_mut().expect("checked above");
            uc.write_inflight = false;
            if uc.closing {
                finishing = true;
            } else if res < 0 {
                let err = -res;
                if !(err == EAGAIN || err == EINTR || err == ECANCELED) {
                    must_close = true;
                }
            } else if res == 0 {
                must_close = true; // zero-length write: peer is gone
            } else {
                let n = res as usize;
                let now = Instant::now();
                Metrics::inc(&self.metrics.net_bytes_out, n as u64);
                uc.wpos += n;
                uc.conn.last_activity = now;
                uc.conn.write_progress = now;
                // The async write landed: advance the queue's written
                // total and close out any clocks it released.
                uc.conn.write.note_written(n as u64);
                for clock in uc.conn.write.take_flushed() {
                    self.recorder.record(
                        EventKind::Reply,
                        token(idx, uc.conn.epoch),
                        clock.total_us_now(),
                    );
                    self.metrics.record_clock_flush(&clock, "uring");
                }
                if uc.wbuf.as_ref().is_some_and(|b| uc.wpos >= b.len()) {
                    let mut b = uc.wbuf.take().expect("checked some");
                    b.clear();
                    self.pool.put(b);
                    uc.wpos = 0;
                }
            }
        }
        if finishing {
            return self.maybe_finish_close(idx);
        }
        if must_close {
            return self.close(idx);
        }
        // Partial writes re-arm in advance(); so does the next backlog.
        self.advance(idx);
    }

    /// Hand completed replies back to their connections. Identical to
    /// the epoll loop's version modulo the slab element type.
    fn drain_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *lock_clean(&self.completions));
        for c in done {
            let (idx, epoch) = token_parts(c.token);
            if idx >= self.conns.len() || self.epochs[idx] != epoch {
                continue; // connection closed while the request ran
            }
            let mut must_close = false;
            {
                let Some(uc) = self.conns[idx].as_mut() else { continue };
                if uc.closing {
                    continue; // reply raced the close; the frame drops
                }
                uc.conn.busy = false;
                uc.conn.last_activity = Instant::now();
                if c.panicked {
                    self.recorder.record(EventKind::Panic, c.token, 0);
                    crate::log_error!("uring", "request handler panicked; closing connection");
                }
                // Queue/kernel/sink durations are final here; the flush
                // stage is recorded when `on_write` releases the clock.
                self.metrics.record_clock_stages(&c.clock);
                match c.frame {
                    Some(frame) if frame.is_empty() => {
                        // Nothing to send (an HTTP stream chunk
                        // swallowed after an error): recycle the sink
                        // buffer, skip the frame counters.
                        self.pool.put(frame);
                        if c.close_after {
                            uc.conn.inbox.clear();
                            uc.conn.corrupt = true;
                            uc.conn.eof = true;
                        }
                    }
                    Some(frame) => {
                        if let Some(status) = http_error_status(&frame) {
                            self.recorder
                                .record(EventKind::HttpError, c.token, status as u64);
                        }
                        let spare = uc.conn.write.adopt(frame);
                        self.pool.put(spare);
                        uc.conn.write.push_clock(c.clock);
                        Metrics::inc(&self.metrics.frames_out, 1);
                        Metrics::inc(&self.shard.frames_out, 1);
                        if c.close_after {
                            uc.conn.inbox.clear();
                            uc.conn.corrupt = true;
                            uc.conn.eof = true;
                        }
                    }
                    None => must_close = true, // unframeable reply
                }
            }
            if must_close {
                self.close(idx);
                continue;
            }
            self.advance(idx);
        }
    }

    fn service_timers(&mut self) {
        let now = Instant::now();
        while let Some(tok) = self.wheel.pop_due(now) {
            let (idx, epoch) = token_parts(tok);
            if idx >= self.conns.len() || self.epochs[idx] != epoch || self.conns[idx].is_none() {
                continue;
            }
            self.check_deadlines(idx, now);
            self.reschedule(idx, now);
        }
    }

    /// The epoll loop's deadline contract on uring state: write-stall
    /// counts the swapped-out buffer, and "drained" means the reply has
    /// fully left the kernel ([`UConn::is_drained`]).
    fn check_deadlines(&mut self, idx: usize, now: Instant) {
        let mut must_close = false;
        let mut poisoned = false;
        {
            let Some(uc) = self.conns[idx].as_mut() else { return };
            if uc.closing {
                return;
            }
            if self.write_timeout != Duration::ZERO
                && uc.out_pending() > 0
                && now >= uc.conn.write_progress + self.write_timeout
            {
                // The peer stopped reading; nothing can be said to it.
                Metrics::inc(&self.metrics.timeouts, 1);
                self.recorder.record(
                    EventKind::Timeout,
                    token(idx, uc.conn.epoch),
                    uc.out_pending() as u64,
                );
                crate::log_debug!("uring", "write-stalled peer closed (pending={})", uc.out_pending());
                must_close = true;
            } else if !(uc.conn.corrupt || uc.conn.eof) {
                let read_stalled = self.read_timeout != Duration::ZERO
                    && uc.is_drained()
                    && uc.conn.frame_start.is_some_and(|t| now >= t + self.read_timeout);
                let idle = self.idle_timeout != Duration::ZERO
                    && uc.is_drained()
                    && uc.conn.frame_start.is_none()
                    && now >= uc.conn.last_activity + self.idle_timeout;
                if read_stalled || idle {
                    Metrics::inc(&self.metrics.timeouts, 1);
                    self.recorder
                        .record(EventKind::Timeout, token(idx, uc.conn.epoch), 0);
                    // Native `0x82` frame vs HTTP `408`, as in the
                    // epoll loop.
                    let frame = if uc.conn.is_http() {
                        Some(timeout_response(if read_stalled {
                            "timeout: request frame stalled"
                        } else {
                            "timeout: idle connection"
                        }))
                    } else if read_stalled {
                        stall_timeout_frame()
                    } else {
                        idle_timeout_frame()
                    };
                    if let Some(frame) = frame {
                        uc.conn.write.push_bytes(&frame);
                        uc.conn.write_progress = now;
                        Metrics::inc(&self.metrics.frames_out, 1);
                        Metrics::inc(&self.shard.frames_out, 1);
                    }
                    uc.conn.corrupt = true;
                    uc.conn.eof = true;
                    poisoned = true;
                }
            }
        }
        if must_close {
            return self.close(idx);
        }
        if poisoned {
            // Flush the notice; close() (via advance) then cancels the
            // read still armed against the quiet peer.
            self.advance(idx);
        }
    }

    fn reschedule(&mut self, idx: usize, now: Instant) {
        if self.idle_timeout == Duration::ZERO
            && self.read_timeout == Duration::ZERO
            && self.write_timeout == Duration::ZERO
        {
            return;
        }
        let Some(uc) = self.conns[idx].as_ref() else { return };
        if uc.closing {
            return;
        }
        let mut next = now + HEARTBEAT;
        if self.write_timeout != Duration::ZERO && uc.out_pending() > 0 {
            next = next.min(uc.conn.write_progress + self.write_timeout);
        }
        if self.read_timeout != Duration::ZERO && uc.is_drained() {
            if let Some(t) = uc.conn.frame_start {
                next = next.min(t + self.read_timeout);
            }
        }
        if self.idle_timeout != Duration::ZERO && uc.is_drained() && uc.conn.frame_start.is_none()
        {
            next = next.min(uc.conn.last_activity + self.idle_timeout);
        }
        let next = next.max(now + Duration::from_millis(1));
        self.wheel.schedule(next, token(idx, uc.conn.epoch));
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_deadline = Some(Instant::now() + self.drain_grace);
        let open = self.conns.iter().filter(|c| c.is_some()).count() as u64;
        self.recorder.record(EventKind::Drain, 0, open);
        crate::log_info!(
            "uring",
            "shard {} draining ({open} connections open)",
            self.recorder.label()
        );
        if self.accept_armed {
            let _ = self.ring.push(Sqe::cancel(ACCEPT_TOKEN, CANCEL_TOKEN));
            self.accept_armed = false;
        }
        // Closing the listener fd does NOT cancel its armed op (the op
        // holds a file reference) — hence the explicit cancel above.
        self.listener = None;
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                self.advance(idx); // answer the accepted, close the done
            }
        }
    }

    /// Initiate a close: cancel in-flight ops and mark the slot; the
    /// slot is vacated by [`ULoop::maybe_finish_close`] once the kernel
    /// has let go of every buffer it was handed.
    fn close(&mut self, idx: usize) {
        let mut cancels: [Option<u64>; 2] = [None, None];
        {
            let Some(uc) = self.conns[idx].as_mut() else { return };
            if uc.closing {
                return;
            }
            uc.closing = true;
            uc.read_waiting = false; // any waiter-queue entry goes stale
            let epoch = uc.conn.epoch;
            if uc.read_inflight {
                cancels[0] = Some(utoken(OP_READ, uc.read_page, epoch, idx));
            }
            if uc.write_inflight {
                cancels[1] = Some(utoken(OP_WRITE, 0, epoch, idx));
            }
        }
        for target in cancels.into_iter().flatten() {
            let _ = self.ring.push(Sqe::cancel(target, CANCEL_TOKEN));
        }
        self.maybe_finish_close(idx);
    }

    /// Vacate a closing slot once no kernel op references its buffers.
    /// Only now does the epoch advance — earlier, and the in-flight
    /// completions this close is waiting for would look stale.
    fn maybe_finish_close(&mut self, idx: usize) {
        let ready = self.conns[idx]
            .as_ref()
            .is_some_and(|uc| uc.closing && !uc.read_inflight && !uc.write_inflight);
        if !ready {
            return;
        }
        let uc = self.conns[idx].take().expect("checked above");
        self.epochs[idx] = self.epochs[idx].wrapping_add(1);
        if let Some(mut b) = uc.wbuf {
            b.clear();
            self.pool.put(b);
        }
        uc.conn.teardown(&mut self.pool);
        self.free.push(idx);
        Metrics::dec(&self.metrics.conns_open, 1);
        Metrics::dec(&self.shard.conns_open, 1);
    }

    /// Final teardown: initiate every close, cancel the service ops,
    /// and reap until the kernel has released every borrowed buffer.
    /// If ops are still stuck at the deadline the buffers are leaked —
    /// an unregistered read landing in freed heap memory would be
    /// undefined behaviour, a leak is just a leak.
    fn teardown(&mut self) {
        self.shutting = true;
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                self.close(idx);
            }
        }
        if self.accept_armed {
            let _ = self.ring.push(Sqe::cancel(ACCEPT_TOKEN, CANCEL_TOKEN));
            self.accept_armed = false;
        }
        if self.wake_armed {
            let _ = self.ring.push(Sqe::cancel(WAKE_TOKEN, CANCEL_TOKEN));
        }
        let deadline = Instant::now() + Duration::from_secs(1);
        let mut cqes: Vec<Cqe> = Vec::new();
        while !(self.conns.iter().all(|c| c.is_none()) && !self.wake_armed) {
            if Instant::now() >= deadline {
                break;
            }
            if self.ring.submit_and_wait(1, Some(Duration::from_millis(50))).is_err() {
                break;
            }
            cqes.clear();
            self.ring.reap(&mut cqes);
            for cqe in cqes.drain(..) {
                match utoken_parts(cqe.user_data).0 {
                    OP_WAKE => self.wake_armed = false,
                    OP_READ | OP_WRITE => self.handle_cqe(cqe),
                    _ => {}
                }
            }
        }
        if !(self.conns.iter().all(|c| c.is_none()) && !self.wake_armed) {
            crate::log_warn!(
                "uring",
                "shard exiting with ops still in flight; leaking their buffers"
            );
            std::mem::forget(std::mem::take(&mut self.arena));
            std::mem::forget(std::mem::take(&mut self.conns));
            let stuck = std::mem::replace(&mut self.wake_buf, Box::new(0));
            std::mem::forget(stuck);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utoken_round_trips_every_field() {
        let tok = utoken(OP_READ, 0xABC, 0x1ABC_DEF0 & EPOCH_MASK, 0xF_1234);
        assert_eq!(utoken_parts(tok), (OP_READ, 0xABC, 0x1ABC_DEF0 & EPOCH_MASK, 0xF_1234));
        let tok = utoken(OP_CANCEL, 0, 0, 0);
        assert_eq!(tok, CANCEL_TOKEN);
        assert_eq!(utoken_parts(ACCEPT_TOKEN).0, OP_ACCEPT);
        assert_eq!(utoken_parts(WAKE_TOKEN).0, OP_WAKE);
    }

    #[test]
    fn utoken_epoch_truncation_is_masked_consistently() {
        // A slot epoch above 29 bits must compare equal through the
        // token round trip when masked the way the CQE handlers do.
        let epoch: u32 = 0xDEAD_BEEF;
        let tok = utoken(OP_WRITE, 0, epoch, 7);
        let (_, _, tok_epoch, idx) = utoken_parts(tok);
        assert_eq!(tok_epoch, epoch & EPOCH_MASK);
        assert_eq!(idx, 7);
    }
}
