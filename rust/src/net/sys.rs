//! Thin Linux syscall layer: `epoll`, `eventfd` and `SO_REUSEPORT`
//! listener groups via direct `extern "C"` bindings (std already links
//! libc — no crates).
//!
//! Only what the sharded readiness loops need is bound:
//! `epoll_create1` / `epoll_ctl` / `epoll_wait`, `eventfd` plus its
//! 8-byte counter read/write, `socket`/`setsockopt`/`bind`/`listen` so
//! a reactor group can share one port with `SO_REUSEPORT` (the kernel
//! then spreads incoming connections across the group's listeners),
//! and `setrlimit` so the load generator can lift the default 1024-fd
//! soft limit before opening thousands of sockets. Everything unsafe is
//! confined to this module; the wrappers above the FFI boundary
//! ([`Epoll`], [`EventFd`], [`reuseport_group`]) expose owned-fd APIs
//! with `io::Result` errors and close-on-drop semantics.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{FromRawFd, RawFd};

// ---------------------------------------------------------------------
// FFI surface (see `man epoll_ctl`, `man eventfd`, `man setrlimit`).
// ---------------------------------------------------------------------

/// One readiness record. On x86-64 the kernel ABI packs the 12-byte
/// struct (u32 events + u64 data with no padding); other architectures
/// use natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    /// Readiness mask (`EPOLLIN` / `EPOLLOUT` / …).
    pub events: u32,
    /// The token registered with [`Epoll::add`].
    pub data: u64,
}

/// One readiness record (naturally aligned ABI, non-x86-64).
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    /// Readiness mask (`EPOLLIN` / `EPOLLOUT` / …).
    pub events: u32,
    /// The token registered with [`Epoll::add`].
    pub data: u64,
}

impl EpollEvent {
    /// An empty record, for pre-sizing `epoll_wait` buffers.
    pub const fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

/// `struct sockaddr_in` (Linux ABI): family, big-endian port, the four
/// address octets in network order, zero padding.
#[repr(C)]
struct SockAddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: [u8; 4],
    sin_zero: [u8; 8],
}

/// `struct sockaddr_in6` (Linux ABI).
#[repr(C)]
struct SockAddrIn6 {
    sin6_family: u16,
    sin6_port: u16,
    sin6_flowinfo: u32,
    sin6_addr: [u8; 16],
    sin6_scope_id: u32,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: u32,
    ) -> c_int;
    fn bind(fd: c_int, addr: *const c_void, addrlen: u32) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
    fn signal(signum: c_int, handler: usize) -> usize;
}

/// Readiness: data to read.
pub const EPOLLIN: u32 = 0x001;
/// Readiness: socket writable again.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported, no need to register).
pub const EPOLLERR: u32 = 0x008;
/// Hang-up (always reported, no need to register).
pub const EPOLLHUP: u32 = 0x010;
/// Peer half-closed its write side.
pub const EPOLLRDHUP: u32 = 0x2000;
/// Edge-triggered delivery.
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;
const RLIMIT_NOFILE: c_int = 7;
const AF_INET: c_int = 2;
const AF_INET6: c_int = 10;
const SOCK_STREAM: c_int = 1;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;
/// Backlog for reuseport listeners (matches std's `TcpListener::bind`).
const LISTEN_BACKLOG: c_int = 128;

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

// ---------------------------------------------------------------------
// Owned-fd wrappers.
// ---------------------------------------------------------------------

/// An owned epoll instance.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    /// Create an epoll instance (`EPOLL_CLOEXEC`).
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with the given interest mask; readiness events carry
    /// `token` back in [`EpollEvent::data`].
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask / token of a registered fd.
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd` (kernels before 2.6.9 demand a non-null event
    /// pointer, which `ctl` already provides).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block for readiness, filling `events`; returns how many fired.
    /// Retries on `EINTR` (real or injected by the `faults` feature);
    /// `timeout_ms < 0` blocks indefinitely.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        #[cfg(feature = "faults")]
        let mut injected_eintr = crate::net::faults::epoll_eintr();
        loop {
            // A simulated signal interruption takes the same retry edge
            // a real EINTR would, proving the loop below.
            #[cfg(feature = "faults")]
            if std::mem::take(&mut injected_eintr) {
                continue;
            }
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// An owned eventfd used as a cross-thread wakeup: workers [`signal`]
/// after pushing a completion, the readiness loop [`drain`]s on the
/// corresponding `EPOLLIN`.
///
/// [`signal`]: EventFd::signal
/// [`drain`]: EventFd::drain
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    /// Create a nonblocking eventfd with a zero counter.
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    /// The raw descriptor, for epoll registration.
    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, waking any epoll watcher. Retries on
    /// `EINTR`: an interrupted-and-dropped signal here would silently
    /// lose a completion wakeup and stall its connection until the next
    /// unrelated event. A full counter (`EAGAIN`) already guarantees a
    /// pending wakeup, so that is the one error safely ignored.
    pub fn signal(&self) {
        let one: u64 = 1;
        loop {
            let n = unsafe { write(self.fd, (&one as *const u64).cast::<c_void>(), 8) };
            if n >= 0 {
                return;
            }
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                return; // EAGAIN: counter saturated, wakeup already pending
            }
        }
    }

    /// Reset the counter. Retries on `EINTR` — a drain dropped to a
    /// signal would leave the counter nonzero with the edge already
    /// consumed, suppressing the next edge-triggered wakeup. `EAGAIN`
    /// (already zero) is fine: a spurious wakeup costs nothing.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        loop {
            let n = unsafe { read(self.fd, (&mut buf as *mut u64).cast::<c_void>(), 8) };
            if n >= 0 {
                return;
            }
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                return;
            }
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;
/// `SIG_ERR` — `signal(2)`'s failure return, `(sighandler_t)-1`.
const SIG_ERR: usize = usize::MAX;

/// Set by [`on_term_signal`]; polled by the serve loop.
static TERM_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// The installed handler. An atomic store is async-signal-safe — no
/// allocation, no locks, no syscalls — so this is the entire handler;
/// the serve loop polls [`term_requested`] and runs the actual graceful
/// drain on a normal thread.
extern "C" fn on_term_signal(_signum: c_int) {
    TERM_REQUESTED.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install the graceful-drain handler for `SIGTERM` and `SIGINT`.
/// Process-global; meant for the `serve` CLI entry point, not the
/// library (tests drive drains through `ServerHandle::shutdown`).
pub fn install_term_handler() -> io::Result<()> {
    for sig in [SIGTERM, SIGINT] {
        let prev = unsafe { signal(sig, on_term_signal as usize) };
        if prev == SIG_ERR {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

/// Whether a termination signal has arrived since
/// [`install_term_handler`].
pub fn term_requested() -> bool {
    TERM_REQUESTED.load(std::sync::atomic::Ordering::SeqCst)
}

/// Raise the soft `RLIMIT_NOFILE` to at least `want` descriptors
/// (clamped to the hard limit). Returns the resulting soft limit. The
/// load generator and soak tests open thousands of sockets from one
/// process; the common 1024-fd default would otherwise fail `connect`
/// long before the server's cap is exercised.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.rlim_cur >= want {
        return Ok(lim.rlim_cur);
    }
    let new = RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(new.rlim_cur)
}

/// Close-on-drop guard for a raw fd mid-construction, so every error
/// path between `socket()` and `TcpListener::from_raw_fd` releases it.
struct OwnedFd(RawFd);

impl Drop for OwnedFd {
    fn drop(&mut self) {
        if self.0 >= 0 {
            unsafe { close(self.0) };
        }
    }
}

/// Bind one listening socket with `SO_REUSEPORT` (and `SO_REUSEADDR`,
/// matching std's listener) set *before* `bind`, which std's
/// `TcpListener::bind` cannot do. Every listener of a reactor group
/// must carry the option or the kernel refuses the shared bind with
/// `EADDRINUSE`.
pub fn listen_reuseport(addr: SocketAddr) -> io::Result<TcpListener> {
    let domain = match addr {
        SocketAddr::V4(_) => AF_INET,
        SocketAddr::V6(_) => AF_INET6,
    };
    let fd = OwnedFd(cvt(unsafe { socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0) })?);
    let one: c_int = 1;
    for opt in [SO_REUSEADDR, SO_REUSEPORT] {
        cvt(unsafe {
            setsockopt(
                fd.0,
                SOL_SOCKET,
                opt,
                (&one as *const c_int).cast::<c_void>(),
                std::mem::size_of::<c_int>() as u32,
            )
        })?;
    }
    match addr {
        SocketAddr::V4(a) => {
            let sa = SockAddrIn {
                sin_family: AF_INET as u16,
                sin_port: a.port().to_be(),
                sin_addr: a.ip().octets(),
                sin_zero: [0; 8],
            };
            cvt(unsafe {
                bind(
                    fd.0,
                    (&sa as *const SockAddrIn).cast::<c_void>(),
                    std::mem::size_of::<SockAddrIn>() as u32,
                )
            })?;
        }
        SocketAddr::V6(a) => {
            let sa = SockAddrIn6 {
                sin6_family: AF_INET6 as u16,
                sin6_port: a.port().to_be(),
                sin6_flowinfo: a.flowinfo(),
                sin6_addr: a.ip().octets(),
                sin6_scope_id: a.scope_id(),
            };
            cvt(unsafe {
                bind(
                    fd.0,
                    (&sa as *const SockAddrIn6).cast::<c_void>(),
                    std::mem::size_of::<SockAddrIn6>() as u32,
                )
            })?;
        }
    }
    cvt(unsafe { listen(fd.0, LISTEN_BACKLOG) })?;
    let listener = unsafe { TcpListener::from_raw_fd(fd.0) };
    std::mem::forget(fd); // ownership transferred to the TcpListener
    Ok(listener)
}

/// Bind `n` `SO_REUSEPORT` listeners sharing one address — one per
/// reactor shard. The first bind resolves a port-0 request to a
/// concrete ephemeral port; the rest join that port. The kernel then
/// hashes incoming connections across the group, which is what lets
/// each shard run its own accept loop with no shared accept lock.
pub fn reuseport_group(addr: SocketAddr, n: usize) -> io::Result<Vec<TcpListener>> {
    let first = listen_reuseport(addr)?;
    let bound = first.local_addr()?;
    let mut group = Vec::with_capacity(n.max(1));
    group.push(first);
    for _ in 1..n {
        group.push(listen_reuseport(bound)?);
    }
    Ok(group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_signal_and_drain() {
        let efd = EventFd::new().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(efd.raw(), EPOLLIN | EPOLLET, 7).unwrap();
        efd.signal();
        efd.signal();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (got_events, got_data) = (events[0].events, events[0].data);
        assert_ne!(got_events & EPOLLIN, 0);
        assert_eq!(got_data, 7);
        efd.drain();
        // Counter reset: no further edge without a new signal.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        efd.signal();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
    }

    #[test]
    fn epoll_reports_socket_readability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP | EPOLLET, 42).unwrap();
        client.write_all(b"ping").unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        let data = events[0].data;
        assert_eq!(data, 42);
        ep.del(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn nofile_limit_is_monotone() {
        let got = raise_nofile_limit(256).unwrap();
        assert!(got >= 256);
    }

    #[test]
    fn reuseport_group_shares_one_port() {
        let group = reuseport_group("127.0.0.1:0".parse().unwrap(), 4).unwrap();
        let addr = group[0].local_addr().unwrap();
        assert_ne!(addr.port(), 0);
        for l in &group {
            assert_eq!(l.local_addr().unwrap(), addr, "all members bind the same port");
            l.set_nonblocking(true).unwrap();
        }
        // The kernel spreads connects across the group; every one must be
        // accepted by *some* member.
        let conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        let mut accepted = 0usize;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while accepted < conns.len() && std::time::Instant::now() < deadline {
            let mut progressed = false;
            for l in &group {
                while l.accept().is_ok() {
                    accepted += 1;
                    progressed = true;
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        assert_eq!(accepted, conns.len(), "every connection reached a group member");
        drop(conns);
    }

    #[test]
    fn reuseport_single_listener_still_accepts() {
        // A group of one degrades to a plain listener.
        let group = reuseport_group("127.0.0.1:0".parse().unwrap(), 1).unwrap();
        assert_eq!(group.len(), 1);
        let addr = group[0].local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = group[0].accept().unwrap();
        client.write_all(b"hi").unwrap();
        let mut buf = [0u8; 2];
        use std::io::Read as _;
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
    }
}
