//! Thin Linux syscall layer: `epoll`, `io_uring`, `eventfd` and
//! `SO_REUSEPORT` listener groups via direct `extern "C"` bindings
//! (std already links libc — no crates).
//!
//! Only what the sharded readiness loops need is bound:
//! `epoll_create1` / `epoll_ctl` / `epoll_wait`, the three `io_uring`
//! syscalls (`io_uring_setup` / `io_uring_enter` /
//! `io_uring_register`) plus the mmap'd submission/completion ring
//! wrappers the uring transport drives, `eventfd` plus its 8-byte
//! counter read/write, `socket`/`setsockopt`/`bind`/`listen` so a
//! reactor group can share one port with `SO_REUSEPORT` (the kernel
//! then spreads incoming connections across the group's listeners),
//! and `setrlimit` so the load generator can lift the default 1024-fd
//! soft limit before opening thousands of sockets. Everything unsafe is
//! confined to this module; the wrappers above the FFI boundary
//! ([`Epoll`], [`IoUring`], [`EventFd`], [`reuseport_group`]) expose
//! owned-fd APIs with `io::Result` errors and close-on-drop semantics.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::os::raw::{c_int, c_long, c_void};
use std::os::unix::io::{FromRawFd, RawFd};
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

// ---------------------------------------------------------------------
// FFI surface (see `man epoll_ctl`, `man eventfd`, `man setrlimit`).
// ---------------------------------------------------------------------

/// One readiness record. On x86-64 the kernel ABI packs the 12-byte
/// struct (u32 events + u64 data with no padding); other architectures
/// use natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    /// Readiness mask (`EPOLLIN` / `EPOLLOUT` / …).
    pub events: u32,
    /// The token registered with [`Epoll::add`].
    pub data: u64,
}

/// One readiness record (naturally aligned ABI, non-x86-64).
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    /// Readiness mask (`EPOLLIN` / `EPOLLOUT` / …).
    pub events: u32,
    /// The token registered with [`Epoll::add`].
    pub data: u64,
}

impl EpollEvent {
    /// An empty record, for pre-sizing `epoll_wait` buffers.
    pub const fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

/// `struct sockaddr_in` (Linux ABI): family, big-endian port, the four
/// address octets in network order, zero padding.
#[repr(C)]
struct SockAddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: [u8; 4],
    sin_zero: [u8; 8],
}

/// `struct sockaddr_in6` (Linux ABI).
#[repr(C)]
struct SockAddrIn6 {
    sin6_family: u16,
    sin6_port: u16,
    sin6_flowinfo: u32,
    sin6_addr: [u8; 16],
    sin6_scope_id: u32,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: u32,
    ) -> c_int;
    fn bind(fd: c_int, addr: *const c_void, addrlen: u32) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
    fn signal(signum: c_int, handler: usize) -> usize;
}

/// Readiness: data to read.
pub const EPOLLIN: u32 = 0x001;
/// Readiness: socket writable again.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported, no need to register).
pub const EPOLLERR: u32 = 0x008;
/// Hang-up (always reported, no need to register).
pub const EPOLLHUP: u32 = 0x010;
/// Peer half-closed its write side.
pub const EPOLLRDHUP: u32 = 0x2000;
/// Edge-triggered delivery.
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;
const RLIMIT_NOFILE: c_int = 7;
const AF_INET: c_int = 2;
const AF_INET6: c_int = 10;
const SOCK_STREAM: c_int = 1;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;
/// Backlog for reuseport listeners (matches std's `TcpListener::bind`).
const LISTEN_BACKLOG: c_int = 128;

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

// ---------------------------------------------------------------------
// Owned-fd wrappers.
// ---------------------------------------------------------------------

/// An owned epoll instance.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    /// Create an epoll instance (`EPOLL_CLOEXEC`).
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with the given interest mask; readiness events carry
    /// `token` back in [`EpollEvent::data`].
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask / token of a registered fd.
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd` (kernels before 2.6.9 demand a non-null event
    /// pointer, which `ctl` already provides).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block for readiness, filling `events`; returns how many fired.
    /// Retries on `EINTR` (real or injected by the `faults` feature);
    /// `timeout_ms < 0` blocks indefinitely.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        #[cfg(feature = "faults")]
        let mut injected_eintr = crate::net::faults::epoll_eintr();
        loop {
            // A simulated signal interruption takes the same retry edge
            // a real EINTR would, proving the loop below.
            #[cfg(feature = "faults")]
            if std::mem::take(&mut injected_eintr) {
                continue;
            }
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// An owned eventfd used as a cross-thread wakeup: workers [`signal`]
/// after pushing a completion, the readiness loop [`drain`]s on the
/// corresponding `EPOLLIN`.
///
/// [`signal`]: EventFd::signal
/// [`drain`]: EventFd::drain
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    /// Create a nonblocking eventfd with a zero counter.
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    /// The raw descriptor, for epoll registration.
    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, waking any epoll watcher. Retries on
    /// `EINTR`: an interrupted-and-dropped signal here would silently
    /// lose a completion wakeup and stall its connection until the next
    /// unrelated event. A full counter (`EAGAIN`) already guarantees a
    /// pending wakeup, so that is the one error safely ignored.
    pub fn signal(&self) {
        let one: u64 = 1;
        loop {
            let n = unsafe { write(self.fd, (&one as *const u64).cast::<c_void>(), 8) };
            if n >= 0 {
                return;
            }
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                return; // EAGAIN: counter saturated, wakeup already pending
            }
        }
    }

    /// Reset the counter. Retries on `EINTR` — a drain dropped to a
    /// signal would leave the counter nonzero with the edge already
    /// consumed, suppressing the next edge-triggered wakeup. `EAGAIN`
    /// (already zero) is fine: a spurious wakeup costs nothing.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        loop {
            let n = unsafe { read(self.fd, (&mut buf as *mut u64).cast::<c_void>(), 8) };
            if n >= 0 {
                return;
            }
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                return;
            }
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;
/// `SIG_ERR` — `signal(2)`'s failure return, `(sighandler_t)-1`.
const SIG_ERR: usize = usize::MAX;

/// Set by [`on_term_signal`]; polled by the serve loop.
static TERM_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// The installed handler. An atomic store is async-signal-safe — no
/// allocation, no locks, no syscalls — so this is the entire handler;
/// the serve loop polls [`term_requested`] and runs the actual graceful
/// drain on a normal thread.
extern "C" fn on_term_signal(_signum: c_int) {
    TERM_REQUESTED.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install the graceful-drain handler for `SIGTERM` and `SIGINT`.
/// Process-global; meant for the `serve` CLI entry point, not the
/// library (tests drive drains through `ServerHandle::shutdown`).
pub fn install_term_handler() -> io::Result<()> {
    for sig in [SIGTERM, SIGINT] {
        let prev = unsafe { signal(sig, on_term_signal as usize) };
        if prev == SIG_ERR {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

/// Whether a termination signal has arrived since
/// [`install_term_handler`].
pub fn term_requested() -> bool {
    TERM_REQUESTED.load(std::sync::atomic::Ordering::SeqCst)
}

const SIGUSR1: c_int = 10;

/// Set by [`on_usr1_signal`]; taken (cleared) by [`usr1_requested`].
static USR1_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// `SIGUSR1` handler — same async-signal-safe atomic-store-only shape
/// as [`on_term_signal`]; the serve loop polls and does the work.
extern "C" fn on_usr1_signal(_signum: c_int) {
    USR1_REQUESTED.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install the `SIGUSR1` handler used by `b64simd serve` to dump the
/// flight-recorder rings to stderr on demand. Process-global, CLI-only,
/// like [`install_term_handler`].
pub fn install_usr1_handler() -> io::Result<()> {
    let prev = unsafe { signal(SIGUSR1, on_usr1_signal as usize) };
    if prev == SIG_ERR {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Take (and clear) the pending `SIGUSR1` flag, so each signal produces
/// exactly one trace dump.
pub fn usr1_requested() -> bool {
    USR1_REQUESTED.swap(false, std::sync::atomic::Ordering::SeqCst)
}

/// Raise the soft `RLIMIT_NOFILE` to at least `want` descriptors
/// (clamped to the hard limit). Returns the resulting soft limit. The
/// load generator and soak tests open thousands of sockets from one
/// process; the common 1024-fd default would otherwise fail `connect`
/// long before the server's cap is exercised.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.rlim_cur >= want {
        return Ok(lim.rlim_cur);
    }
    let new = RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(new.rlim_cur)
}

/// Close-on-drop guard for a raw fd mid-construction, so every error
/// path between `socket()` and `TcpListener::from_raw_fd` releases it.
struct OwnedFd(RawFd);

impl Drop for OwnedFd {
    fn drop(&mut self) {
        if self.0 >= 0 {
            unsafe { close(self.0) };
        }
    }
}

/// Bind one listening socket with `SO_REUSEPORT` (and `SO_REUSEADDR`,
/// matching std's listener) set *before* `bind`, which std's
/// `TcpListener::bind` cannot do. Every listener of a reactor group
/// must carry the option or the kernel refuses the shared bind with
/// `EADDRINUSE`.
pub fn listen_reuseport(addr: SocketAddr) -> io::Result<TcpListener> {
    let domain = match addr {
        SocketAddr::V4(_) => AF_INET,
        SocketAddr::V6(_) => AF_INET6,
    };
    let fd = OwnedFd(cvt(unsafe { socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0) })?);
    let one: c_int = 1;
    for opt in [SO_REUSEADDR, SO_REUSEPORT] {
        cvt(unsafe {
            setsockopt(
                fd.0,
                SOL_SOCKET,
                opt,
                (&one as *const c_int).cast::<c_void>(),
                std::mem::size_of::<c_int>() as u32,
            )
        })?;
    }
    match addr {
        SocketAddr::V4(a) => {
            let sa = SockAddrIn {
                sin_family: AF_INET as u16,
                sin_port: a.port().to_be(),
                sin_addr: a.ip().octets(),
                sin_zero: [0; 8],
            };
            cvt(unsafe {
                bind(
                    fd.0,
                    (&sa as *const SockAddrIn).cast::<c_void>(),
                    std::mem::size_of::<SockAddrIn>() as u32,
                )
            })?;
        }
        SocketAddr::V6(a) => {
            let sa = SockAddrIn6 {
                sin6_family: AF_INET6 as u16,
                sin6_port: a.port().to_be(),
                sin6_flowinfo: a.flowinfo(),
                sin6_addr: a.ip().octets(),
                sin6_scope_id: a.scope_id(),
            };
            cvt(unsafe {
                bind(
                    fd.0,
                    (&sa as *const SockAddrIn6).cast::<c_void>(),
                    std::mem::size_of::<SockAddrIn6>() as u32,
                )
            })?;
        }
    }
    cvt(unsafe { listen(fd.0, LISTEN_BACKLOG) })?;
    let listener = unsafe { TcpListener::from_raw_fd(fd.0) };
    std::mem::forget(fd); // ownership transferred to the TcpListener
    Ok(listener)
}

/// Bind `n` `SO_REUSEPORT` listeners sharing one address — one per
/// reactor shard. The first bind resolves a port-0 request to a
/// concrete ephemeral port; the rest join that port. The kernel then
/// hashes incoming connections across the group, which is what lets
/// each shard run its own accept loop with no shared accept lock.
pub fn reuseport_group(addr: SocketAddr, n: usize) -> io::Result<Vec<TcpListener>> {
    let first = listen_reuseport(addr)?;
    let bound = first.local_addr()?;
    let mut group = Vec::with_capacity(n.max(1));
    group.push(first);
    for _ in 1..n {
        group.push(listen_reuseport(bound)?);
    }
    Ok(group)
}

// ---------------------------------------------------------------------
// io_uring: submission/completion rings via direct syscalls (see
// `man io_uring_setup`, `man io_uring_enter`, `man io_uring_register`).
// ---------------------------------------------------------------------

// The io_uring syscall numbers are identical on every architecture
// (Linux unified new syscall numbering from 424 up).
const SYS_IO_URING_SETUP: c_long = 425;
const SYS_IO_URING_ENTER: c_long = 426;
const SYS_IO_URING_REGISTER: c_long = 427;

extern "C" {
    fn syscall(num: c_long, ...) -> c_long;
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, len: usize) -> c_int;
}

const PROT_READ: c_int = 0x1;
const PROT_WRITE: c_int = 0x2;
const MAP_SHARED: c_int = 0x01;
/// Pre-fault the ring pages: the loop touches them on every submission.
const MAP_POPULATE: c_int = 0x8000;

/// mmap offsets selecting which ring a mapping covers.
const IORING_OFF_SQ_RING: u64 = 0;
const IORING_OFF_CQ_RING: u64 = 0x800_0000;
const IORING_OFF_SQES: u64 = 0x1000_0000;

/// `io_uring_params.features`: SQ and CQ rings share one mapping.
const IORING_FEAT_SINGLE_MMAP: u32 = 1 << 0;
/// `io_uring_params.features`: overflowed CQEs are buffered, not lost.
const IORING_FEAT_NODROP: u32 = 1 << 1;
/// `io_uring_params.features`: `io_uring_enter` accepts the extended
/// wait argument (timed waits without TIMEOUT SQEs) — Linux 5.11, the
/// kernel floor [`uring_supported`] enforces.
const IORING_FEAT_EXT_ARG: u32 = 1 << 8;

/// `io_uring_setup` flag: honour `io_uring_params.cq_entries`.
const IORING_SETUP_CQSIZE: u32 = 1 << 3;

/// `io_uring_enter` flag: wait for `min_complete` completions.
const IORING_ENTER_GETEVENTS: u32 = 1 << 0;
/// `io_uring_enter` flag: `arg` is an [`EnterArg`], not a sigset.
const IORING_ENTER_EXT_ARG: u32 = 1 << 3;

const IORING_REGISTER_BUFFERS: u32 = 0;

// Opcodes (from `io_uring_sqe.opcode`); all are ≤ 5.6 additions, well
// inside the 5.11 floor.
const IORING_OP_NOP: u8 = 0;
const IORING_OP_READ_FIXED: u8 = 4;
const IORING_OP_ACCEPT: u8 = 13;
const IORING_OP_ASYNC_CANCEL: u8 = 14;
const IORING_OP_READ: u8 = 22;
const IORING_OP_WRITE: u8 = 23;

/// `io_uring_sqe.ioprio` flag on ACCEPT: keep the SQE armed, posting
/// one CQE per accepted connection (5.19+; older kernels complete the
/// SQE with `-EINVAL` and the uring loop falls back to re-armed
/// single-shot accepts).
const IORING_ACCEPT_MULTISHOT: u16 = 1 << 0;

/// CQE flag: more completions follow from the same (multishot) SQE.
pub const IORING_CQE_F_MORE: u32 = 1 << 1;

// Raw errno values the uring loop dispatches on (io::ErrorKind has no
// stable mapping for several of these).
/// `ETIME`: the `io_uring_enter` wait timeout elapsed.
const ETIME: i32 = 62;
/// `EBUSY`: completions must be reaped before more submissions.
const EBUSY: i32 = 16;
/// `ECANCELED`: an in-flight op was cancelled (`IORING_OP_ASYNC_CANCEL`).
pub const ECANCELED: i32 = 125;
/// `EINVAL`: the kernel rejected an SQE field (e.g. multishot accept
/// on a pre-5.19 kernel).
pub const EINVAL: i32 = 22;

/// `struct io_sqring_offsets` (kernel ABI).
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct SqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    flags: u32,
    dropped: u32,
    array: u32,
    resv1: u32,
    user_addr: u64,
}

/// `struct io_cqring_offsets` (kernel ABI).
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct CqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    overflow: u32,
    cqes: u32,
    flags: u32,
    resv1: u32,
    user_addr: u64,
}

/// `struct io_uring_params` (120 bytes): inputs to `io_uring_setup`,
/// ring geometry and feature flags back from the kernel.
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct UringParams {
    sq_entries: u32,
    cq_entries: u32,
    flags: u32,
    sq_thread_cpu: u32,
    sq_thread_idle: u32,
    features: u32,
    wq_fd: u32,
    resv: [u32; 3],
    sq_off: SqringOffsets,
    cq_off: CqringOffsets,
}

/// One submission queue entry (`struct io_uring_sqe`, 64 bytes, the
/// kernel's unions flattened to the fields this transport uses).
#[repr(C)]
#[derive(Clone, Copy)]
pub struct Sqe {
    opcode: u8,
    flags: u8,
    ioprio: u16,
    fd: i32,
    off: u64,
    addr: u64,
    len: u32,
    op_flags: u32,
    user_data: u64,
    buf_index: u16,
    personality: u16,
    splice_fd_in: i32,
    addr3: u64,
    pad2: u64,
}

impl Sqe {
    const fn zeroed() -> Sqe {
        Sqe {
            opcode: 0,
            flags: 0,
            ioprio: 0,
            fd: -1,
            off: 0,
            addr: 0,
            len: 0,
            op_flags: 0,
            user_data: 0,
            buf_index: 0,
            personality: 0,
            splice_fd_in: 0,
            addr3: 0,
            pad2: 0,
        }
    }

    /// No-op: completes immediately (the probe's round trip).
    pub fn nop(user_data: u64) -> Sqe {
        Sqe { opcode: IORING_OP_NOP, user_data, ..Sqe::zeroed() }
    }

    /// Accept on a listening socket (`SOCK_CLOEXEC`); `multishot` keeps
    /// the SQE armed across connections (5.19+), completing with
    /// [`IORING_CQE_F_MORE`] while it stays armed.
    pub fn accept(fd: RawFd, multishot: bool, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_ACCEPT,
            fd,
            ioprio: if multishot { IORING_ACCEPT_MULTISHOT } else { 0 },
            op_flags: SOCK_CLOEXEC as u32,
            user_data,
            ..Sqe::zeroed()
        }
    }

    /// Read up to `len` bytes into `buf`.
    ///
    /// # Safety contract (upheld by the caller)
    /// `buf..buf+len` must stay valid — neither freed nor reallocated —
    /// until this op's CQE is reaped.
    pub fn read(fd: RawFd, buf: *mut u8, len: u32, user_data: u64) -> Sqe {
        Sqe { opcode: IORING_OP_READ, fd, addr: buf as u64, len, user_data, ..Sqe::zeroed() }
    }

    /// [`Sqe::read`] against a buffer registered with
    /// [`IoUring::register_buffers`]: `buf..buf+len` must lie inside
    /// registered buffer `buf_index`, whose pages the kernel holds
    /// pinned — no per-op page mapping.
    pub fn read_fixed(fd: RawFd, buf: *mut u8, len: u32, buf_index: u16, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_READ_FIXED,
            fd,
            addr: buf as u64,
            len,
            buf_index,
            user_data,
            ..Sqe::zeroed()
        }
    }

    /// Write `len` bytes from `buf`; same buffer-stability contract as
    /// [`Sqe::read`].
    pub fn write(fd: RawFd, buf: *const u8, len: u32, user_data: u64) -> Sqe {
        Sqe { opcode: IORING_OP_WRITE, fd, addr: buf as u64, len, user_data, ..Sqe::zeroed() }
    }

    /// Cancel the in-flight op whose `user_data` equals `target`; the
    /// cancelled op completes with `-ECANCELED`, this op with `0` /
    /// `-ENOENT` / `-EALREADY`.
    pub fn cancel(target: u64, user_data: u64) -> Sqe {
        Sqe { opcode: IORING_OP_ASYNC_CANCEL, addr: target, user_data, ..Sqe::zeroed() }
    }
}

/// One completion queue entry (`struct io_uring_cqe`, 16 bytes).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct Cqe {
    /// Echo of the submission's `user_data` token.
    pub user_data: u64,
    /// Op result: the syscall-convention return value (bytes / fd / 0),
    /// negated errno on failure.
    pub res: i32,
    /// Completion flags ([`IORING_CQE_F_MORE`] is the one this
    /// transport reads).
    pub flags: u32,
}

/// `struct io_uring_getevents_arg` for `IORING_ENTER_EXT_ARG` waits.
#[repr(C)]
struct EnterArg {
    sigmask: u64,
    sigmask_sz: u32,
    pad: u32,
    ts: u64,
}

/// `struct __kernel_timespec`.
#[repr(C)]
struct KernelTimespec {
    tv_sec: i64,
    tv_nsec: i64,
}

/// `struct iovec`, for [`IoUring::register_buffers`].
#[repr(C)]
#[derive(Clone, Copy)]
pub struct IoVec {
    /// Buffer start.
    pub base: *mut c_void,
    /// Buffer length in bytes.
    pub len: usize,
}

fn cvt_syscall(ret: c_long) -> io::Result<c_long> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An owned ring mapping (`munmap` on drop).
struct RingMmap {
    ptr: *mut c_void,
    len: usize,
}

impl RingMmap {
    fn map(fd: RawFd, len: usize, offset: u64) -> io::Result<RingMmap> {
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_SHARED | MAP_POPULATE,
                fd,
                offset as i64,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(RingMmap { ptr, len })
    }

    fn base(&self) -> *mut u8 {
        self.ptr.cast()
    }
}

impl Drop for RingMmap {
    fn drop(&mut self) {
        unsafe { munmap(self.ptr, self.len) };
    }
}

/// Kernel-shared ring index, written by exactly one side: `Release` on
/// the writer publishes the entries filled before the bump, `Acquire`
/// on the reader makes them visible.
#[inline]
unsafe fn ring_load(p: *const u32) -> u32 {
    (*p.cast::<AtomicU32>()).load(Ordering::Acquire)
}

#[inline]
unsafe fn ring_store(p: *mut u32, v: u32) {
    (*p.cast::<AtomicU32>()).store(v, Ordering::Release)
}

/// An owned io_uring instance: the ring fd plus its three mmap'd
/// regions (SQ ring header, CQ ring header — shared with the SQ mapping
/// on `IORING_FEAT_SINGLE_MMAP` kernels — and the SQE array).
///
/// Single-consumer by design: one ring per reactor shard, touched only
/// by that shard's loop thread, so the only synchronization needed is
/// the acquire/release pairing with the kernel on the ring indices.
/// Submissions are staged with [`IoUring::push`] and handed to the
/// kernel by [`IoUring::submit`] / [`IoUring::submit_and_wait`] (EINTR
/// is retried, like [`Epoll::wait`]); completions come back through
/// [`IoUring::reap`].
pub struct IoUring {
    fd: RawFd,
    features: u32,
    // Mapping owners (dropped after the fd closes; pointers below
    // borrow from them).
    _sq_mem: RingMmap,
    _cq_mem: Option<RingMmap>,
    _sqe_mem: RingMmap,
    // SQ: kernel consumes at head, we produce at tail.
    sq_head: *const u32,
    sq_tail: *mut u32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    sqes: *mut Sqe,
    /// Local tail: entries staged by `push` but not yet published.
    sq_local_tail: u32,
    /// High-water mark already handed to `io_uring_enter`.
    sq_submitted: u32,
    // CQ: kernel produces at tail, we consume at head.
    cq_head: *mut u32,
    cq_tail: *const u32,
    cq_mask: u32,
    cqes: *const Cqe,
}

// The raw ring pointers pin this to one thread at a time, which is how
// the shard loops use it (each ring is moved into its loop thread).
unsafe impl Send for IoUring {}

impl IoUring {
    /// Create a ring with `sq_entries` submission slots (rounded up to
    /// a power of two by the kernel) and, when `cq_entries > 0`, that
    /// many completion slots (`IORING_SETUP_CQSIZE`) — sized by the
    /// uring transport so every possible in-flight op has a CQ slot.
    pub fn new(sq_entries: u32, cq_entries: u32) -> io::Result<IoUring> {
        let mut p = UringParams::default();
        if cq_entries > 0 {
            p.flags |= IORING_SETUP_CQSIZE;
            p.cq_entries = cq_entries;
        }
        let fd = cvt_syscall(unsafe {
            syscall(SYS_IO_URING_SETUP, sq_entries as usize, &mut p as *mut UringParams as usize)
        })? as RawFd;
        let fd_guard = OwnedFd(fd);

        let sq_ring_len = p.sq_off.array as usize + p.sq_entries as usize * 4;
        let cq_ring_len = p.cq_off.cqes as usize + p.cq_entries as usize * 16;
        let single = p.features & IORING_FEAT_SINGLE_MMAP != 0;
        let sq_mem = RingMmap::map(
            fd,
            if single { sq_ring_len.max(cq_ring_len) } else { sq_ring_len },
            IORING_OFF_SQ_RING,
        )?;
        let cq_mem = if single {
            None
        } else {
            Some(RingMmap::map(fd, cq_ring_len, IORING_OFF_CQ_RING)?)
        };
        let sqe_mem = RingMmap::map(
            fd,
            p.sq_entries as usize * std::mem::size_of::<Sqe>(),
            IORING_OFF_SQES,
        )?;

        let sq = sq_mem.base();
        let cq = cq_mem.as_ref().map_or(sq, RingMmap::base);
        unsafe {
            let tail = *sq.add(p.sq_off.tail as usize).cast::<u32>();
            let ring = IoUring {
                fd,
                features: p.features,
                sq_head: sq.add(p.sq_off.head as usize).cast(),
                sq_tail: sq.add(p.sq_off.tail as usize).cast(),
                sq_mask: *sq.add(p.sq_off.ring_mask as usize).cast::<u32>(),
                sq_entries: p.sq_entries,
                sq_array: sq.add(p.sq_off.array as usize).cast(),
                sqes: sqe_mem.base().cast(),
                sq_local_tail: tail,
                sq_submitted: tail,
                cq_head: cq.add(p.cq_off.head as usize).cast(),
                cq_tail: cq.add(p.cq_off.tail as usize).cast(),
                cq_mask: *cq.add(p.cq_off.ring_mask as usize).cast::<u32>(),
                cqes: cq.add(p.cq_off.cqes as usize).cast(),
                _sq_mem: sq_mem,
                _cq_mem: cq_mem,
                _sqe_mem: sqe_mem,
            };
            std::mem::forget(fd_guard); // ownership moved into the ring
            Ok(ring)
        }
    }

    /// Kernel feature flags reported at setup.
    pub fn features(&self) -> u32 {
        self.features
    }

    /// Stage one SQE. When the submission ring is full the staged
    /// backlog is flushed with [`IoUring::submit`] first (the kernel
    /// consumes SQEs synchronously on enter, freeing every slot), so
    /// a push only fails if that flush does.
    pub fn push(&mut self, sqe: Sqe) -> io::Result<()> {
        let head = unsafe { ring_load(self.sq_head) };
        if self.sq_local_tail.wrapping_sub(head) >= self.sq_entries {
            self.submit()?;
        }
        let slot = self.sq_local_tail & self.sq_mask;
        unsafe {
            *self.sqes.add(slot as usize) = sqe;
            *self.sq_array.add(slot as usize) = slot;
        }
        self.sq_local_tail = self.sq_local_tail.wrapping_add(1);
        Ok(())
    }

    /// Publish staged SQEs and hand them to the kernel without waiting.
    pub fn submit(&mut self) -> io::Result<()> {
        self.enter_staged(0, None)
    }

    /// Publish staged SQEs and wait for `min_complete` completions or
    /// `timeout` (`None` blocks indefinitely — the caller's wheel
    /// decides). Returns normally on an elapsed timeout and on `EBUSY`
    /// (completions pending reap); the caller reaps either way.
    pub fn submit_and_wait(&mut self, min_complete: u32, timeout: Option<Duration>) -> io::Result<()> {
        self.enter_staged(min_complete, Some(timeout))
    }

    /// Common enter path: `wait = None` is submit-only; `Some(timeout)`
    /// adds `GETEVENTS` (+ an `EXT_ARG` timed wait when the timeout is
    /// finite).
    fn enter_staged(
        &mut self,
        min_complete: u32,
        wait: Option<Option<Duration>>,
    ) -> io::Result<()> {
        unsafe { ring_store(self.sq_tail, self.sq_local_tail) };
        let to_submit = self.sq_local_tail.wrapping_sub(self.sq_submitted);
        if wait.is_none() && to_submit == 0 {
            return Ok(());
        }
        let ret = match wait {
            None => self.enter(to_submit, 0, 0, std::ptr::null(), 0),
            Some(None) => {
                self.enter(to_submit, min_complete, IORING_ENTER_GETEVENTS, std::ptr::null(), 0)
            }
            Some(Some(t)) => {
                let ts = KernelTimespec {
                    tv_sec: t.as_secs().min(i64::MAX as u64) as i64,
                    tv_nsec: t.subsec_nanos() as i64,
                };
                let arg = EnterArg {
                    sigmask: 0,
                    sigmask_sz: 8, // _NSIG / 8, ignored with a null sigmask
                    pad: 0,
                    ts: &ts as *const KernelTimespec as u64,
                };
                self.enter(
                    to_submit,
                    min_complete,
                    IORING_ENTER_GETEVENTS | IORING_ENTER_EXT_ARG,
                    (&arg as *const EnterArg).cast(),
                    std::mem::size_of::<EnterArg>(),
                )
            }
        };
        match ret {
            Ok(_) => {
                self.sq_submitted = self.sq_local_tail;
                Ok(())
            }
            // ETIME: the wait elapsed *after* the submission phase
            // consumed the SQEs.
            Err(e) if e.raw_os_error() == Some(ETIME) => {
                self.sq_submitted = self.sq_local_tail;
                Ok(())
            }
            // EBUSY: the kernel wants completions reaped before it
            // takes more submissions; ours stay staged for the retry.
            Err(e) if e.raw_os_error() == Some(EBUSY) => Ok(()),
            Err(e) => Err(e),
        }
    }

    /// `io_uring_enter`, retrying on `EINTR` (real or injected by the
    /// `faults` feature) like [`Epoll::wait`]. A retry after an
    /// interrupted wait resubmits nothing: the first pass already
    /// consumed the staged SQEs.
    fn enter(
        &self,
        to_submit: u32,
        min_complete: u32,
        flags: u32,
        arg: *const c_void,
        argsz: usize,
    ) -> io::Result<u32> {
        #[cfg(feature = "faults")]
        let mut injected_eintr = crate::net::faults::uring_enter_eintr();
        loop {
            #[cfg(feature = "faults")]
            if std::mem::take(&mut injected_eintr) {
                continue;
            }
            let ret = unsafe {
                syscall(
                    SYS_IO_URING_ENTER,
                    self.fd as usize,
                    to_submit as usize,
                    min_complete as usize,
                    flags as usize,
                    arg as usize,
                    argsz,
                )
            };
            if ret >= 0 {
                return Ok(ret as u32);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    /// Drain every available CQE into `out`; returns how many.
    pub fn reap(&mut self, out: &mut Vec<Cqe>) -> usize {
        let mut n = 0usize;
        loop {
            let tail = unsafe { ring_load(self.cq_tail) };
            // Plain read of our own head: the kernel only reads it.
            let mut head = unsafe { *self.cq_head };
            if head == tail {
                return n;
            }
            while head != tail {
                out.push(unsafe { *self.cqes.add((head & self.cq_mask) as usize) });
                head = head.wrapping_add(1);
                n += 1;
            }
            unsafe { ring_store(self.cq_head, head) };
        }
    }

    /// Register `bufs` as the ring's fixed buffers
    /// (`IORING_REGISTER_BUFFERS`): the kernel pins their pages once,
    /// and `READ_FIXED`/`WRITE_FIXED` ops referencing them by index
    /// skip the per-op page lookup. Fails (commonly `ENOMEM` against
    /// `RLIMIT_MEMLOCK`) without affecting normal ops — the uring
    /// transport degrades to plain `READ`.
    pub fn register_buffers(&self, bufs: &[IoVec]) -> io::Result<()> {
        cvt_syscall(unsafe {
            syscall(
                SYS_IO_URING_REGISTER,
                self.fd as usize,
                IORING_REGISTER_BUFFERS as usize,
                bufs.as_ptr() as usize,
                bufs.len(),
            )
        })
        .map(|_| ())
    }
}

impl Drop for IoUring {
    fn drop(&mut self) {
        // Closing the ring fd cancels and reaps in-flight ops
        // kernel-side; the mmaps unmap afterwards (field drop order).
        unsafe { close(self.fd) };
    }
}

/// Typed "kernel lacks io_uring" error: surfaced by `serve` when the
/// uring transport is required but [`uring_supported`] says no; without
/// the requirement flag the server falls back to epoll with a logged
/// notice instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UringUnsupported;

impl std::fmt::Display for UringUnsupported {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kernel lacks io_uring support (io_uring_setup with IORING_FEAT_EXT_ARG, Linux 5.11+)"
        )
    }
}

impl std::error::Error for UringUnsupported {}

/// Whether this kernel can run the uring transport, probed once per
/// process: `io_uring_setup` must succeed, the ring must report
/// `IORING_FEAT_EXT_ARG` (timed waits, Linux 5.11+) and `NODROP`, and
/// a NOP must complete end to end — submission, wait and reap through
/// the real mmap'd rings, so a kernel that allows the syscall but
/// breaks the ring ABI (or a seccomp profile stubbing it out) still
/// probes false.
pub fn uring_supported() -> bool {
    static PROBE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *PROBE.get_or_init(probe_uring)
}

fn probe_uring() -> bool {
    #[cfg(feature = "faults")]
    if crate::net::faults::uring_setup_fail() {
        // Injected at the cached probe, not per setup call: one roll
        // decides for the whole process, so a fault plan yields a
        // deterministic fallback instead of per-shard flakiness.
        crate::log_warn!("sys", "injected uring.setup.fail — reporting io_uring unsupported");
        return false;
    }
    let Ok(mut ring) = IoUring::new(8, 0) else { return false };
    if ring.features() & (IORING_FEAT_EXT_ARG | IORING_FEAT_NODROP)
        != (IORING_FEAT_EXT_ARG | IORING_FEAT_NODROP)
    {
        return false;
    }
    const PROBE_TOKEN: u64 = 0xB64_51D;
    if ring.push(Sqe::nop(PROBE_TOKEN)).is_err() {
        return false;
    }
    if ring.submit_and_wait(1, Some(Duration::from_millis(200))).is_err() {
        return false;
    }
    let mut cqes = Vec::with_capacity(1);
    ring.reap(&mut cqes);
    cqes.iter().any(|c| c.user_data == PROBE_TOKEN)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_signal_and_drain() {
        let efd = EventFd::new().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(efd.raw(), EPOLLIN | EPOLLET, 7).unwrap();
        efd.signal();
        efd.signal();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (got_events, got_data) = (events[0].events, events[0].data);
        assert_ne!(got_events & EPOLLIN, 0);
        assert_eq!(got_data, 7);
        efd.drain();
        // Counter reset: no further edge without a new signal.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        efd.signal();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
    }

    #[test]
    fn epoll_reports_socket_readability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP | EPOLLET, 42).unwrap();
        client.write_all(b"ping").unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        let data = events[0].data;
        assert_eq!(data, 42);
        ep.del(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn nofile_limit_is_monotone() {
        let got = raise_nofile_limit(256).unwrap();
        assert!(got >= 256);
    }

    #[test]
    fn reuseport_group_shares_one_port() {
        let group = reuseport_group("127.0.0.1:0".parse().unwrap(), 4).unwrap();
        let addr = group[0].local_addr().unwrap();
        assert_ne!(addr.port(), 0);
        for l in &group {
            assert_eq!(l.local_addr().unwrap(), addr, "all members bind the same port");
            l.set_nonblocking(true).unwrap();
        }
        // The kernel spreads connects across the group; every one must be
        // accepted by *some* member.
        let conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        let mut accepted = 0usize;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while accepted < conns.len() && std::time::Instant::now() < deadline {
            let mut progressed = false;
            for l in &group {
                while l.accept().is_ok() {
                    accepted += 1;
                    progressed = true;
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        assert_eq!(accepted, conns.len(), "every connection reached a group member");
        drop(conns);
    }

    #[test]
    fn reuseport_single_listener_still_accepts() {
        // A group of one degrades to a plain listener.
        let group = reuseport_group("127.0.0.1:0".parse().unwrap(), 1).unwrap();
        assert_eq!(group.len(), 1);
        let addr = group[0].local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = group[0].accept().unwrap();
        client.write_all(b"hi").unwrap();
        let mut buf = [0u8; 2];
        use std::io::Read as _;
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
    }

    /// Whether the running kernel supports io_uring; uring tests skip
    /// (with a note on stderr) when it does not, rather than failing.
    fn uring_or_skip(test: &str) -> bool {
        if uring_supported() {
            true
        } else {
            eprintln!("note: skipping {test}: kernel lacks io_uring");
            false
        }
    }

    #[test]
    fn uring_probe_is_cached_and_consistent() {
        let first = uring_supported();
        for _ in 0..4 {
            assert_eq!(uring_supported(), first);
        }
    }

    #[test]
    fn uring_nop_round_trip() {
        if !uring_or_skip("uring_nop_round_trip") {
            return;
        }
        let mut ring = IoUring::new(4, 0).unwrap();
        // Push more NOPs than the SQ has slots: push() must flush the
        // staged backlog rather than overwrite live entries.
        for i in 0..9u64 {
            ring.push(Sqe::nop(i)).unwrap();
        }
        let mut cqes = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while cqes.len() < 9 && std::time::Instant::now() < deadline {
            ring.submit_and_wait(1, Some(std::time::Duration::from_millis(100))).unwrap();
            ring.reap(&mut cqes);
        }
        let mut tokens: Vec<u64> = cqes.iter().map(|c| c.user_data).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, (0..9).collect::<Vec<u64>>());
        assert!(cqes.iter().all(|c| c.res == 0));
    }

    #[test]
    fn uring_enter_timeout_elapses() {
        if !uring_or_skip("uring_enter_timeout_elapses") {
            return;
        }
        let mut ring = IoUring::new(4, 0).unwrap();
        let start = std::time::Instant::now();
        // Nothing in flight: the timed wait must return (not hang, not
        // error) once the EXT_ARG timeout fires.
        ring.submit_and_wait(1, Some(std::time::Duration::from_millis(30))).unwrap();
        let waited = start.elapsed();
        assert!(waited >= std::time::Duration::from_millis(20), "waited {waited:?}");
        let mut cqes = Vec::new();
        assert_eq!(ring.reap(&mut cqes), 0);
    }

    #[test]
    fn uring_socket_read_write() {
        if !uring_or_skip("uring_socket_read_write") {
            return;
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();

        let mut ring = IoUring::new(8, 0).unwrap();
        let payload = b"uring-hello";
        ring.push(Sqe::write(server.as_raw_fd(), payload.as_ptr(), payload.len() as u32, 1))
            .unwrap();
        let mut buf = vec![0u8; 64];
        ring.push(Sqe::read(client.as_raw_fd(), buf.as_mut_ptr(), buf.len() as u32, 2)).unwrap();
        let mut cqes = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while cqes.len() < 2 && std::time::Instant::now() < deadline {
            ring.submit_and_wait(1, Some(std::time::Duration::from_millis(100))).unwrap();
            ring.reap(&mut cqes);
        }
        let wrote = cqes.iter().find(|c| c.user_data == 1).expect("write CQE");
        let read = cqes.iter().find(|c| c.user_data == 2).expect("read CQE");
        assert_eq!(wrote.res as usize, payload.len());
        assert_eq!(read.res as usize, payload.len());
        assert_eq!(&buf[..payload.len()], payload);
    }

    #[test]
    fn uring_registered_buffer_read() {
        if !uring_or_skip("uring_registered_buffer_read") {
            return;
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();

        let mut ring = IoUring::new(8, 0).unwrap();
        let mut arena = vec![0u8; 4096];
        let iov = [IoVec { base: arena.as_mut_ptr().cast(), len: arena.len() }];
        if let Err(e) = ring.register_buffers(&iov) {
            // RLIMIT_MEMLOCK can legitimately reject even 4 KiB in
            // constrained CI sandboxes — that's the degradation path
            // the transport handles, not a test failure.
            eprintln!("note: skipping registered-buffer leg: {e}");
            return;
        }
        client.write_all(b"fixed-read").unwrap();
        ring.push(Sqe::read_fixed(server.as_raw_fd(), arena.as_mut_ptr(), 4096, 0, 9)).unwrap();
        let mut cqes = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while cqes.is_empty() && std::time::Instant::now() < deadline {
            ring.submit_and_wait(1, Some(std::time::Duration::from_millis(100))).unwrap();
            ring.reap(&mut cqes);
        }
        assert_eq!(cqes[0].user_data, 9);
        assert_eq!(cqes[0].res as usize, b"fixed-read".len());
        assert_eq!(&arena[..b"fixed-read".len()], b"fixed-read");
        // Drop order: ring (unregisters + closes) before arena frees.
        drop(ring);
    }

    #[test]
    fn uring_cancel_completes_inflight_read() {
        if !uring_or_skip("uring_cancel_completes_inflight_read") {
            return;
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();

        let mut ring = IoUring::new(8, 0).unwrap();
        let mut buf = vec![0u8; 64];
        // A read that will never become ready (the client sends nothing).
        ring.push(Sqe::read(server.as_raw_fd(), buf.as_mut_ptr(), buf.len() as u32, 11)).unwrap();
        ring.submit().unwrap();
        ring.push(Sqe::cancel(11, 12)).unwrap();
        let mut cqes = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while cqes.iter().filter(|c| c.user_data == 11).count() == 0
            && std::time::Instant::now() < deadline
        {
            ring.submit_and_wait(1, Some(std::time::Duration::from_millis(100))).unwrap();
            ring.reap(&mut cqes);
        }
        let read = cqes.iter().find(|c| c.user_data == 11).expect("cancelled read CQE");
        assert_eq!(read.res, -ECANCELED, "read completes with -ECANCELED");
        drop(ring);
    }
}
