//! Thin Linux syscall layer: `epoll` and `eventfd` via direct
//! `extern "C"` bindings (std already links libc — no crates).
//!
//! Only what the readiness loop needs is bound: `epoll_create1` /
//! `epoll_ctl` / `epoll_wait`, `eventfd` plus its 8-byte counter
//! read/write, and `setrlimit` so the load generator can lift the
//! default 1024-fd soft limit before opening thousands of sockets.
//! Everything unsafe is confined to this module; the wrappers above the
//! FFI boundary ([`Epoll`], [`EventFd`]) expose an owned-fd API with
//! `io::Result` errors and close-on-drop semantics.

use std::io;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;

// ---------------------------------------------------------------------
// FFI surface (see `man epoll_ctl`, `man eventfd`, `man setrlimit`).
// ---------------------------------------------------------------------

/// One readiness record. On x86-64 the kernel ABI packs the 12-byte
/// struct (u32 events + u64 data with no padding); other architectures
/// use natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

/// One readiness record (naturally aligned ABI, non-x86-64).
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub const fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

/// Readiness: data to read.
pub const EPOLLIN: u32 = 0x001;
/// Readiness: socket writable again.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported, no need to register).
pub const EPOLLERR: u32 = 0x008;
/// Hang-up (always reported, no need to register).
pub const EPOLLHUP: u32 = 0x010;
/// Peer half-closed its write side.
pub const EPOLLRDHUP: u32 = 0x2000;
/// Edge-triggered delivery.
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;
const RLIMIT_NOFILE: c_int = 7;

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

// ---------------------------------------------------------------------
// Owned-fd wrappers.
// ---------------------------------------------------------------------

/// An owned epoll instance.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with the given interest mask; readiness events carry
    /// `token` back in [`EpollEvent::data`].
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask / token of a registered fd.
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd` (kernels before 2.6.9 demand a non-null event
    /// pointer, which `ctl` already provides).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block for readiness, filling `events`; returns how many fired.
    /// Retries on `EINTR`; `timeout_ms < 0` blocks indefinitely.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// An owned eventfd used as a cross-thread wakeup: workers [`signal`]
/// after pushing a completion, the readiness loop [`drain`]s on the
/// corresponding `EPOLLIN`.
///
/// [`signal`]: EventFd::signal
/// [`drain`]: EventFd::drain
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, waking any epoll watcher. A full counter
    /// (`EAGAIN`) already guarantees a pending wakeup, so it is ignored.
    pub fn signal(&self) {
        let one: u64 = 1;
        unsafe { write(self.fd, (&one as *const u64).cast::<c_void>(), 8) };
    }

    /// Reset the counter (nonblocking read; `EAGAIN` means it was
    /// already zero, which is fine — a spurious wakeup costs nothing).
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe { read(self.fd, (&mut buf as *mut u64).cast::<c_void>(), 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// Raise the soft `RLIMIT_NOFILE` to at least `want` descriptors
/// (clamped to the hard limit). Returns the resulting soft limit. The
/// load generator and soak tests open thousands of sockets from one
/// process; the common 1024-fd default would otherwise fail `connect`
/// long before the server's cap is exercised.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.rlim_cur >= want {
        return Ok(lim.rlim_cur);
    }
    let new = RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(new.rlim_cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_signal_and_drain() {
        let efd = EventFd::new().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(efd.raw(), EPOLLIN | EPOLLET, 7).unwrap();
        efd.signal();
        efd.signal();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (got_events, got_data) = (events[0].events, events[0].data);
        assert_ne!(got_events & EPOLLIN, 0);
        assert_eq!(got_data, 7);
        efd.drain();
        // Counter reset: no further edge without a new signal.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        efd.signal();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
    }

    #[test]
    fn epoll_reports_socket_readability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP | EPOLLET, 42).unwrap();
        client.write_all(b"ping").unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        let data = events[0].data;
        assert_eq!(data, 42);
        ep.del(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn nofile_limit_is_monotone() {
        let got = raise_nofile_limit(256).unwrap();
        assert!(got >= 256);
    }
}
