//! Incremental framing over nonblocking sockets.
//!
//! The wire protocol ([`crate::server::proto`]) is length-prefixed, so a
//! blocking transport can just `read_exact` twice. A readiness loop
//! cannot block: bytes arrive in arbitrary fragments — a frame may be
//! torn across many reads, or several frames may land in one. The
//! [`FrameMachine`] accumulates whatever the socket yields and peels
//! complete frames off the front; the [`WriteQueue`] holds serialized
//! response frames through partial writes until `EPOLLOUT` says the
//! socket drained. Both run on pooled buffers
//! ([`super::buffer::BufferPool`]) and compact lazily: the partial-frame
//! remainder is only memmoved when it is smaller than the consumed
//! prefix, so a large frame arriving in many fragments is never
//! re-copied quadratically.
//!
//! The response side's zero-copy staging lives here too: a
//! [`ReplySink`] builds complete wire frames in place — reserve the
//! length prefix, let the codec kernels write the payload directly into
//! the buffer, backfill the prefix — and [`WriteQueue::adopt`] swaps
//! the finished buffer in whole when the queue is drained, so a reply
//! reaches the socket without ever being re-serialized or memcpyed
//! through an intermediate `Vec`.

use std::collections::VecDeque;
use std::io::{self, Write};

use crate::obs::clock::ReqClock;
use crate::server::proto::{Message, ProtoError, MAX_FRAME, TAG_RESP_DATA, TAG_RESP_ERROR};

/// Incremental parser: push raw bytes in, pull parsed frames out.
pub struct FrameMachine {
    buf: Vec<u8>,
    /// Parse cursor: everything before it has been consumed.
    pos: usize,
}

impl FrameMachine {
    /// Build on a (pooled) buffer.
    pub fn new(buf: Vec<u8>) -> FrameMachine {
        FrameMachine { buf, pos: 0 }
    }

    /// Append freshly read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reclaim the underlying buffer (connection teardown).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// Parse the next complete frame, if one is buffered. `Ok(None)`
    /// means "need more bytes"; protocol errors (oversized length
    /// prefix, malformed body) are fatal for the connection.
    pub fn next_frame(&mut self) -> Result<Option<Message>, ProtoError> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            self.maybe_compact();
            return Ok(None);
        }
        let len =
            u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(ProtoError::FrameTooLarge(len));
        }
        if avail < 4 + len {
            self.maybe_compact();
            return Ok(None);
        }
        let msg = Message::from_bytes(&self.buf[self.pos + 4..self.pos + 4 + len])?;
        self.pos += 4 + len;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        Ok(Some(msg))
    }

    /// Drop the consumed prefix when the move is cheaper than the waste:
    /// only when the live remainder is no larger than the dead prefix,
    /// so a half-arrived large frame (pos stuck at 0) is never shuffled.
    fn maybe_compact(&mut self) {
        let live = self.buf.len() - self.pos;
        if self.pos > 0 && live <= self.pos {
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(live);
            self.pos = 0;
        }
    }
}

/// In-place builder for complete wire frames (length prefix included),
/// the write end of the zero-copy reply path.
///
/// The frame protocol is `u32le length ++ body`, but the body's length
/// is only known once the codec has run — and the whole point is to let
/// the codec write *directly* into the outgoing buffer. So the sink
/// works in three steps: [`begin_frame`] reserves the 4-byte prefix,
/// the caller appends the body (header fields via [`push`], bulk
/// payload via the in-place region returned by [`grow`], shrinking an
/// over-reserved region with [`truncate_to`]), and [`end_frame`]
/// backfills the prefix from the actual cursor. A frame that must be
/// abandoned mid-build (a decode error discovered after the payload
/// region was reserved) is erased with [`rollback_frame`] and replaced
/// by an error frame — the consumer never sees partial frames.
///
/// The finished buffer is handed to the connection's [`WriteQueue`] via
/// [`WriteQueue::adopt`], completing the path: kernel output lands in
/// the same allocation the socket write reads from.
///
/// [`begin_frame`]: ReplySink::begin_frame
/// [`push`]: ReplySink::push
/// [`grow`]: ReplySink::grow
/// [`truncate_to`]: ReplySink::truncate_to
/// [`end_frame`]: ReplySink::end_frame
/// [`rollback_frame`]: ReplySink::rollback_frame
pub struct ReplySink {
    buf: Vec<u8>,
    /// Absolute offset of the open frame's length prefix.
    frame_start: usize,
    open: bool,
}

impl ReplySink {
    /// An empty sink on a fresh buffer.
    pub fn new() -> ReplySink {
        ReplySink::with_buf(Vec::new())
    }

    /// Build on a (recycled) buffer; its contents are cleared.
    pub fn with_buf(mut buf: Vec<u8>) -> ReplySink {
        buf.clear();
        ReplySink { buf, frame_start: 0, open: false }
    }

    /// Start a frame: reserves the 4-byte length prefix. Panics if a
    /// frame is already open.
    pub fn begin_frame(&mut self) {
        assert!(!self.open, "previous frame not finished");
        self.frame_start = self.buf.len();
        self.buf.extend_from_slice(&[0u8; 4]);
        self.open = true;
    }

    /// Append body bytes to the open frame.
    pub fn push(&mut self, bytes: &[u8]) {
        debug_assert!(self.open);
        self.buf.extend_from_slice(bytes);
    }

    /// Extend the open frame by `n` bytes and return the new region for
    /// in-place writes — this is where the engine's slice kernels (and
    /// their non-temporal stores) target the socket-bound buffer
    /// directly.
    ///
    /// The region is zero-initialized (`Vec::resize`): handing the
    /// kernels uninitialized memory through a safe `&mut [u8]` would be
    /// UB, so one linear zero pass is the price of staying in safe
    /// Rust. It still removes the reply-`Vec` → frame-`Vec` → queue
    /// copy chain this type exists to eliminate.
    pub fn grow(&mut self, n: usize) -> &mut [u8] {
        debug_assert!(self.open);
        let start = self.buf.len();
        self.buf.resize(start + n, 0);
        &mut self.buf[start..]
    }

    /// Current absolute cursor; pair with [`Self::truncate_to`] to trim
    /// an over-reserved payload region to the bytes actually written.
    pub fn mark(&self) -> usize {
        self.buf.len()
    }

    /// Shrink the open frame back to an earlier [`Self::mark`].
    pub fn truncate_to(&mut self, mark: usize) {
        debug_assert!(self.open && mark >= self.frame_start + 4);
        self.buf.truncate(mark);
    }

    /// Backfill the length prefix and close the frame. An oversized
    /// body fails with [`ProtoError::FrameTooLarge`] (and erases the
    /// frame), mirroring `Message::to_frame_bytes` on the `Vec` path —
    /// the caller treats it as fatal for the connection.
    pub fn end_frame(&mut self) -> Result<(), ProtoError> {
        debug_assert!(self.open);
        let body = self.buf.len() - self.frame_start - 4;
        if body > MAX_FRAME {
            self.buf.truncate(self.frame_start);
            self.open = false;
            return Err(ProtoError::FrameTooLarge(body));
        }
        let prefix = (body as u32).to_le_bytes();
        self.buf[self.frame_start..self.frame_start + 4].copy_from_slice(&prefix);
        self.open = false;
        Ok(())
    }

    /// Erase the open frame entirely (error discovered mid-build).
    pub fn rollback_frame(&mut self) {
        debug_assert!(self.open);
        self.buf.truncate(self.frame_start);
        self.open = false;
    }

    /// Open a `RespData` frame — length prefix, tag and id — leaving
    /// the payload to follow via [`Self::push`] / [`Self::grow`] and a
    /// closing [`Self::end_frame`]. This (with [`Self::push_error`])
    /// keeps the reply wire layout in one place; the produced bytes are
    /// pinned byte-identical to `Message` serialization by the unit
    /// and parity tests.
    pub fn begin_data_frame(&mut self, id: u64) {
        self.begin_frame();
        self.push(&[TAG_RESP_DATA]);
        self.push(&id.to_le_bytes());
    }

    /// Write a complete `RespData` frame from already-materialized
    /// bytes (stream-session output) — one copy into the sink instead
    /// of the serialize-then-copy pair `push_message` would pay.
    pub fn push_data(&mut self, id: u64, data: &[u8]) -> Result<(), ProtoError> {
        self.begin_data_frame(id);
        self.push(data);
        self.end_frame()
    }

    /// Write a complete `RespError` frame, byte-identical to
    /// serializing `Message::RespError { id, message }`.
    pub fn push_error(&mut self, id: u64, message: &str) -> Result<(), ProtoError> {
        self.begin_frame();
        self.push(&[TAG_RESP_ERROR]);
        self.push(&id.to_le_bytes());
        self.push(message.as_bytes());
        self.end_frame()
    }

    /// Serialize a whole message as one frame (the cold replies: stream
    /// control acks, stats, errors — anything without a payload worth
    /// writing in place).
    pub fn push_message(&mut self, msg: &Message) -> Result<(), ProtoError> {
        self.begin_frame();
        let body = msg.to_bytes();
        self.push(&body);
        self.end_frame()
    }

    /// Total finished bytes buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Surrender the buffer (all frames complete) for hand-off to the
    /// connection's write queue.
    pub fn into_buf(self) -> Vec<u8> {
        debug_assert!(!self.open, "unfinished frame in sink");
        self.buf
    }
}

impl Default for ReplySink {
    fn default() -> Self {
        ReplySink::new()
    }
}

/// The coordinator writes reply frames through its own
/// [`ResponseSink`](crate::coordinator::sink::ResponseSink) trait; this
/// is the transport-side implementation, delegating to the inherent
/// frame-building methods above. Keeping the impl here (not in
/// `coordinator`) preserves the base64 → coordinator → net → server
/// layer order: `net` knows the coordinator's trait, the coordinator
/// never names a `net` type.
impl crate::coordinator::sink::ResponseSink for ReplySink {
    fn begin_data(&mut self, id: u64) {
        self.begin_data_frame(id);
    }

    fn grow(&mut self, n: usize) -> &mut [u8] {
        ReplySink::grow(self, n)
    }

    fn mark(&self) -> usize {
        ReplySink::mark(self)
    }

    fn truncate_to(&mut self, mark: usize) {
        ReplySink::truncate_to(self, mark);
    }

    fn commit(&mut self) -> Result<(), crate::coordinator::sink::FrameTooLarge> {
        self.end_frame().map_err(|e| match e {
            ProtoError::FrameTooLarge(n) => crate::coordinator::sink::FrameTooLarge(n),
            other => unreachable!("end_frame only fails with FrameTooLarge, got {other}"),
        })
    }

    fn abort(&mut self) {
        self.rollback_frame();
    }

    fn error_reply(
        &mut self,
        id: u64,
        message: &str,
    ) -> Result<(), crate::coordinator::sink::FrameTooLarge> {
        self.push_error(id, message).map_err(|e| match e {
            ProtoError::FrameTooLarge(n) => crate::coordinator::sink::FrameTooLarge(n),
            other => unreachable!("push_error only fails with FrameTooLarge, got {other}"),
        })
    }
}

/// Outgoing bytes awaiting a writable socket. Frames are appended
/// whole; `write_to` pushes as much as the socket accepts and keeps the
/// rest for the next `EPOLLOUT`.
///
/// The queue also tracks first-flush attribution for the stage clocks:
/// it keeps monotone totals of bytes ever queued and bytes ever
/// written, and a [`ReqClock`] parked with [`Self::push_clock`] is
/// surfaced by [`Self::take_flushed`] once the write totals prove its
/// reply bytes reached the socket. The epoll path advances the written
/// total inside [`Self::write_to`]; the uring path, whose writes
/// complete asynchronously after [`Self::take_pending`], reports them
/// with [`Self::note_written`] when the completion arrives.
pub struct WriteQueue {
    buf: Vec<u8>,
    pos: usize,
    /// Cumulative bytes ever queued (monotone, survives buffer swaps).
    total_queued: u64,
    /// Cumulative bytes the socket has accepted (monotone).
    total_written: u64,
    /// Stage clocks waiting for their reply to flush, each due once
    /// `total_written` reaches the `total_queued` at park time.
    clocks: VecDeque<(u64, ReqClock)>,
}

impl WriteQueue {
    /// Build on a (pooled) buffer.
    pub fn new(buf: Vec<u8>) -> WriteQueue {
        WriteQueue { buf, pos: 0, total_queued: 0, total_written: 0, clocks: VecDeque::new() }
    }

    /// Queue a pre-serialized frame (length prefix included).
    pub fn push_bytes(&mut self, frame: &[u8]) {
        self.total_queued += frame.len() as u64;
        self.buf.extend_from_slice(frame);
    }

    /// Serialize and queue a message as one frame.
    pub fn push_frame(&mut self, msg: &Message) -> Result<(), ProtoError> {
        let frame = msg.to_frame_bytes()?;
        self.push_bytes(&frame);
        Ok(())
    }

    /// Take ownership of a buffer of complete frames (a finished
    /// [`ReplySink`]). When the queue is drained the buffer is swapped
    /// in whole — the zero-copy hand-off — and the queue's previous
    /// (empty) buffer is returned for pooling. With a backlog pending,
    /// wire order requires appending behind it instead, and the spent
    /// input buffer is returned. Either way exactly one buffer comes
    /// back, so the caller's pool stays balanced.
    pub fn adopt(&mut self, frames: Vec<u8>) -> Vec<u8> {
        self.total_queued += frames.len() as u64;
        if self.pending() == 0 {
            self.buf.clear();
            self.pos = 0;
            std::mem::replace(&mut self.buf, frames)
        } else {
            self.buf.extend_from_slice(&frames);
            frames
        }
    }

    /// Bytes still waiting to go out.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Park a request's stage clock until everything queued so far —
    /// its reply included — has been written. Call right after queueing
    /// the reply's frames.
    pub fn push_clock(&mut self, clock: ReqClock) {
        self.clocks.push_back((self.total_queued, clock));
    }

    /// Report `n` bytes accepted by the socket outside
    /// [`Self::write_to`] (the uring transport's asynchronous write
    /// completions).
    pub fn note_written(&mut self, n: u64) {
        self.total_written += n;
    }

    /// Clocks whose reply bytes have fully reached the socket since
    /// the last call, in queue order. The caller records their flush
    /// stage (and fires the slow-request hook).
    pub fn take_flushed(&mut self) -> Vec<ReqClock> {
        let mut out = Vec::new();
        while let Some((due, _)) = self.clocks.front() {
            if *due > self.total_written {
                break;
            }
            out.push(self.clocks.pop_front().unwrap().1);
        }
        out
    }

    /// Whether any parked clock is still waiting on a flush.
    pub fn has_waiting_clocks(&self) -> bool {
        !self.clocks.is_empty()
    }

    /// Swap the queued bytes out for an asynchronous write: returns the
    /// whole backing buffer plus the offset of the first unsent byte,
    /// and installs `replacement` as the new (empty) queue. The uring
    /// transport hands the returned buffer to the kernel — its address
    /// must stay stable for the life of the write op, which a buffer
    /// still owned by a growable queue cannot guarantee — while new
    /// frames keep accumulating in the replacement.
    pub fn take_pending(&mut self, replacement: Vec<u8>) -> (Vec<u8>, usize) {
        let pos = self.pos;
        self.pos = 0;
        (std::mem::replace(&mut self.buf, replacement), pos)
    }

    /// Reclaim the underlying buffer (connection teardown).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// Write until drained or the socket pushes back. Returns
    /// `Ok(written)` where `written` counts the bytes accepted this
    /// call; `WouldBlock` is not an error — check [`Self::pending`] to
    /// see whether an `EPOLLOUT` re-arm is needed.
    pub fn write_to(&mut self, w: &mut impl Write) -> io::Result<usize> {
        let mut written = 0usize;
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.pos += n;
                    self.total_written += n as u64;
                    written += n;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= (1 << 20) {
            // Partially drained but the dead prefix is getting big.
            let live = self.buf.len() - self.pos;
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(live);
            self.pos = 0;
        }
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::{Mode, Whitespace};

    fn sample_messages() -> Vec<Message> {
        vec![
            Message::Ping,
            Message::Encode {
                id: 1,
                alphabet: "standard".into(),
                mode: Mode::Strict,
                data: vec![0xAB; 100],
            },
            Message::Decode {
                id: 2,
                alphabet: "url".into(),
                mode: Mode::Forgiving,
                ws: Whitespace::CrLf,
                data: b"Zm9v\r\nYg==".to_vec(),
            },
            Message::StreamChunk { id: 3, data: vec![7; 300] },
            Message::RespData { id: 4, data: vec![1, 2, 3] },
            Message::Stats,
        ]
    }

    fn wire(messages: &[Message]) -> Vec<u8> {
        let mut all = Vec::new();
        for m in messages {
            all.extend_from_slice(&m.to_frame_bytes().unwrap());
        }
        all
    }

    #[test]
    fn byte_at_a_time_delivery() {
        let msgs = sample_messages();
        let stream = wire(&msgs);
        let mut fm = FrameMachine::new(Vec::new());
        let mut got = Vec::new();
        for &b in &stream {
            fm.push(&[b]);
            while let Some(m) = fm.next_frame().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs);
        assert_eq!(fm.buffered(), 0);
    }

    #[test]
    fn torn_frames_at_every_split_point() {
        let msgs = sample_messages();
        let stream = wire(&msgs);
        for split in 0..=stream.len() {
            let mut fm = FrameMachine::new(Vec::new());
            let mut got = Vec::new();
            for part in [&stream[..split], &stream[split..]] {
                fm.push(part);
                while let Some(m) = fm.next_frame().unwrap() {
                    got.push(m);
                }
            }
            assert_eq!(got, msgs, "split={split}");
        }
    }

    #[test]
    fn many_frames_in_one_push() {
        let msgs = sample_messages();
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&wire(&msgs));
        let mut got = Vec::new();
        while let Some(m) = fm.next_frame().unwrap() {
            got.push(m);
        }
        assert_eq!(got, msgs);
    }

    #[test]
    fn oversized_length_prefix_is_fatal() {
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&(u32::MAX).to_le_bytes());
        assert!(matches!(fm.next_frame(), Err(ProtoError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_body_is_fatal() {
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&2u32.to_le_bytes());
        fm.push(&[0xFF, 0x00]); // unknown tag
        assert!(matches!(fm.next_frame(), Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn compaction_keeps_partial_frames_intact() {
        // Interleave a parsed frame with a torn one so pos > 0, then
        // force the "need more" path that compacts.
        let ping = Message::Ping.to_frame_bytes().unwrap();
        let big = Message::StreamChunk { id: 9, data: vec![0x5A; 10_000] }
            .to_frame_bytes()
            .unwrap();
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&ping);
        fm.push(&big[..5]);
        assert_eq!(fm.next_frame().unwrap(), Some(Message::Ping));
        assert!(fm.next_frame().unwrap().is_none()); // compacts here
        fm.push(&big[5..]);
        match fm.next_frame().unwrap() {
            Some(Message::StreamChunk { id: 9, data }) => assert_eq!(data, vec![0x5A; 10_000]),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn write_queue_partial_writes() {
        /// Accepts at most `cap` bytes per call, then WouldBlock.
        struct Throttle {
            out: Vec<u8>,
            cap: usize,
            calls_left: usize,
        }
        impl Write for Throttle {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.calls_left == 0 {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                self.calls_left -= 1;
                let n = buf.len().min(self.cap);
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }

        let mut q = WriteQueue::new(Vec::new());
        let frame = Message::RespData { id: 1, data: vec![9; 1000] }.to_frame_bytes().unwrap();
        q.push_bytes(&frame);
        q.push_frame(&Message::Pong).unwrap();
        let total = q.pending();
        let mut sink = Throttle { out: Vec::new(), cap: 100, calls_left: 3 };
        q.write_to(&mut sink).unwrap();
        assert_eq!(q.pending(), total - 300, "three throttled writes landed");
        sink.calls_left = usize::MAX;
        q.write_to(&mut sink).unwrap();
        assert_eq!(q.pending(), 0);
        let mut expect = frame;
        expect.extend_from_slice(&Message::Pong.to_frame_bytes().unwrap());
        assert_eq!(sink.out, expect, "byte order preserved across partial writes");
    }

    #[test]
    fn reply_sink_matches_message_serialization() {
        // Building a data frame piecewise through the sink must be
        // byte-identical to the Vec serialization path.
        let msg = Message::RespData { id: 42, data: vec![7u8; 300] };
        let expect = msg.to_frame_bytes().unwrap();
        let mut sink = ReplySink::new();
        sink.begin_data_frame(42);
        let region = sink.grow(300);
        region.copy_from_slice(&[7u8; 300]);
        sink.end_frame().unwrap();
        assert_eq!(sink.into_buf(), expect);
        // push_data, push_message and push_error all agree with the
        // Message serialization they stand in for.
        let mut sink = ReplySink::new();
        sink.push_data(42, &[7u8; 300]).unwrap();
        assert_eq!(sink.into_buf(), expect.clone());
        let mut sink = ReplySink::new();
        sink.push_message(&msg).unwrap();
        assert_eq!(sink.into_buf(), expect);
        let err = Message::RespError { id: 9, message: "bad byte".into() };
        let mut sink = ReplySink::new();
        sink.push_error(9, "bad byte").unwrap();
        assert_eq!(sink.into_buf(), err.to_frame_bytes().unwrap());
    }

    #[test]
    fn reply_sink_truncate_and_rollback() {
        let mut sink = ReplySink::new();
        // Over-reserve, then trim to the bytes actually produced.
        sink.begin_data_frame(1);
        let mark = sink.mark();
        let region = sink.grow(100);
        region[..3].copy_from_slice(b"abc");
        sink.truncate_to(mark + 3);
        sink.end_frame().unwrap();
        let expect = Message::RespData { id: 1, data: b"abc".to_vec() }.to_frame_bytes().unwrap();
        assert_eq!(sink.len(), expect.len());
        // A rolled-back frame leaves no trace, and the next frame lands
        // flush against the previous one.
        sink.begin_frame();
        sink.grow(50);
        sink.rollback_frame();
        sink.push_message(&Message::Pong).unwrap();
        let mut want = expect;
        want.extend_from_slice(&Message::Pong.to_frame_bytes().unwrap());
        assert_eq!(sink.into_buf(), want);
    }

    #[test]
    fn write_queue_flush_clocks_fire_only_after_their_bytes_drain() {
        use crate::obs::clock::{Proto, ReqClock};
        let mut q = WriteQueue::new(Vec::new());
        // First reply: 10 bytes, clock parked behind them.
        q.push_bytes(&[1u8; 10]);
        q.push_clock(ReqClock::new(Proto::Native));
        // Second reply: 20 more bytes, its own clock behind all 30.
        q.push_bytes(&[2u8; 20]);
        q.push_clock(ReqClock::new(Proto::Http));
        assert!(q.has_waiting_clocks());
        assert!(q.take_flushed().is_empty(), "nothing written yet");

        /// Accepts at most `cap` bytes per call, then WouldBlock.
        struct Throttle(usize);
        impl Write for Throttle {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.0 == 0 {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                let n = buf.len().min(self.0);
                self.0 = 0;
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        // A 5-byte partial write releases neither clock.
        q.write_to(&mut Throttle(5)).unwrap();
        assert!(q.take_flushed().is_empty());
        // 10 more bytes (15 total) covers the first reply only.
        q.write_to(&mut Throttle(10)).unwrap();
        let flushed = q.take_flushed();
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].proto(), Proto::Native);
        // Draining the rest releases the second.
        q.write_to(&mut Throttle(usize::MAX)).unwrap();
        assert_eq!(q.pending(), 0);
        let flushed = q.take_flushed();
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].proto(), Proto::Http);
        assert!(!q.has_waiting_clocks());
    }

    #[test]
    fn write_queue_async_writes_release_clocks_via_note_written() {
        use crate::obs::clock::{Proto, ReqClock};
        // The uring path: bytes leave via take_pending and complete
        // later; note_written is the flush signal.
        let mut q = WriteQueue::new(Vec::new());
        q.push_bytes(&[7u8; 12]);
        q.push_clock(ReqClock::new(Proto::Native));
        let (buf, pos) = q.take_pending(Vec::new());
        assert_eq!((buf.len(), pos), (12, 0));
        assert!(q.take_flushed().is_empty(), "take_pending is not a flush");
        q.note_written(8); // short write completion
        assert!(q.take_flushed().is_empty());
        q.note_written(4); // remainder lands
        assert_eq!(q.take_flushed().len(), 1);
        // Clocks parked while an async write is in flight wait for
        // their own bytes, not the in-flight ones.
        q.push_bytes(&[8u8; 3]);
        q.push_clock(ReqClock::new(Proto::Native));
        q.note_written(2);
        assert!(q.take_flushed().is_empty());
        q.note_written(1);
        assert_eq!(q.take_flushed().len(), 1);
    }

    #[test]
    fn write_queue_adopt_swaps_when_drained_appends_when_not() {
        // Drained queue: the frames buffer is swapped in, the old buffer
        // comes back (same allocation, cleared).
        let mut q = WriteQueue::new(Vec::with_capacity(64));
        let frame = Message::Pong.to_frame_bytes().unwrap();
        let spare = q.adopt(frame.clone());
        assert!(spare.capacity() >= 64, "drained queue returns its old buffer");
        assert!(spare.is_empty());
        assert_eq!(q.pending(), frame.len());
        // Pending backlog: bytes are appended behind it (wire order) and
        // the input buffer is returned instead.
        let second = Message::RespData { id: 9, data: vec![1, 2, 3] }.to_frame_bytes().unwrap();
        let spent = q.adopt(second.clone());
        assert_eq!(spent, second, "backlogged queue returns the spent input");
        let mut out = Vec::new();
        q.write_to(&mut out).unwrap();
        let mut expect = frame;
        expect.extend_from_slice(&second);
        assert_eq!(out, expect, "adopted frames drain in order");
    }
}
