//! Incremental framing over nonblocking sockets.
//!
//! The wire protocol ([`crate::server::proto`]) is length-prefixed, so a
//! blocking transport can just `read_exact` twice. A readiness loop
//! cannot block: bytes arrive in arbitrary fragments — a frame may be
//! torn across many reads, or several frames may land in one. The
//! [`FrameMachine`] accumulates whatever the socket yields and peels
//! complete frames off the front; the [`WriteQueue`] holds serialized
//! response frames through partial writes until `EPOLLOUT` says the
//! socket drained. Both run on pooled buffers
//! ([`super::buffer::BufferPool`]) and compact lazily: the partial-frame
//! remainder is only memmoved when it is smaller than the consumed
//! prefix, so a large frame arriving in many fragments is never
//! re-copied quadratically.

use std::io::{self, Write};

use crate::server::proto::{Message, ProtoError, MAX_FRAME};

/// Incremental parser: push raw bytes in, pull parsed frames out.
pub struct FrameMachine {
    buf: Vec<u8>,
    /// Parse cursor: everything before it has been consumed.
    pos: usize,
}

impl FrameMachine {
    /// Build on a (pooled) buffer.
    pub fn new(buf: Vec<u8>) -> FrameMachine {
        FrameMachine { buf, pos: 0 }
    }

    /// Append freshly read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reclaim the underlying buffer (connection teardown).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// Parse the next complete frame, if one is buffered. `Ok(None)`
    /// means "need more bytes"; protocol errors (oversized length
    /// prefix, malformed body) are fatal for the connection.
    pub fn next_frame(&mut self) -> Result<Option<Message>, ProtoError> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            self.maybe_compact();
            return Ok(None);
        }
        let len =
            u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(ProtoError::FrameTooLarge(len));
        }
        if avail < 4 + len {
            self.maybe_compact();
            return Ok(None);
        }
        let msg = Message::from_bytes(&self.buf[self.pos + 4..self.pos + 4 + len])?;
        self.pos += 4 + len;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        Ok(Some(msg))
    }

    /// Drop the consumed prefix when the move is cheaper than the waste:
    /// only when the live remainder is no larger than the dead prefix,
    /// so a half-arrived large frame (pos stuck at 0) is never shuffled.
    fn maybe_compact(&mut self) {
        let live = self.buf.len() - self.pos;
        if self.pos > 0 && live <= self.pos {
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(live);
            self.pos = 0;
        }
    }
}

/// Outgoing bytes awaiting a writable socket. Frames are appended
/// whole; `write_to` pushes as much as the socket accepts and keeps the
/// rest for the next `EPOLLOUT`.
pub struct WriteQueue {
    buf: Vec<u8>,
    pos: usize,
}

impl WriteQueue {
    /// Build on a (pooled) buffer.
    pub fn new(buf: Vec<u8>) -> WriteQueue {
        WriteQueue { buf, pos: 0 }
    }

    /// Queue a pre-serialized frame (length prefix included).
    pub fn push_bytes(&mut self, frame: &[u8]) {
        self.buf.extend_from_slice(frame);
    }

    /// Serialize and queue a message as one frame.
    pub fn push_frame(&mut self, msg: &Message) -> Result<(), ProtoError> {
        let frame = msg.to_frame_bytes()?;
        self.push_bytes(&frame);
        Ok(())
    }

    /// Bytes still waiting to go out.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reclaim the underlying buffer (connection teardown).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// Write until drained or the socket pushes back. Returns
    /// `Ok(written)` where `written` counts the bytes accepted this
    /// call; `WouldBlock` is not an error — check [`Self::pending`] to
    /// see whether an `EPOLLOUT` re-arm is needed.
    pub fn write_to(&mut self, w: &mut impl Write) -> io::Result<usize> {
        let mut written = 0usize;
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.pos += n;
                    written += n;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= (1 << 20) {
            // Partially drained but the dead prefix is getting big.
            let live = self.buf.len() - self.pos;
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(live);
            self.pos = 0;
        }
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::{Mode, Whitespace};

    fn sample_messages() -> Vec<Message> {
        vec![
            Message::Ping,
            Message::Encode {
                id: 1,
                alphabet: "standard".into(),
                mode: Mode::Strict,
                data: vec![0xAB; 100],
            },
            Message::Decode {
                id: 2,
                alphabet: "url".into(),
                mode: Mode::Forgiving,
                ws: Whitespace::CrLf,
                data: b"Zm9v\r\nYg==".to_vec(),
            },
            Message::StreamChunk { id: 3, data: vec![7; 300] },
            Message::RespData { id: 4, data: vec![1, 2, 3] },
            Message::Stats,
        ]
    }

    fn wire(messages: &[Message]) -> Vec<u8> {
        let mut all = Vec::new();
        for m in messages {
            all.extend_from_slice(&m.to_frame_bytes().unwrap());
        }
        all
    }

    #[test]
    fn byte_at_a_time_delivery() {
        let msgs = sample_messages();
        let stream = wire(&msgs);
        let mut fm = FrameMachine::new(Vec::new());
        let mut got = Vec::new();
        for &b in &stream {
            fm.push(&[b]);
            while let Some(m) = fm.next_frame().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs);
        assert_eq!(fm.buffered(), 0);
    }

    #[test]
    fn torn_frames_at_every_split_point() {
        let msgs = sample_messages();
        let stream = wire(&msgs);
        for split in 0..=stream.len() {
            let mut fm = FrameMachine::new(Vec::new());
            let mut got = Vec::new();
            for part in [&stream[..split], &stream[split..]] {
                fm.push(part);
                while let Some(m) = fm.next_frame().unwrap() {
                    got.push(m);
                }
            }
            assert_eq!(got, msgs, "split={split}");
        }
    }

    #[test]
    fn many_frames_in_one_push() {
        let msgs = sample_messages();
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&wire(&msgs));
        let mut got = Vec::new();
        while let Some(m) = fm.next_frame().unwrap() {
            got.push(m);
        }
        assert_eq!(got, msgs);
    }

    #[test]
    fn oversized_length_prefix_is_fatal() {
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&(u32::MAX).to_le_bytes());
        assert!(matches!(fm.next_frame(), Err(ProtoError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_body_is_fatal() {
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&2u32.to_le_bytes());
        fm.push(&[0xFF, 0x00]); // unknown tag
        assert!(matches!(fm.next_frame(), Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn compaction_keeps_partial_frames_intact() {
        // Interleave a parsed frame with a torn one so pos > 0, then
        // force the "need more" path that compacts.
        let ping = Message::Ping.to_frame_bytes().unwrap();
        let big = Message::StreamChunk { id: 9, data: vec![0x5A; 10_000] }
            .to_frame_bytes()
            .unwrap();
        let mut fm = FrameMachine::new(Vec::new());
        fm.push(&ping);
        fm.push(&big[..5]);
        assert_eq!(fm.next_frame().unwrap(), Some(Message::Ping));
        assert!(fm.next_frame().unwrap().is_none()); // compacts here
        fm.push(&big[5..]);
        match fm.next_frame().unwrap() {
            Some(Message::StreamChunk { id: 9, data }) => assert_eq!(data, vec![0x5A; 10_000]),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn write_queue_partial_writes() {
        /// Accepts at most `cap` bytes per call, then WouldBlock.
        struct Throttle {
            out: Vec<u8>,
            cap: usize,
            calls_left: usize,
        }
        impl Write for Throttle {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.calls_left == 0 {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                self.calls_left -= 1;
                let n = buf.len().min(self.cap);
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }

        let mut q = WriteQueue::new(Vec::new());
        let frame = Message::RespData { id: 1, data: vec![9; 1000] }.to_frame_bytes().unwrap();
        q.push_bytes(&frame);
        q.push_frame(&Message::Pong).unwrap();
        let total = q.pending();
        let mut sink = Throttle { out: Vec::new(), cap: 100, calls_left: 3 };
        q.write_to(&mut sink).unwrap();
        assert_eq!(q.pending(), total - 300, "three throttled writes landed");
        sink.calls_left = usize::MAX;
        q.write_to(&mut sink).unwrap();
        assert_eq!(q.pending(), 0);
        let mut expect = frame;
        expect.extend_from_slice(&Message::Pong.to_frame_bytes().unwrap());
        assert_eq!(sink.out, expect, "byte order preserved across partial writes");
    }
}
