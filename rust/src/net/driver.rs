//! The epoll transport: a group of edge-triggered readiness loops (one
//! per reactor shard, each owning a `SO_REUSEPORT` listener, slab,
//! buffer pool and completion queue), all feeding one worker pool that
//! executes requests against the shared [`Router`].
//!
//! ```text
//!   clients ─► SO_REUSEPORT ─► [reactor 0] ──┐
//!              (kernel hash)   [reactor 1] ──┤ WorkItem ─► [workers] ─► Router
//!                              [reactor N] ──┘    ▲            │       (batched
//!                 eventfd ◄── Completion ────────────────────◄─┘        SIMD)
//!                 (per shard)  (reply frame buffer)
//! ```
//!
//! A loop never blocks on a socket and never runs codec work; the
//! workers never touch a socket. They meet at each shard's completion
//! queue, drained on that shard's [`EventFd`] wakeup (every `WorkItem`
//! carries its shard's queue + eventfd, so a shared worker can answer
//! any shard). Per-connection request/response order is preserved by
//! keeping at most one request per connection in flight (see
//! [`super::conn`]); cross-connection concurrency — the thing the old
//! thread-per-connection transport capped at 256 threads — is bounded
//! only by the configured admission cap, shared across shards by one
//! `ConnLimiter`, since an idle connection costs one slab slot and two
//! pooled buffers, not a thread.
//!
//! Replies take the zero-copy path by default
//! (`ServerConfig::zero_copy`): a worker builds the complete reply
//! frame in a `ReplySink` — the router's sink entry points let the
//! codec kernels write the payload in place — and the loop *adopts* the
//! finished buffer into the connection's `WriteQueue` instead of
//! memcpying it. The `Vec`-serialization path is kept selectable as the
//! differential reference.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use super::buffer::BufferPool;
use super::conn::Conn;
use super::frame::ReplySink;
use super::sys::{
    Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLET, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use crate::coordinator::backpressure::ConnLimiter;
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::state::SessionState;
use crate::coordinator::{Metrics, Router};
use crate::server::proto::Message;
use crate::server::service::{dispatch, dispatch_into, refuse_busy, ServerConfig};

/// Slab token of the listening socket.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Slab token of the completion-queue eventfd.
const TOKEN_WAKE: u64 = u64::MAX - 1;

/// Readiness events fetched per `epoll_wait`.
const EVENT_BATCH: usize = 1024;
/// Read scratch shared by every connection (the loop is single-threaded).
const READ_SCRATCH: usize = 64 << 10;

fn token(idx: usize, epoch: u32) -> u64 {
    ((epoch as u64) << 32) | idx as u64
}

fn token_parts(tok: u64) -> (usize, u32) {
    ((tok & 0xFFFF_FFFF) as usize, (tok >> 32) as u32)
}

/// One request headed for the worker pool. Carries its shard's
/// completion queue and eventfd so the shared workers can route the
/// reply back to whichever reactor owns the connection.
struct WorkItem {
    token: u64,
    msg: Message,
    session: Arc<Mutex<SessionState>>,
    done: Arc<Mutex<Vec<Completion>>>,
    wake: Arc<EventFd>,
    /// A recycled buffer from the shard's pool for the reply sink
    /// (empty on the `Vec` path), closing the allocation loop: adopt's
    /// spare buffers return to the pool, the pool feeds the next
    /// reply's sink.
    buf: Vec<u8>,
}

/// One executed request headed back to its loop. `frame = None` marks a
/// reply that could not be framed (oversized) — fatal for the
/// connection, matching the blocking transport's behaviour.
struct Completion {
    token: u64,
    frame: Option<Vec<u8>>,
}

/// Handles the spawned transport threads + each loop's wakeup fd.
pub(crate) struct EpollServer {
    pub threads: Vec<JoinHandle<()>>,
    pub wakes: Vec<Arc<EventFd>>,
}

/// Spawn one readiness loop per listener (the reactor shards) plus the
/// shared worker pool. The caller keeps `stop` and signals every wake
/// fd to shut the loops down; the workers exit once all loops have
/// dropped their work senders.
pub(crate) fn spawn(
    router: Arc<Router>,
    config: &ServerConfig,
    listeners: Vec<TcpListener>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<EpollServer> {
    let limiter = ConnLimiter::new(config.max_connections);
    let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let metrics = router.metrics().clone();
    // A fresh serve starts a fresh per-shard breakdown; without this a
    // router re-served after shutdown would report dead shards forever.
    metrics.reset_shards();

    let mut threads = Vec::new();
    let mut wakes: Vec<Arc<EventFd>> = Vec::new();
    let mut built = Ok(());
    for (shard_id, listener) in listeners.into_iter().enumerate() {
        match spawn_shard(shard_id, listener, config, &metrics, &limiter, &work_tx, &stop) {
            Ok((thread, wake)) => {
                threads.push(thread);
                wakes.push(wake);
            }
            Err(e) => {
                built = Err(e);
                break;
            }
        }
    }
    // Only the loops may hold work senders: the workers' exit condition
    // is every sender dropping when the loops stop.
    drop(work_tx);
    let zero_copy = config.zero_copy;
    if built.is_ok() {
        for i in 0..config.net_workers.max(1) {
            let rx = work_rx.clone();
            let router = router.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("b64simd-net-worker-{i}"))
                .spawn(move || worker_loop(rx, router, zero_copy));
            match spawned {
                Ok(t) => threads.push(t),
                Err(e) => {
                    built = Err(e);
                    break;
                }
            }
        }
    }
    if let Err(e) = built {
        // Unwind whatever did spawn before the failure — loop threads
        // and worker threads alike — so no reactor keeps the listeners
        // bound behind a failed `serve`.
        stop.store(true, Ordering::SeqCst);
        for w in &wakes {
            w.signal();
        }
        for t in threads {
            let _ = t.join();
        }
        return Err(e);
    }
    Ok(EpollServer { threads, wakes })
}

/// Set up one reactor shard: its epoll instance, wake fd, completion
/// queue and loop thread.
fn spawn_shard(
    shard_id: usize,
    listener: TcpListener,
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    limiter: &Arc<ConnLimiter>,
    work_tx: &mpsc::Sender<WorkItem>,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<(JoinHandle<()>, Arc<EventFd>)> {
    listener.set_nonblocking(true)?;
    let epoll = Epoll::new()?;
    let wake = Arc::new(EventFd::new()?);
    epoll.add(listener.as_raw_fd(), EPOLLIN | EPOLLET, TOKEN_LISTENER)?;
    epoll.add(wake.raw(), EPOLLIN | EPOLLET, TOKEN_WAKE)?;
    let lp = Loop {
        epoll,
        listener,
        wake: wake.clone(),
        metrics: metrics.clone(),
        shard: metrics.register_shard(),
        limiter: limiter.clone(),
        max_streams: config.max_streams_per_connection,
        zero_copy: config.zero_copy,
        conns: Vec::new(),
        epochs: Vec::new(),
        free: Vec::new(),
        pool: BufferPool::new(2048, 256 << 10),
        scratch: vec![0u8; READ_SCRATCH],
        work_tx: work_tx.clone(),
        completions: Arc::new(Mutex::new(Vec::new())),
        stop: stop.clone(),
    };
    let thread = std::thread::Builder::new()
        .name(format!("b64simd-net-loop-{shard_id}"))
        .spawn(move || lp.run())?;
    Ok((thread, wake))
}

/// Worker: pull a request, execute it against the router (this is where
/// the batched SIMD work happens, concurrently across workers), push
/// the reply frame onto the owning shard's completion queue, wake that
/// shard. Exits when every loop drops its sending side.
///
/// With `zero_copy` set the reply frame is built in place through a
/// [`ReplySink`] (codec output written directly into the buffer the
/// loop will adopt into the write queue); otherwise the reply `Message`
/// is serialized through `to_frame_bytes`, the differential reference
/// path. A `None` frame (oversized reply) closes the connection either
/// way.
fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<WorkItem>>>, router: Arc<Router>, zero_copy: bool) {
    loop {
        // Holding the lock across `recv` just serializes the hand-off,
        // not the work: the lock drops as soon as an item arrives.
        let item = { rx.lock().unwrap().recv() };
        let Ok(WorkItem { token, msg, session, done, wake, buf }) = item else { break };
        let frame = if zero_copy {
            let mut sink = ReplySink::with_buf(buf);
            let framed = {
                let mut session = session.lock().unwrap();
                dispatch_into(msg, &router, &mut session, &mut sink)
            };
            framed.ok().map(|()| sink.into_buf())
        } else {
            drop(buf); // empty on this path
            let reply = {
                let mut session = session.lock().unwrap();
                dispatch(msg, &router, &mut session)
            };
            reply.to_frame_bytes().ok()
        };
        done.lock().unwrap().push(Completion { token, frame });
        wake.signal();
    }
}

/// One single-threaded readiness loop (a reactor shard).
struct Loop {
    epoll: Epoll,
    listener: TcpListener,
    wake: Arc<EventFd>,
    metrics: Arc<Metrics>,
    /// This shard's slice of the metrics (globals stay the roll-up).
    shard: Arc<ShardMetrics>,
    /// Connection cap shared across every shard.
    limiter: Arc<ConnLimiter>,
    max_streams: usize,
    /// Reply path: pop a pooled sink buffer per request when true.
    zero_copy: bool,
    /// Connection slab, indexed by the token's low 32 bits.
    conns: Vec<Option<Conn>>,
    /// Slot generations (guard against stale tokens after reuse).
    epochs: Vec<u32>,
    /// Vacant slab slots.
    free: Vec<usize>,
    pool: BufferPool,
    scratch: Vec<u8>,
    work_tx: mpsc::Sender<WorkItem>,
    completions: Arc<Mutex<Vec<Completion>>>,
    stop: Arc<AtomicBool>,
}

impl Loop {
    fn run(mut self) {
        let mut events = vec![EpollEvent::zeroed(); EVENT_BATCH];
        'events: loop {
            let n = match self.epoll.wait(&mut events, -1) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("b64simd: epoll loop failed: {e}");
                    break 'events;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                break 'events;
            }
            for ev in &events[..n] {
                // Copy out of the (packed) record before field access.
                let (mask, data) = { (ev.events, ev.data) };
                match data {
                    TOKEN_WAKE => {
                        // Drain the counter *before* the queue so a
                        // completion pushed mid-drain re-arms the edge.
                        self.wake.drain();
                        self.drain_completions();
                    }
                    TOKEN_LISTENER => self.accept_burst(),
                    tok => self.conn_event(tok, mask),
                }
            }
        }
        // Shutdown: tear every connection down so the open-conns gauge
        // and the buffer pool reflect reality before the loop thread
        // joins.
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                self.close(idx);
            }
        }
    }

    /// Accept until `WouldBlock` (edge-triggered listener). Per-connection
    /// failures (a client that reset while queued in the backlog —
    /// `ECONNABORTED` and friends) must not end the burst: the listener
    /// only re-edges on a *new* connection, so breaking early would
    /// strand the established connections still behind the aborted one.
    /// Persistent failures (fd exhaustion) are bounded so the loop
    /// cannot spin forever on an error `accept` does not consume.
    fn accept_burst(&mut self) {
        let mut hard_errors = 0;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted | std::io::ErrorKind::ConnectionAborted
                    ) =>
                {
                    continue
                }
                Err(_) => {
                    hard_errors += 1;
                    if hard_errors > 64 {
                        break; // e.g. EMFILE: back off until the next edge
                    }
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        let Some(permit) = self.limiter.try_acquire() else {
            Metrics::inc(&self.metrics.conns_refused, 1);
            refuse_busy(stream, &self.limiter);
            return;
        };
        if stream.set_nonblocking(true).is_err() {
            return; // permit drops, socket closes
        }
        stream.set_nodelay(true).ok();
        let idx = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.epochs.push(0);
            self.conns.len() - 1
        });
        let epoch = self.epochs[idx];
        let conn = Conn::new(stream, epoch, self.max_streams, &mut self.pool, permit);
        let interest = EPOLLIN | EPOLLOUT | EPOLLRDHUP | EPOLLET;
        if self
            .epoll
            .add(conn.stream.as_raw_fd(), interest, token(idx, epoch))
            .is_err()
        {
            conn.teardown(&mut self.pool);
            self.free.push(idx);
            return;
        }
        Metrics::inc(&self.metrics.conns_accepted, 1);
        Metrics::inc(&self.metrics.conns_open, 1);
        Metrics::inc(&self.shard.conns_accepted, 1);
        Metrics::inc(&self.shard.conns_open, 1);
        self.conns[idx] = Some(conn);
        self.pump(idx);
    }

    fn conn_event(&mut self, tok: u64, mask: u32) {
        let (idx, epoch) = token_parts(tok);
        if idx >= self.conns.len() || self.epochs[idx] != epoch {
            return; // stale: the slot was closed (and possibly reused)
        }
        let Some(conn) = self.conns[idx].as_mut() else { return };
        if mask & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0 {
            // Latch readability; HUP/ERR also surface through read().
            conn.readable = true;
        }
        // EPOLLOUT needs no flag: pump always starts with a flush.
        self.pump(idx);
    }

    /// Drive one connection as far as it will go: flush pending writes,
    /// parse buffered frames, dispatch if idle, read while the socket
    /// and the backpressure caps allow, and close once a finished peer
    /// is fully answered.
    fn pump(&mut self, idx: usize) {
        loop {
            let Some(conn) = self.conns[idx].as_mut() else { return };
            // 1. Writes first: draining the socket lifts the write-side
            //    backpressure check below.
            match conn.write.write_to(&mut conn.stream) {
                Ok(n) => {
                    if n > 0 {
                        Metrics::inc(&self.metrics.net_bytes_out, n as u64);
                    }
                }
                Err(_) => return self.close(idx),
            }
            // 2. Peel complete frames into the inbox.
            if !conn.corrupt {
                match conn.parse_into_inbox() {
                    Ok(parsed) => {
                        if parsed > 0 {
                            Metrics::inc(&self.metrics.frames_in, parsed as u64);
                            Metrics::inc(&self.shard.frames_in, parsed as u64);
                        }
                    }
                    // Protocol error: poison the stream. Requests parsed
                    // *before* the bad frame still get their replies
                    // (the threaded transport answers each frame before
                    // reading the next — parity demands the same), then
                    // the drained connection closes below.
                    Err(_) => {
                        conn.corrupt = true;
                        conn.eof = true;
                        conn.readable = false;
                    }
                }
            }
            // 3. Dispatch the next request if none is in flight.
            if !conn.busy {
                if let Some(msg) = conn.inbox.pop_front() {
                    conn.busy = true;
                    let buf = if self.zero_copy { self.pool.get() } else { Vec::new() };
                    let item = WorkItem {
                        token: token(idx, conn.epoch),
                        msg,
                        session: conn.session.clone(),
                        done: self.completions.clone(),
                        wake: self.wake.clone(),
                        buf,
                    };
                    if self.work_tx.send(item).is_err() {
                        return self.close(idx); // shutting down
                    }
                }
            }
            // 4. Read while the latch and the caps allow.
            if conn.wants_read() {
                match conn.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        conn.eof = true;
                        conn.readable = false;
                    }
                    Ok(n) => {
                        Metrics::inc(&self.metrics.net_bytes_in, n as u64);
                        conn.frames.push(&self.scratch[..n]);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        conn.readable = false;
                        break;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return self.close(idx),
                }
                continue; // new bytes (or EOF): reparse and re-dispatch
            }
            break;
        }
        let Some(conn) = self.conns[idx].as_ref() else { return };
        if conn.eof && conn.drained() {
            self.close(idx);
        }
    }

    /// Hand completed replies back to their connections and keep those
    /// connections moving.
    fn drain_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *self.completions.lock().unwrap());
        for c in done {
            let (idx, epoch) = token_parts(c.token);
            if idx >= self.conns.len() || self.epochs[idx] != epoch {
                continue; // connection closed while the request ran
            }
            let Some(conn) = self.conns[idx].as_mut() else { continue };
            conn.busy = false;
            match c.frame {
                Some(frame) => {
                    // Zero-copy hand-off: a drained queue takes the
                    // frame buffer whole; either way one spare buffer
                    // comes back for the pool.
                    let spare = conn.write.adopt(frame);
                    self.pool.put(spare);
                    Metrics::inc(&self.metrics.frames_out, 1);
                    Metrics::inc(&self.shard.frames_out, 1);
                }
                None => {
                    self.close(idx);
                    continue;
                }
            }
            self.pump(idx);
        }
    }

    fn close(&mut self, idx: usize) {
        let Some(conn) = self.conns[idx].take() else { return };
        self.epochs[idx] = self.epochs[idx].wrapping_add(1);
        let _ = self.epoll.del(conn.stream.as_raw_fd());
        conn.teardown(&mut self.pool);
        self.free.push(idx);
        Metrics::dec(&self.metrics.conns_open, 1);
        Metrics::dec(&self.shard.conns_open, 1);
    }
}
