//! The epoll transport: a group of edge-triggered readiness loops (one
//! per reactor shard, each owning a `SO_REUSEPORT` listener, slab,
//! buffer pool and completion queue), all feeding one worker pool that
//! executes requests against the shared [`Router`].
//!
//! ```text
//!   clients ─► SO_REUSEPORT ─► [reactor 0] ──┐
//!              (kernel hash)   [reactor 1] ──┤ WorkItem ─► [workers] ─► Router
//!                              [reactor N] ──┘    ▲            │       (batched
//!                 eventfd ◄── Completion ────────────────────◄─┘        SIMD)
//!                 (per shard)  (reply frame buffer)
//! ```
//!
//! A loop never blocks on a socket and never runs codec work; the
//! workers never touch a socket. They meet at each shard's completion
//! queue, drained on that shard's [`EventFd`] wakeup (every `WorkItem`
//! carries its shard's queue + eventfd, so a shared worker can answer
//! any shard). Per-connection request/response order is preserved by
//! keeping at most one request per connection in flight (see
//! [`super::conn`]); cross-connection concurrency — the thing the old
//! thread-per-connection transport capped at 256 threads — is bounded
//! only by the configured admission cap, shared across shards by one
//! `ConnLimiter`, since an idle connection costs one slab slot and two
//! pooled buffers, not a thread.
//!
//! Replies take the zero-copy path by default
//! (`ServerConfig::zero_copy`): a worker builds the complete reply
//! frame in a `ReplySink` — the router's sink entry points let the
//! codec kernels write the payload in place — and the loop *adopts* the
//! finished buffer into the connection's `WriteQueue` instead of
//! memcpying it. The `Vec`-serialization path is kept selectable as the
//! differential reference.
//!
//! ## Connection lifecycle
//!
//! Each shard also runs the connection deadlines on a
//! [`TimerWheel`] whose earliest entry becomes the `epoll_wait`
//! timeout: idle connections, slow-loris peers dripping a request
//! frame, and peers that stop reading their replies are shed (the
//! first two with a typed `RespError` notice; a write-stalled peer
//! cannot receive one, so it is closed silently). Graceful drain —
//! the `drain` flag, set by `ServerHandle::shutdown` or a termination
//! signal — stops accepting and *reading*, answers every request that
//! was parsed off the wire, flushes, and only then lets the loop
//! exit; a grace deadline bounds how long a stuck peer can hold
//! shutdown hostage. Worker panics are caught per request
//! ([`std::panic::catch_unwind`]): the offending connection gets an
//! error reply and is closed, every mutex on the path is
//! poison-tolerant, and the worker survives to serve other
//! connections.

use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::buffer::BufferPool;
use super::conn::{Conn, Inbound, Job, Machine};
use super::faults;
use super::frame::{FrameMachine, ReplySink};
use super::http::{
    busy_response, panic_response, respond_clocked, timeout_response, HttpMachine, Protocol,
};
use super::sys::{
    Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLET, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use super::timer::TimerWheel;
use crate::coordinator::backpressure::{ConnLimiter, RateLimiter};
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::state::SessionState;
use crate::coordinator::{Metrics, Router};
use crate::obs::clock::ReqClock;
use crate::obs::recorder::{EventKind, FlightRecorder};
use crate::server::proto::Message;
use crate::server::service::{
    dispatch_clocked, dispatch_into_clocked, idle_timeout_frame, refuse_busy, stall_timeout_frame,
    ServerConfig,
};

/// Slab token of the listening socket.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Slab token of the completion-queue eventfd.
const TOKEN_WAKE: u64 = u64::MAX - 1;

/// Readiness events fetched per `epoll_wait`.
const EVENT_BATCH: usize = 1024;
/// Read scratch shared by every connection (the loop is single-threaded).
const READ_SCRATCH: usize = 64 << 10;

/// Re-evaluation cadence for deadlines whose side conditions are not
/// currently met (e.g. a stalled frame behind an in-flight request):
/// the wheel keeps one entry per connection at most this far out.
pub(crate) const HEARTBEAT: Duration = Duration::from_secs(1);

/// `epoll_wait` cap while draining, so the grace deadline and final
/// flushes are observed promptly even with an empty wheel.
pub(crate) const DRAIN_POLL_MS: i32 = 25;

pub(crate) fn token(idx: usize, epoch: u32) -> u64 {
    ((epoch as u64) << 32) | idx as u64
}

pub(crate) fn token_parts(tok: u64) -> (usize, u32) {
    ((tok & 0xFFFF_FFFF) as usize, (tok >> 32) as u32)
}

/// Sniff an HTTP error status (4xx/5xx) from a finished reply frame, if
/// it is one. The loops record these as flight-recorder events centrally
/// — the status line is `HTTP/1.1 NNN ...`, so the code sits at bytes
/// 9..12 — instead of threading the recorder into the response builder.
pub(crate) fn http_error_status(frame: &[u8]) -> Option<u16> {
    let digits = frame.strip_prefix(b"HTTP/1.1 ")?.get(..3)?;
    if !digits.iter().all(|b| b.is_ascii_digit()) {
        return None;
    }
    let status = (digits[0] - b'0') as u16 * 100
        + (digits[1] - b'0') as u16 * 10
        + (digits[2] - b'0') as u16;
    (status >= 400).then_some(status)
}

/// Poison-tolerant lock. A worker that panicked mid-request may have
/// poisoned a session or queue mutex on its way out; what these
/// mutexes guard is either per-connection state that dies with the
/// connection (the panic path closes it) or a plain queue hand-off, so
/// later lockers take the inner value instead of wedging the shard on
/// an `unwrap`.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Peer address for the HTTP rate limiter's per-client buckets. A
/// socket that cannot report one (already reset) falls into a shared
/// bucket rather than being refused outright.
pub(crate) fn peer_ip(stream: &TcpStream) -> IpAddr {
    stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or(IpAddr::V4(Ipv4Addr::UNSPECIFIED))
}

/// Over-cap refusal on an HTTP listener: a one-shot `503` instead of
/// the native busy frame. Best effort, like its native twin — the
/// socket closes on drop either way.
pub(crate) fn refuse_busy_http(mut stream: TcpStream, limiter: &ConnLimiter) {
    let reply = busy_response(limiter.open(), limiter.max());
    let _ = stream.write_all(&reply);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// One request headed for the worker pool. Carries its shard's
/// completion queue and eventfd so the shared workers can route the
/// reply back to whichever reactor owns the connection.
pub(crate) struct WorkItem {
    pub(crate) token: u64,
    pub(crate) job: Job,
    pub(crate) session: Arc<Mutex<SessionState>>,
    pub(crate) done: Arc<Mutex<Vec<Completion>>>,
    pub(crate) wake: Arc<EventFd>,
    /// A recycled buffer from the shard's pool for the reply sink
    /// (empty on the `Vec` path), closing the allocation loop: adopt's
    /// spare buffers return to the pool, the pool feeds the next
    /// reply's sink.
    pub(crate) buf: Vec<u8>,
    /// The request's stage clock (parse-stamped by the loop); the
    /// worker stamps dequeue and the dispatch path stamps kernel/sink.
    pub(crate) clock: ReqClock,
}

/// One executed request headed back to its loop. `frame = None` marks a
/// reply that could not be framed (oversized) — fatal for the
/// connection, matching the blocking transport's behaviour.
/// `close_after` delivers the frame and then closes (the panic path:
/// one error reply, then the connection is gone).
pub(crate) struct Completion {
    pub(crate) token: u64,
    pub(crate) frame: Option<Vec<u8>>,
    pub(crate) close_after: bool,
    /// The request's stage clock, returned so the loop can record
    /// queue/kernel/sink durations and park it on the write queue for
    /// flush attribution.
    pub(crate) clock: ReqClock,
    /// The handler panicked serving this request (the frame is the
    /// error notice) — recorded as a flight-recorder event.
    pub(crate) panicked: bool,
}

/// Handles the spawned transport threads + each loop's wakeup fd.
pub(crate) struct NetServer {
    pub threads: Vec<JoinHandle<()>>,
    pub wakes: Vec<Arc<EventFd>>,
}

/// Spawn one readiness loop per listener (the reactor shards) plus the
/// shared worker pool. The caller keeps `stop` (hard abort) and
/// `drain` (graceful: answer parsed requests, then exit) and signals
/// every wake fd after flipping either; the workers exit once all
/// loops have dropped their work senders.
pub(crate) fn spawn(
    router: Arc<Router>,
    config: &ServerConfig,
    listeners: Vec<(TcpListener, Protocol)>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) -> std::io::Result<NetServer> {
    let limiter = ConnLimiter::new(config.max_connections);
    // One token table across every shard: a client hashing onto a
    // different reactor must not get a fresh rate budget.
    let rate = RateLimiter::new(config.rate_limit);
    let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let metrics = router.metrics().clone();
    // A fresh serve starts a fresh per-shard breakdown; without this a
    // router re-served after shutdown would report dead shards forever.
    // (The flight-recorder registry self-prunes: its entries are weak
    // and die with each shard's reactor loop.)
    metrics.reset_shards();

    let mut threads = Vec::new();
    let mut wakes: Vec<Arc<EventFd>> = Vec::new();
    let mut built = Ok(());
    for (shard_id, listener) in listeners.into_iter().enumerate() {
        let spawned = spawn_shard(
            shard_id, listener, config, &metrics, &limiter, &rate, &work_tx, &stop, &drain,
        );
        match spawned {
            Ok((thread, wake)) => {
                threads.push(thread);
                wakes.push(wake);
            }
            Err(e) => {
                built = Err(e);
                break;
            }
        }
    }
    // Only the loops may hold work senders: the workers' exit condition
    // is every sender dropping when the loops stop.
    drop(work_tx);
    let zero_copy = config.zero_copy;
    if built.is_ok() {
        for i in 0..config.net_workers.max(1) {
            let rx = work_rx.clone();
            let router = router.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("b64simd-net-worker-{i}"))
                .spawn(move || worker_loop(rx, router, zero_copy));
            match spawned {
                Ok(t) => threads.push(t),
                Err(e) => {
                    built = Err(e);
                    break;
                }
            }
        }
    }
    if let Err(e) = built {
        // Unwind whatever did spawn before the failure — loop threads
        // and worker threads alike — so no reactor keeps the listeners
        // bound behind a failed `serve`.
        stop.store(true, Ordering::SeqCst);
        for w in &wakes {
            w.signal();
        }
        for t in threads {
            let _ = t.join();
        }
        return Err(e);
    }
    Ok(NetServer { threads, wakes })
}

/// Set up one reactor shard: its epoll instance, wake fd, completion
/// queue and loop thread.
#[allow(clippy::too_many_arguments)]
fn spawn_shard(
    shard_id: usize,
    listener: (TcpListener, Protocol),
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    limiter: &Arc<ConnLimiter>,
    rate: &Option<Arc<RateLimiter>>,
    work_tx: &mpsc::Sender<WorkItem>,
    stop: &Arc<AtomicBool>,
    drain: &Arc<AtomicBool>,
) -> std::io::Result<(JoinHandle<()>, Arc<EventFd>)> {
    let (listener, protocol) = listener;
    listener.set_nonblocking(true)?;
    let epoll = Epoll::new()?;
    let wake = Arc::new(EventFd::new()?);
    epoll.add(listener.as_raw_fd(), EPOLLIN | EPOLLET, TOKEN_LISTENER)?;
    epoll.add(wake.raw(), EPOLLIN | EPOLLET, TOKEN_WAKE)?;
    let recorder = Arc::new(FlightRecorder::new(format!("epoll-{shard_id}")));
    crate::obs::recorder::register(&recorder);
    let lp = Loop {
        epoll,
        listener: Some(listener),
        protocol,
        recorder,
        rate: rate.clone(),
        wake: wake.clone(),
        metrics: metrics.clone(),
        shard: metrics.register_shard(),
        limiter: limiter.clone(),
        max_streams: config.max_streams_per_connection,
        zero_copy: config.zero_copy,
        conns: Vec::new(),
        epochs: Vec::new(),
        free: Vec::new(),
        pool: BufferPool::new(2048, 256 << 10),
        scratch: vec![0u8; READ_SCRATCH],
        work_tx: work_tx.clone(),
        completions: Arc::new(Mutex::new(Vec::new())),
        stop: stop.clone(),
        drain: drain.clone(),
        draining: false,
        drain_deadline: None,
        wheel: TimerWheel::new(),
        idle_timeout: config.idle_timeout,
        read_timeout: config.read_timeout,
        write_timeout: config.write_timeout,
        drain_grace: config.drain_grace,
    };
    let thread = std::thread::Builder::new()
        .name(format!("b64simd-net-loop-{shard_id}"))
        .spawn(move || lp.run())?;
    Ok((thread, wake))
}

/// Worker: pull a request, execute it against the router (this is where
/// the batched SIMD work happens, concurrently across workers), push
/// the reply frame onto the owning shard's completion queue, wake that
/// shard. Exits when every loop drops its sending side.
///
/// With `zero_copy` set the reply frame is built in place through a
/// [`ReplySink`] (codec output written directly into the buffer the
/// loop will adopt into the write queue); otherwise the reply `Message`
/// is serialized through `to_frame_bytes`, the differential reference
/// path. A `None` frame (oversized reply) closes the connection either
/// way.
///
/// Each request runs under [`std::panic::catch_unwind`]: a panicking
/// handler costs exactly its own connection — the peer gets a typed
/// error reply, the connection closes — never the worker thread (and
/// with it a share of every shard's dispatch capacity).
pub(crate) fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<WorkItem>>>,
    router: Arc<Router>,
    zero_copy: bool,
) {
    loop {
        // Holding the lock across `recv` just serializes the hand-off,
        // not the work: the lock drops as soon as an item arrives.
        let item = { lock_clean(&rx).recv() };
        let Ok(WorkItem { token, job, session, done, wake, buf, clock }) = item else { break };
        clock.stamp_dequeue();
        let (frame, close_after, panicked) = match job {
            Job::Native(msg) => {
                let id = msg.request_id();
                let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    if zero_copy {
                        let mut sink = ReplySink::with_buf(buf);
                        let framed = {
                            let mut session = lock_clean(&session);
                            dispatch_into_clocked(msg, &router, &mut session, &mut sink, Some(&clock))
                        };
                        framed.ok().map(|()| sink.into_buf())
                    } else {
                        drop(buf); // empty on this path
                        let reply = {
                            let mut session = lock_clean(&session);
                            dispatch_clocked(msg, &router, &mut session, Some(&clock))
                        };
                        let frame = reply.to_frame_bytes().ok();
                        clock.stamp_sink();
                        frame
                    }
                }));
                match outcome {
                    Ok(frame) => (frame, false, false),
                    Err(_) => {
                        Metrics::inc(&router.metrics().worker_panics, 1);
                        let reply = Message::RespError {
                            id,
                            message: "internal error: request handler panicked".to_string(),
                        };
                        (reply.to_frame_bytes().ok(), true, true)
                    }
                }
            }
            // HTTP always builds the response in the pooled buffer —
            // the reply *is* wire bytes either way, so there is no
            // `Vec`-serialization differential path to preserve.
            Job::Http(work) => {
                let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    let mut session = lock_clean(&session);
                    respond_clocked(work, &router, &mut session, buf, Some(&clock))
                }));
                match outcome {
                    Ok((frame, close)) => (Some(frame), close, false),
                    Err(_) => {
                        Metrics::inc(&router.metrics().worker_panics, 1);
                        (Some(panic_response()), true, true)
                    }
                }
            }
        };
        lock_clean(&done).push(Completion { token, frame, close_after, clock, panicked });
        wake.signal();
    }
}

/// One single-threaded readiness loop (a reactor shard).
struct Loop {
    epoll: Epoll,
    /// Dropped (closed) when drain begins, so the kernel stops routing
    /// new connections to this shard's `SO_REUSEPORT` bucket.
    listener: Option<TcpListener>,
    /// Wire protocol of every connection accepted from this listener.
    protocol: Protocol,
    /// This shard's flight recorder (registered in the process-wide
    /// registry for `/debug/trace` and SIGUSR1 dumps).
    recorder: Arc<FlightRecorder>,
    /// Per-client token buckets for the HTTP gateway (`None` = off or a
    /// native shard); shared across shards.
    rate: Option<Arc<RateLimiter>>,
    wake: Arc<EventFd>,
    metrics: Arc<Metrics>,
    /// This shard's slice of the metrics (globals stay the roll-up).
    shard: Arc<ShardMetrics>,
    /// Connection cap shared across every shard.
    limiter: Arc<ConnLimiter>,
    max_streams: usize,
    /// Reply path: pop a pooled sink buffer per request when true.
    zero_copy: bool,
    /// Connection slab, indexed by the token's low 32 bits.
    conns: Vec<Option<Conn>>,
    /// Slot generations (guard against stale tokens after reuse).
    epochs: Vec<u32>,
    /// Vacant slab slots.
    free: Vec<usize>,
    pool: BufferPool,
    scratch: Vec<u8>,
    work_tx: mpsc::Sender<WorkItem>,
    completions: Arc<Mutex<Vec<Completion>>>,
    stop: Arc<AtomicBool>,
    /// Graceful-shutdown request flag (shared with `ServerHandle`).
    drain: Arc<AtomicBool>,
    /// This loop has observed `drain` and is winding down.
    draining: bool,
    /// Force-close whatever is still open at this point.
    drain_deadline: Option<Instant>,
    /// Connection deadlines; earliest entry = `epoll_wait` timeout.
    wheel: TimerWheel,
    idle_timeout: Duration,
    read_timeout: Duration,
    write_timeout: Duration,
    drain_grace: Duration,
}

impl Loop {
    fn run(mut self) {
        crate::obs::recorder::set_thread_recorder(Some(self.recorder.clone()));
        let mut events = vec![EpollEvent::zeroed(); EVENT_BATCH];
        'events: loop {
            let mut timeout = self.wheel.next_timeout_ms(Instant::now());
            if self.draining {
                timeout = if timeout < 0 { DRAIN_POLL_MS } else { timeout.min(DRAIN_POLL_MS) };
            }
            let n = match self.epoll.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    crate::log_error!("driver", "epoll loop failed: {e}");
                    break 'events;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                break 'events;
            }
            if !self.draining && self.drain.load(Ordering::SeqCst) {
                self.begin_drain();
            }
            for ev in &events[..n] {
                // Copy out of the (packed) record before field access.
                let (mask, data) = { (ev.events, ev.data) };
                match data {
                    TOKEN_WAKE => {
                        // Drain the counter *before* the queue so a
                        // completion pushed mid-drain re-arms the edge.
                        self.wake.drain();
                        self.drain_completions();
                    }
                    TOKEN_LISTENER => self.accept_burst(),
                    tok => self.conn_event(tok, mask),
                }
            }
            self.service_timers();
            if self.draining {
                if self.drain_deadline.map_or(false, |d| Instant::now() >= d) {
                    // Grace expired: whatever is still open gets cut.
                    for idx in 0..self.conns.len() {
                        if self.conns[idx].is_some() {
                            self.close(idx);
                        }
                    }
                }
                if self.conns.iter().all(|c| c.is_none()) {
                    break 'events; // every accepted request answered
                }
            }
        }
        // Shutdown: tear every connection down so the open-conns gauge
        // and the buffer pool reflect reality before the loop thread
        // joins.
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                self.close(idx);
            }
        }
    }

    /// Flip into drain mode: stop accepting (the listener fd closes, so
    /// the kernel stops hashing new connections here), start the grace
    /// clock, and close every already-quiescent connection. Connections
    /// with a request in flight, queued in the inbox or replies still
    /// flushing stay until answered; their sockets are read no further.
    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_deadline = Some(Instant::now() + self.drain_grace);
        let open = self.conns.iter().filter(|c| c.is_some()).count() as u64;
        self.recorder.record(EventKind::Drain, 0, open);
        crate::log_info!("driver", "shard {} draining ({open} connections open)", self.recorder.label());
        if let Some(listener) = self.listener.take() {
            let _ = self.epoll.del(listener.as_raw_fd());
        }
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                self.pump(idx); // flush; pump closes the drained
            }
        }
    }

    /// Accept until `WouldBlock` (edge-triggered listener). Per-connection
    /// failures (a client that reset while queued in the backlog —
    /// `ECONNABORTED` and friends) must not end the burst: the listener
    /// only re-edges on a *new* connection, so breaking early would
    /// strand the established connections still behind the aborted one.
    /// Persistent failures (fd exhaustion) are bounded so the loop
    /// cannot spin forever on an error `accept` does not consume.
    fn accept_burst(&mut self) {
        let mut hard_errors = 0;
        loop {
            let Some(listener) = self.listener.as_ref() else { return };
            match faults::accept(listener) {
                Ok((stream, _)) => self.admit(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted | std::io::ErrorKind::ConnectionAborted
                    ) =>
                {
                    continue
                }
                Err(_) => {
                    hard_errors += 1;
                    if hard_errors > 64 {
                        break; // e.g. EMFILE: back off until the next edge
                    }
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        let Some(permit) = self.limiter.try_acquire() else {
            Metrics::inc(&self.metrics.conns_refused, 1);
            match self.protocol {
                Protocol::Native => refuse_busy(stream, &self.limiter),
                Protocol::Http => refuse_busy_http(stream, &self.limiter),
            }
            return;
        };
        if stream.set_nonblocking(true).is_err() {
            return; // permit drops, socket closes
        }
        stream.set_nodelay(true).ok();
        let idx = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.epochs.push(0);
            self.conns.len() - 1
        });
        let epoch = self.epochs[idx];
        let machine = match self.protocol {
            Protocol::Native => Machine::Native(FrameMachine::new(self.pool.get())),
            Protocol::Http => Machine::Http(Box::new(HttpMachine::new(
                self.pool.get(),
                self.rate.clone(),
                peer_ip(&stream),
            ))),
        };
        let conn = Conn::new(stream, epoch, self.max_streams, &mut self.pool, permit, machine);
        let interest = EPOLLIN | EPOLLOUT | EPOLLRDHUP | EPOLLET;
        if self
            .epoll
            .add(conn.stream.as_raw_fd(), interest, token(idx, epoch))
            .is_err()
        {
            conn.teardown(&mut self.pool);
            self.free.push(idx);
            return;
        }
        Metrics::inc(&self.metrics.conns_accepted, 1);
        Metrics::inc(&self.metrics.conns_open, 1);
        Metrics::inc(&self.shard.conns_accepted, 1);
        Metrics::inc(&self.shard.conns_open, 1);
        self.recorder.record(
            EventKind::Accept,
            token(idx, epoch),
            self.shard.conns_open.load(Ordering::Relaxed),
        );
        self.conns[idx] = Some(conn);
        self.reschedule(idx, Instant::now());
        self.pump(idx);
    }

    fn conn_event(&mut self, tok: u64, mask: u32) {
        let (idx, epoch) = token_parts(tok);
        if idx >= self.conns.len() || self.epochs[idx] != epoch {
            return; // stale: the slot was closed (and possibly reused)
        }
        let Some(conn) = self.conns[idx].as_mut() else { return };
        if mask & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0 {
            // Latch readability; HUP/ERR also surface through read().
            conn.readable = true;
        }
        // EPOLLOUT needs no flag: pump always starts with a flush.
        self.pump(idx);
    }

    /// Drive one connection as far as it will go: flush pending writes,
    /// parse buffered frames, dispatch if idle, read while the socket
    /// and the backpressure caps allow, and close once a finished peer
    /// is fully answered. While draining, parsing and reading stop —
    /// "accepted" means parsed, and drain answers exactly the accepted
    /// requests — and a connection closes as soon as it is drained.
    fn pump(&mut self, idx: usize) {
        loop {
            let Some(conn) = self.conns[idx].as_mut() else { return };
            let now = Instant::now();
            // 1. Writes first: draining the socket lifts the write-side
            //    backpressure check below.
            let flushed = {
                let mut w = faults::wrap_write(&mut conn.stream);
                conn.write.write_to(&mut w)
            };
            match flushed {
                Ok(n) => {
                    if n > 0 {
                        Metrics::inc(&self.metrics.net_bytes_out, n as u64);
                        conn.last_activity = now;
                        conn.write_progress = now;
                        // Replies whose bytes have now fully drained:
                        // close out their stage clocks.
                        for clock in conn.write.take_flushed() {
                            self.recorder.record(
                                EventKind::Reply,
                                token(idx, conn.epoch),
                                clock.total_us_now(),
                            );
                            self.metrics.record_clock_flush(&clock, "driver");
                        }
                    } else if conn.write.pending() == 0 {
                        // An empty queue is never "stalled".
                        conn.write_progress = now;
                    }
                }
                Err(_) => return self.close(idx),
            }
            // 2. Peel complete frames into the inbox.
            if !conn.corrupt && !self.draining {
                match conn.parse_into_inbox() {
                    Ok(parsed) => {
                        if parsed > 0 {
                            Metrics::inc(&self.metrics.frames_in, parsed as u64);
                            Metrics::inc(&self.shard.frames_in, parsed as u64);
                            self.recorder.record(
                                EventKind::Frame,
                                token(idx, conn.epoch),
                                parsed as u64,
                            );
                        }
                        // Frame-granularity progress for the read-stall
                        // deadline: the clock starts when a partial
                        // frame sits at the head of the accumulator and
                        // only a *complete* frame resets it, so a
                        // slow-loris peer dripping bytes cannot refresh
                        // its own deadline.
                        if conn.machine.buffered() == 0 {
                            conn.frame_start = None;
                        } else if parsed > 0 || conn.frame_start.is_none() {
                            conn.frame_start = Some(now);
                        }
                    }
                    // Protocol error: poison the stream. Requests parsed
                    // *before* the bad frame still get their replies
                    // (the threaded transport answers each frame before
                    // reading the next — parity demands the same), then
                    // the drained connection closes below.
                    Err(_) => {
                        conn.corrupt = true;
                        conn.eof = true;
                        conn.readable = false;
                    }
                }
            }
            // 3. Dispatch the next request if none is in flight (drain
            //    included: accepted requests are answered to the last).
            if !conn.busy {
                if let Some(Inbound { mut job, clock }) = conn.inbox.pop_front() {
                    // Sample the drain flag as the job leaves the inbox,
                    // not when it was parsed: responses during drain
                    // must advertise closure.
                    if let Job::Http(w) = &mut job {
                        w.draining = self.draining;
                    }
                    conn.busy = true;
                    self.recorder
                        .record(EventKind::Dispatch, token(idx, conn.epoch), 0);
                    // HTTP replies are always built in a pooled buffer;
                    // `zero_copy` only selects the native differential
                    // serialization path.
                    let pooled = self.zero_copy || conn.is_http();
                    let buf = if pooled { self.pool.get() } else { Vec::new() };
                    let item = WorkItem {
                        token: token(idx, conn.epoch),
                        job,
                        session: conn.session.clone(),
                        done: self.completions.clone(),
                        wake: self.wake.clone(),
                        buf,
                        clock,
                    };
                    if self.work_tx.send(item).is_err() {
                        return self.close(idx); // shutting down
                    }
                }
            }
            // 4. Read while the latch and the caps allow; a draining
            //    loop takes nothing more off the wire.
            if conn.wants_read() && !self.draining {
                match faults::read_stream(&mut conn.stream, &mut self.scratch) {
                    Ok(0) => {
                        conn.eof = true;
                        conn.readable = false;
                    }
                    Ok(n) => {
                        Metrics::inc(&self.metrics.net_bytes_in, n as u64);
                        conn.machine.push(&self.scratch[..n]);
                        conn.last_activity = now;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        conn.readable = false;
                        break;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return self.close(idx),
                }
                continue; // new bytes (or EOF): reparse and re-dispatch
            }
            break;
        }
        let Some(conn) = self.conns[idx].as_ref() else { return };
        if (conn.eof || self.draining) && conn.drained() {
            self.close(idx);
        }
    }

    /// Pop due wheel entries and act on connection deadlines. Stale
    /// entries (closed or reused slots) fall to the epoch check; live
    /// connections re-schedule at their recomputed next deadline, so
    /// the wheel carries exactly one live entry per connection.
    fn service_timers(&mut self) {
        let now = Instant::now();
        while let Some(tok) = self.wheel.pop_due(now) {
            let (idx, epoch) = token_parts(tok);
            if idx >= self.conns.len() || self.epochs[idx] != epoch || self.conns[idx].is_none() {
                continue;
            }
            self.check_deadlines(idx, now);
            self.reschedule(idx, now);
        }
    }

    /// Evaluate the idle / read-stall / write-stall deadlines for one
    /// connection whose wheel entry came due.
    fn check_deadlines(&mut self, idx: usize, now: Instant) {
        // Retry a pending flush first: an injected EAGAIN leaves no
        // kernel EPOLLOUT edge behind it, so the heartbeat is what
        // re-drives the write queue under fault injection.
        if self.conns[idx].as_ref().map_or(false, |c| c.write.pending() > 0) {
            self.pump(idx);
        }
        let Some(conn) = self.conns[idx].as_mut() else { return };
        // Write stall: the peer stopped reading while replies are
        // queued. Nothing can be said to a peer that will not read —
        // close silently.
        if self.write_timeout != Duration::ZERO
            && conn.write.pending() > 0
            && now >= conn.write_progress + self.write_timeout
        {
            Metrics::inc(&self.metrics.timeouts, 1);
            self.recorder.record(
                EventKind::Timeout,
                token(idx, conn.epoch),
                conn.write.pending() as u64,
            );
            crate::log_debug!("driver", "write-stalled peer closed (pending={})", conn.write.pending());
            return self.close(idx);
        }
        if conn.corrupt || conn.eof {
            return; // already on its way out
        }
        // Read stall (slow loris): the partial frame at the head of the
        // accumulator has not completed within the window. Evaluated
        // only once prior requests are answered, so the error notice
        // cannot overtake a pending reply (the heartbeat re-checks
        // after the backlog clears).
        let read_stalled = self.read_timeout != Duration::ZERO
            && conn.drained()
            && conn.frame_start.map_or(false, |t| now >= t + self.read_timeout);
        // Idle: quiescent — nothing in flight, nothing buffered — for
        // the whole idle window.
        let idle = self.idle_timeout != Duration::ZERO
            && conn.drained()
            && conn.frame_start.is_none()
            && now >= conn.last_activity + self.idle_timeout;
        if read_stalled || idle {
            Metrics::inc(&self.metrics.timeouts, 1);
            self.recorder
                .record(EventKind::Timeout, token(idx, conn.epoch), 0);
            // Same notice semantics on both protocols, different
            // encodings: a native `0x82` frame vs an HTTP `408`.
            let frame = if conn.is_http() {
                Some(timeout_response(if read_stalled {
                    "timeout: request frame stalled"
                } else {
                    "timeout: idle connection"
                }))
            } else if read_stalled {
                stall_timeout_frame()
            } else {
                idle_timeout_frame()
            };
            if let Some(frame) = frame {
                conn.write.push_bytes(&frame);
                conn.write_progress = now;
                Metrics::inc(&self.metrics.frames_out, 1);
                Metrics::inc(&self.shard.frames_out, 1);
            }
            // Poison like a bad frame: no more reads or parses; close
            // once the notice flushes (the write-stall deadline still
            // bounds a peer that refuses to take it).
            conn.corrupt = true;
            conn.eof = true;
            conn.readable = false;
            self.pump(idx);
        }
    }

    /// Schedule this connection's next wheel entry: the nearest
    /// *currently applicable* deadline, else a coarse heartbeat that
    /// re-evaluates once conditions change (e.g. a busy connection
    /// drains and its stalled frame becomes actionable). Deadlines only
    /// move later, so activity never has to touch the wheel.
    fn reschedule(&mut self, idx: usize, now: Instant) {
        if self.idle_timeout == Duration::ZERO
            && self.read_timeout == Duration::ZERO
            && self.write_timeout == Duration::ZERO
        {
            return; // all deadlines disabled: no wheel entries at all
        }
        let Some(conn) = self.conns[idx].as_ref() else { return };
        let mut next = now + HEARTBEAT;
        if self.write_timeout != Duration::ZERO && conn.write.pending() > 0 {
            next = next.min(conn.write_progress + self.write_timeout);
        }
        if self.read_timeout != Duration::ZERO && conn.drained() {
            if let Some(t) = conn.frame_start {
                next = next.min(t + self.read_timeout);
            }
        }
        if self.idle_timeout != Duration::ZERO && conn.drained() && conn.frame_start.is_none() {
            next = next.min(conn.last_activity + self.idle_timeout);
        }
        // An applicable deadline at or before `now` would have fired in
        // `check_deadlines`; the clamp only guards clock-edge equality
        // against re-popping in the same `service_timers` pass.
        let next = next.max(now + Duration::from_millis(1));
        self.wheel.schedule(next, token(idx, conn.epoch));
    }

    /// Hand completed replies back to their connections and keep those
    /// connections moving.
    fn drain_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *lock_clean(&self.completions));
        for c in done {
            let (idx, epoch) = token_parts(c.token);
            if idx >= self.conns.len() || self.epochs[idx] != epoch {
                continue; // connection closed while the request ran
            }
            let Some(conn) = self.conns[idx].as_mut() else { continue };
            conn.busy = false;
            conn.last_activity = Instant::now();
            if c.panicked {
                self.recorder.record(EventKind::Panic, c.token, 0);
                crate::log_error!("driver", "request handler panicked; closing connection");
            }
            // Queue/kernel/sink stage durations are known as soon as the
            // worker hands the reply back; only the flush stage waits
            // for the socket (recorded when the write queue releases the
            // clock in `pump`).
            self.metrics.record_clock_stages(&c.clock);
            match c.frame {
                Some(frame) if frame.is_empty() => {
                    // Nothing to send (an HTTP stream chunk swallowed
                    // after an error, or a truncated-response close):
                    // recycle the sink buffer without touching the
                    // write queue or the frame counters.
                    self.pool.put(frame);
                    if c.close_after {
                        conn.inbox.clear();
                        conn.corrupt = true;
                        conn.eof = true;
                        conn.readable = false;
                    }
                }
                Some(frame) => {
                    if let Some(status) = http_error_status(&frame) {
                        self.recorder
                            .record(EventKind::HttpError, c.token, status as u64);
                    }
                    // Zero-copy hand-off: a drained queue takes the
                    // frame buffer whole; either way one spare buffer
                    // comes back for the pool. The clock parks *after*
                    // adopt so its due mark covers the adopted bytes.
                    let spare = conn.write.adopt(frame);
                    self.pool.put(spare);
                    conn.write.push_clock(c.clock);
                    Metrics::inc(&self.metrics.frames_out, 1);
                    Metrics::inc(&self.shard.frames_out, 1);
                    if c.close_after {
                        // Deliver the final reply (a panic notice, a
                        // `Connection: close` response, or a drain
                        // notice), then treat the stream as poisoned:
                        // pipelined requests behind it are dropped.
                        conn.inbox.clear();
                        conn.corrupt = true;
                        conn.eof = true;
                        conn.readable = false;
                    }
                }
                None => {
                    self.close(idx);
                    continue;
                }
            }
            self.pump(idx);
        }
    }

    fn close(&mut self, idx: usize) {
        let Some(conn) = self.conns[idx].take() else { return };
        self.epochs[idx] = self.epochs[idx].wrapping_add(1);
        let _ = self.epoll.del(conn.stream.as_raw_fd());
        conn.teardown(&mut self.pool);
        self.free.push(idx);
        Metrics::dec(&self.metrics.conns_open, 1);
        Metrics::dec(&self.shard.conns_open, 1);
    }
}
