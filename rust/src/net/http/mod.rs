//! HTTP/1.1 gateway: a second wire protocol on the same reactors.
//!
//! The paper's motivating workload is text-only web documents — MIME
//! email and HTML/JSON/XML that embed binary as base64 — so the server
//! grows a front door that speaks the web's own protocol. A listener
//! carries a [`Protocol`] tag; accepted connections route to either the
//! native `FrameMachine` or the [`HttpMachine`] here, and both feed the
//! same worker pool, session state and metrics.
//!
//! Layout:
//!
//! * [`machine`] — incremental request parser (torn-read tolerant,
//!   pipelining-aware) producing a stream of [`HttpJob`]s, including a
//!   chunked-transfer *decoder* for streamed request bodies;
//! * [`sink`] — [`HttpSink`], a `ResponseSink` that frames the router's
//!   in-place reply as a chunked HTTP response instead of a native
//!   `0x81` frame;
//! * [`respond`] — routing (`POST /encode|/decode|/datauri`,
//!   `GET /healthz|/metrics`) and response assembly, run on the worker
//!   threads.
//!
//! Request bodies above [`STREAM_THRESHOLD`] (or with
//! `Transfer-Encoding: chunked`) never materialize in one buffer: the
//! machine emits [`HttpJob::StreamBegin`]/[`HttpJob::StreamChunk`]/
//! [`HttpJob::StreamEnd`] and the responder drives the coordinator's
//! `SessionState` streaming codecs, so a decode larger than the native
//! protocol's `MAX_FRAME` completes in bounded memory — the ">256 MiB
//! payloads hit the frame-size wall" item from the roadmap.

pub mod machine;
pub mod respond;
pub mod sink;

pub use machine::HttpMachine;
pub use respond::{busy_response, panic_response, respond, respond_clocked, timeout_response};
pub use sink::HttpSink;

/// Which wire protocol a listener (and every connection accepted from
/// it) speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// The native length-prefixed frame protocol (`docs/PROTOCOL.md`).
    Native,
    /// The HTTP/1.1 gateway.
    Http,
}

/// Buffered bodies are capped here; larger (or chunked) request bodies
/// take the streaming path through the session codecs.
pub const STREAM_THRESHOLD: usize = 4 << 20;

/// Reserved `SessionState` stream id for the HTTP gateway's streamed
/// request body. HTTP/1.1 requests on one connection are strictly
/// sequential, so a single well-known id suffices; it sits at the top
/// of the id space where no native client id can collide (native
/// streams and HTTP never share a connection anyway).
pub const HTTP_STREAM_ID: u64 = u64::MAX;

/// Request method, as far as the gateway cares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// `GET`.
    Get,
    /// `POST`.
    Post,
    /// Anything else (answered `405` on known paths).
    Other,
}

/// One parsed request head (plus the body, when buffered).
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method.
    pub method: Method,
    /// Request path (the target up to `?`), not percent-decoded — the
    /// gateway's routes and parameters are plain ASCII tokens.
    pub path: String,
    /// Query parameters as raw `key=value` pairs, in order, not
    /// percent-decoded.
    pub query: Vec<(String, String)>,
    /// `Content-Type` header value, verbatim.
    pub content_type: Option<String>,
    /// Whether the connection must close after this response
    /// (`Connection: close`, or an HTTP/1.0 request without
    /// `keep-alive`).
    pub close: bool,
    /// The buffered body ([`HttpJob::Request`] only; empty on the
    /// streaming path).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First query parameter named `key`, if any.
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// One unit of work parsed off an HTTP connection. Everything —
/// including protocol errors — flows through the connection inbox as a
/// job, so pipelined responses keep request order.
#[derive(Debug)]
pub enum HttpJob {
    /// A complete request with its body buffered.
    Request(HttpRequest),
    /// Head of a streamed-body request (body exceeds
    /// [`STREAM_THRESHOLD`] or uses chunked transfer); `body` is empty.
    StreamBegin(HttpRequest),
    /// A slice of a streamed request body.
    StreamChunk(Vec<u8>),
    /// End of a streamed request body. `close` carries the request
    /// head's connection disposition.
    StreamEnd {
        /// Close the connection once the response is flushed.
        close: bool,
    },
    /// A response decided during parsing: `100 Continue` interim
    /// replies, `429` rate-limit refusals, and `400/431/505` parse
    /// errors.
    Immediate {
        /// HTTP status code.
        status: u16,
        /// Response body (sent as `text/plain`; ignored for `100`).
        message: String,
        /// Close the connection once the response is flushed.
        close: bool,
    },
}

/// An [`HttpJob`] plus the drain flag sampled when the job left the
/// inbox — during graceful shutdown responses carry
/// `Connection: close` and `/healthz` flips to `503`.
#[derive(Debug)]
pub struct HttpWork {
    /// The parsed job.
    pub job: HttpJob,
    /// Server is draining: advertise closure, fail health checks.
    pub draining: bool,
}
