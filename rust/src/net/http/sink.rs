//! [`HttpSink`]: the router's in-place reply path, framed as HTTP.
//!
//! `Router::process_into` writes reply payloads straight into the
//! socket-bound buffer through the `ResponseSink` trait; the native
//! transport's `ReplySink` frames them as `0x81` data frames, this sink
//! frames the *same* in-place bytes as a `200 OK` chunked response. The
//! whole payload becomes one chunk: the head ends with an 8-hex-digit
//! chunk-size placeholder (`00000000\r\n` — leading zeros are valid
//! chunk sizes per RFC 7230 §4.1) that [`HttpSink::commit`] backfills
//! once the payload length is known, so commit stays O(1) with no
//! memmove of a multi-megabyte body. Chunked framing is used even
//! though the length is known at commit time because the router may
//! abort and replace the frame mid-write — a `Content-Length` head
//! would have to be rewritten, a chunked head is simply truncated.

use crate::coordinator::{FrameTooLarge, ResponseSink};

/// Width of the backfilled chunk-size field.
const SIZE_DIGITS: usize = 8;

/// Placeholder bytes between head and payload: 8 hex digits + CRLF.
const PLACEHOLDER: usize = SIZE_DIGITS + 2;

/// A `ResponseSink` producing one chunked HTTP/1.1 response in a
/// reusable connection buffer.
pub struct HttpSink {
    buf: Vec<u8>,
    /// Offset where this response began (everything before is earlier
    /// pipelined output).
    start: usize,
    /// Offset of the first payload byte (just past the placeholder).
    payload_start: usize,
    /// `Content-Type` for the data reply.
    content_type: &'static str,
    /// Response-body prefix written before the router's payload (the
    /// `data:<mime>;base64,` head of a data URI).
    prefix: Option<String>,
    /// Advertise `Connection: close` (request asked, or draining).
    close: bool,
}

impl HttpSink {
    /// A sink appending to `buf`. `prefix` bytes, when present, are
    /// emitted as payload ahead of whatever the router writes.
    pub fn new(
        buf: Vec<u8>,
        content_type: &'static str,
        close: bool,
        prefix: Option<String>,
    ) -> Self {
        let start = buf.len();
        Self { buf, start, payload_start: start, content_type, prefix, close }
    }

    /// Recover the buffer (now holding the complete response).
    pub fn into_buf(self) -> Vec<u8> {
        self.buf
    }
}

impl ResponseSink for HttpSink {
    fn begin_data(&mut self, _id: u64) {
        self.buf.extend_from_slice(b"HTTP/1.1 200 OK\r\nContent-Type: ");
        self.buf.extend_from_slice(self.content_type.as_bytes());
        self.buf.extend_from_slice(b"\r\nTransfer-Encoding: chunked\r\n");
        if self.close {
            self.buf.extend_from_slice(b"Connection: close\r\n");
        }
        self.buf.extend_from_slice(b"\r\n00000000\r\n");
        self.payload_start = self.buf.len();
        if let Some(prefix) = &self.prefix {
            self.buf.extend_from_slice(prefix.as_bytes());
        }
    }

    fn grow(&mut self, n: usize) -> &mut [u8] {
        let at = self.buf.len();
        self.buf.resize(at + n, 0);
        &mut self.buf[at..]
    }

    fn mark(&self) -> usize {
        self.buf.len()
    }

    fn truncate_to(&mut self, mark: usize) {
        self.buf.truncate(mark);
    }

    fn commit(&mut self) -> Result<(), FrameTooLarge> {
        let n = self.buf.len() - self.payload_start;
        if n >= 1 << (4 * SIZE_DIGITS) {
            // Payload would not fit the fixed-width size field. A
            // buffered body is capped far below this; treat it like the
            // native path's oversized frame (connection-fatal).
            self.buf.truncate(self.start);
            return Err(FrameTooLarge(n));
        }
        if n == 0 {
            // `chunked` forbids an empty data chunk (it terminates the
            // body), so drop the placeholder and go straight to the
            // terminal chunk.
            self.buf.truncate(self.payload_start - PLACEHOLDER);
        } else {
            let at = self.payload_start - PLACEHOLDER;
            for i in 0..SIZE_DIGITS {
                let nibble = (n >> (4 * (SIZE_DIGITS - 1 - i))) & 0xF;
                self.buf[at + i] = b"0123456789abcdef"[nibble];
            }
            self.buf.extend_from_slice(b"\r\n");
        }
        self.buf.extend_from_slice(b"0\r\n\r\n");
        Ok(())
    }

    fn abort(&mut self) {
        self.buf.truncate(self.start);
    }

    fn error_reply(&mut self, _id: u64, message: &str) -> Result<(), FrameTooLarge> {
        self.buf.truncate(self.start);
        // Admission rejections ("busy: ...") are retryable server
        // pressure; everything else is a fault of the request payload.
        let (status, reason) = if message.starts_with("busy") {
            (503, "Service Unavailable")
        } else {
            (422, "Unprocessable Entity")
        };
        super::respond::write_simple(&mut self.buf, status, reason, message, self.close);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn committed(payload: &[u8], prefix: Option<&str>) -> Vec<u8> {
        let mut sink = HttpSink::new(Vec::new(), "text/plain", false, prefix.map(String::from));
        sink.begin_data(7);
        sink.grow(payload.len()).copy_from_slice(payload);
        sink.commit().unwrap();
        sink.into_buf()
    }

    #[test]
    fn single_chunk_framing_with_backfilled_size() {
        let out = committed(b"aGVsbG8=", None);
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n00000008\r\naGVsbG8=\r\n0\r\n\r\n"), "{text}");
    }

    #[test]
    fn prefix_counts_as_payload() {
        let out = committed(b"AAAA", Some("data:text/plain;base64,"));
        let text = String::from_utf8(out).unwrap();
        // 23 prefix bytes + 4 payload = 0x1b.
        assert!(text.ends_with("0000001b\r\ndata:text/plain;base64,AAAA\r\n0\r\n\r\n"), "{text}");
    }

    #[test]
    fn empty_payload_has_no_empty_chunk() {
        let out = committed(b"", None);
        let text = String::from_utf8(out).unwrap();
        // An empty data chunk would terminate the body early; the
        // placeholder must vanish entirely.
        assert!(text.ends_with("\r\n\r\n0\r\n\r\n"), "{text}");
        assert!(!text.contains("00000000"), "{text}");
    }

    #[test]
    fn truncate_trims_overreserved_payload() {
        let mut sink = HttpSink::new(Vec::new(), "application/octet-stream", false, None);
        sink.begin_data(1);
        let m = sink.mark();
        sink.grow(64);
        sink.truncate_to(m + 3);
        let end = sink.mark();
        sink.buf[end - 3..].copy_from_slice(b"abc");
        sink.commit().unwrap();
        let text = String::from_utf8(sink.into_buf()).unwrap();
        assert!(text.ends_with("00000003\r\nabc\r\n0\r\n\r\n"), "{text}");
    }

    #[test]
    fn abort_then_error_replaces_frame_in_place() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"PRIOR");
        let mut sink = HttpSink::new(buf, "text/plain", false, None);
        sink.begin_data(1);
        sink.grow(100);
        sink.abort();
        sink.error_reply(1, "invalid byte 0x21 at offset 3").unwrap();
        let out = sink.into_buf();
        assert_eq!(&out[..5], b"PRIOR", "earlier pipelined output untouched");
        let text = String::from_utf8_lossy(&out[5..]);
        assert!(text.starts_with("HTTP/1.1 422 Unprocessable Entity\r\n"), "{text}");
        assert!(text.contains("invalid byte 0x21 at offset 3"), "{text}");
    }

    #[test]
    fn busy_maps_to_503() {
        let mut sink = HttpSink::new(Vec::new(), "text/plain", true, None);
        sink.error_reply(1, "busy: 4096 requests in flight (limit 4096)").unwrap();
        let text = String::from_utf8(sink.into_buf()).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn close_flag_advertises_connection_close() {
        let mut sink = HttpSink::new(Vec::new(), "text/plain", true, None);
        sink.begin_data(1);
        sink.grow(1)[0] = b'x';
        sink.commit().unwrap();
        let text = String::from_utf8(sink.into_buf()).unwrap();
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }
}
