//! Incremental HTTP/1.1 request parser.
//!
//! [`HttpMachine`] is the gateway twin of the native `FrameMachine`:
//! bytes go in via [`HttpMachine::push`] exactly as the socket delivers
//! them (torn anywhere, pipelined back-to-back), parsed jobs come out
//! via [`HttpMachine::next_job`]. The parser is a byte-offset state
//! machine over one internal buffer — no line splitting allocations on
//! the hot path, lazy compaction, and a scan hint so a slow-trickling
//! header is not re-scanned from the start on every read.
//!
//! Everything the parser decides — including `400/431/505` protocol
//! errors, `429` rate-limit refusals and `100 Continue` interim
//! replies — is emitted as an [`HttpJob`] so responses stay in request
//! order on pipelined connections. A protocol error poisons the
//! machine: the error job carries `close` and no further bytes are
//! parsed (HTTP/1.1 framing cannot be trusted past a malformed head).

use std::collections::VecDeque;
use std::net::IpAddr;
use std::sync::Arc;

use super::{HttpJob, HttpRequest, Method, STREAM_THRESHOLD};
use crate::coordinator::backpressure::RateLimiter;

/// Maximum bytes of one request head (request line + headers).
pub const HEADER_CAP: usize = 16 << 10;

/// Maximum bytes of one chunk-size line (hex digits + extensions).
const CHUNK_LINE_CAP: usize = 128;

/// Maximum bytes of one trailer line.
const TRAILER_LINE_CAP: usize = 4 << 10;

/// Consumed-prefix length that triggers buffer compaction.
const COMPACT_AT: usize = 32 << 10;

/// Where the parser is between jobs.
enum State {
    /// Accumulating a request head.
    Headers,
    /// Buffering a `Content-Length` body into the request.
    Body {
        /// The parsed head the body belongs to.
        req: Box<HttpRequest>,
        /// Body bytes still expected.
        remaining: usize,
    },
    /// Swallowing the body of a request already answered (rate-limited).
    Discard {
        /// Body bytes still to swallow.
        remaining: usize,
    },
    /// Relaying a large `Content-Length` body as stream chunks.
    StreamBody {
        /// Body bytes still expected.
        remaining: usize,
        /// The head's connection disposition, for the `StreamEnd` job.
        close: bool,
    },
    /// Decoding a chunked-transfer body.
    Chunked {
        /// Position within the chunk grammar.
        sub: ChunkState,
        /// Swallow instead of emitting (rate-limited request).
        discard: bool,
        /// The head's connection disposition, for the `StreamEnd` job.
        close: bool,
    },
}

/// Position within a chunked-transfer body.
enum ChunkState {
    /// Expecting a `<hex>[;ext]\r\n` size line.
    Size,
    /// Inside a chunk's data.
    Data {
        /// Data bytes left in this chunk.
        remaining: usize,
    },
    /// Expecting the `\r\n` after a chunk's data.
    DataEnd,
    /// Skipping trailer lines up to the empty terminator line.
    Trailer,
}

/// Torn-read-tolerant HTTP/1.1 request parser for one connection.
pub struct HttpMachine {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`.
    pos: usize,
    /// Header scan hint: no head terminator ends at or before this
    /// absolute index, so the next scan resumes here instead of `pos`.
    scan: usize,
    state: State,
    /// Jobs parsed but not yet handed out (a single head can yield two:
    /// `100 Continue` plus the request itself later).
    ready: VecDeque<HttpJob>,
    limiter: Option<Arc<RateLimiter>>,
    peer: IpAddr,
    /// Protocol error emitted; no further parsing.
    dead: bool,
}

impl HttpMachine {
    /// A fresh parser over a (pooled) buffer. `limiter`, when present,
    /// is consulted once per `POST` head against `peer`'s bucket.
    pub fn new(buf: Vec<u8>, limiter: Option<Arc<RateLimiter>>, peer: IpAddr) -> Self {
        Self {
            buf,
            pos: 0,
            scan: 0,
            state: State::Headers,
            ready: VecDeque::new(),
            limiter,
            peer,
            dead: false,
        }
    }

    /// Append bytes exactly as read off the socket.
    pub fn push(&mut self, data: &[u8]) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
            self.scan = 0;
        } else if self.pos >= COMPACT_AT {
            self.buf.drain(..self.pos);
            self.scan = self.scan.saturating_sub(self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(data);
    }

    /// Unconsumed bytes waiting on more input (a torn head or chunk
    /// line). Body bytes are consumed eagerly, so a slow streaming
    /// upload does not look like a stalled frame to the transport's
    /// read-stall timer.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Recover the internal buffer (connection teardown → pool).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// Parse the next job out of the buffered bytes, or `None` when
    /// more input is needed (or the machine is poisoned).
    pub fn next_job(&mut self) -> Option<HttpJob> {
        loop {
            if let Some(job) = self.ready.pop_front() {
                return Some(job);
            }
            if self.dead || !self.step() {
                return None;
            }
        }
    }

    /// Emit a terminal protocol-error response and poison the machine.
    fn fail(&mut self, status: u16, message: &str) -> bool {
        self.ready.push_back(HttpJob::Immediate {
            status,
            message: format!("{message}\n"),
            close: true,
        });
        self.dead = true;
        true
    }

    /// Advance the state machine once. Returns `false` when no progress
    /// is possible without more input.
    fn step(&mut self) -> bool {
        match std::mem::replace(&mut self.state, State::Headers) {
            State::Headers => self.step_headers(),
            State::Body { mut req, mut remaining } => {
                let take = remaining.min(self.buf.len() - self.pos);
                if take == 0 {
                    self.state = State::Body { req, remaining };
                    return false;
                }
                req.body.extend_from_slice(&self.buf[self.pos..self.pos + take]);
                self.pos += take;
                remaining -= take;
                if remaining == 0 {
                    self.ready.push_back(HttpJob::Request(*req));
                } else {
                    self.state = State::Body { req, remaining };
                }
                true
            }
            State::Discard { mut remaining } => {
                let take = remaining.min(self.buf.len() - self.pos);
                if take == 0 {
                    self.state = State::Discard { remaining };
                    return false;
                }
                self.pos += take;
                remaining -= take;
                if remaining > 0 {
                    self.state = State::Discard { remaining };
                }
                true
            }
            State::StreamBody { mut remaining, close } => {
                let take = remaining.min(self.buf.len() - self.pos);
                if take == 0 {
                    self.state = State::StreamBody { remaining, close };
                    return false;
                }
                self.ready
                    .push_back(HttpJob::StreamChunk(self.buf[self.pos..self.pos + take].to_vec()));
                self.pos += take;
                remaining -= take;
                if remaining == 0 {
                    self.ready.push_back(HttpJob::StreamEnd { close });
                } else {
                    self.state = State::StreamBody { remaining, close };
                }
                true
            }
            State::Chunked { sub, discard, close } => self.step_chunked(sub, discard, close),
        }
    }

    /// One transition of the chunked-transfer decoder.
    fn step_chunked(&mut self, sub: ChunkState, discard: bool, close: bool) -> bool {
        match sub {
            ChunkState::Size => {
                let Some(eol) = find_crlf(&self.buf[self.pos..]) else {
                    if self.buf.len() - self.pos > CHUNK_LINE_CAP {
                        return self.fail(400, "chunk size line too long");
                    }
                    self.state = State::Chunked { sub: ChunkState::Size, discard, close };
                    return false;
                };
                let line = &self.buf[self.pos..self.pos + eol];
                let Some(size) = parse_chunk_size(line) else {
                    return self.fail(400, "bad chunk size");
                };
                self.pos += eol + 2;
                let sub = if size == 0 {
                    ChunkState::Trailer
                } else {
                    ChunkState::Data { remaining: size }
                };
                self.state = State::Chunked { sub, discard, close };
                true
            }
            ChunkState::Data { mut remaining } => {
                let take = remaining.min(self.buf.len() - self.pos);
                if take == 0 {
                    self.state =
                        State::Chunked { sub: ChunkState::Data { remaining }, discard, close };
                    return false;
                }
                if !discard {
                    self.ready.push_back(HttpJob::StreamChunk(
                        self.buf[self.pos..self.pos + take].to_vec(),
                    ));
                }
                self.pos += take;
                remaining -= take;
                let sub = if remaining == 0 {
                    ChunkState::DataEnd
                } else {
                    ChunkState::Data { remaining }
                };
                self.state = State::Chunked { sub, discard, close };
                true
            }
            ChunkState::DataEnd => {
                if self.buf.len() - self.pos < 2 {
                    self.state = State::Chunked { sub: ChunkState::DataEnd, discard, close };
                    return false;
                }
                if &self.buf[self.pos..self.pos + 2] != b"\r\n" {
                    return self.fail(400, "bad chunk data terminator");
                }
                self.pos += 2;
                self.state = State::Chunked { sub: ChunkState::Size, discard, close };
                true
            }
            ChunkState::Trailer => {
                let Some(eol) = find_crlf(&self.buf[self.pos..]) else {
                    if self.buf.len() - self.pos > TRAILER_LINE_CAP {
                        return self.fail(431, "trailer line too long");
                    }
                    self.state = State::Chunked { sub: ChunkState::Trailer, discard, close };
                    return false;
                };
                self.pos += eol + 2;
                if eol == 0 {
                    // Empty line: body complete. A discarded (already
                    // answered) body ends silently.
                    if !discard {
                        self.ready.push_back(HttpJob::StreamEnd { close });
                    }
                } else {
                    self.state = State::Chunked { sub: ChunkState::Trailer, discard, close };
                }
                true
            }
        }
    }

    /// Try to complete a request head; on success queue its jobs and
    /// transition into the body state.
    fn step_headers(&mut self) -> bool {
        let from = self.scan.max(self.pos);
        let Some(at) = self.buf[from..].windows(4).position(|w| w == b"\r\n\r\n") else {
            if self.buf.len() - self.pos > HEADER_CAP {
                return self.fail(431, "request header too large");
            }
            // A future terminator can straddle the scanned tail by up
            // to three bytes.
            self.scan = self.buf.len().saturating_sub(3).max(self.pos);
            return false;
        };
        let head_end = from + at;
        let head = match parse_head(&self.buf[self.pos..head_end]) {
            Ok(h) => h,
            Err((status, message)) => return self.fail(status, message),
        };
        self.pos = head_end + 4;
        self.scan = self.pos;

        let Head {
            method,
            path,
            query,
            content_type,
            close,
            content_length,
            chunked,
            expect_continue,
        } = head;
        let has_body = chunked || content_length > 0;

        // Rate limit POSTs once per head (the short-circuit keeps GETs
        // from spending tokens). Refusals still swallow a bounded body
        // so pipelined requests behind it stay parseable; an oversized
        // one closes instead of reading it all.
        let limited =
            method == Method::Post && self.limiter.as_ref().is_some_and(|l| !l.allow(self.peer));
        if limited {
            if !chunked && content_length > STREAM_THRESHOLD {
                return self.fail(429, "rate limit exceeded");
            }
            self.ready.push_back(HttpJob::Immediate {
                status: 429,
                message: "rate limit exceeded\n".into(),
                close,
            });
            if chunked {
                self.state = State::Chunked { sub: ChunkState::Size, discard: true, close };
            } else if content_length > 0 {
                self.state = State::Discard { remaining: content_length };
            }
            return true;
        }

        if expect_continue && has_body {
            self.ready.push_back(HttpJob::Immediate {
                status: 100,
                message: String::new(),
                close: false,
            });
        }

        let req = HttpRequest { method, path, query, content_type, close, body: Vec::new() };
        if chunked {
            self.ready.push_back(HttpJob::StreamBegin(req));
            self.state = State::Chunked { sub: ChunkState::Size, discard: false, close };
        } else if content_length > STREAM_THRESHOLD {
            self.ready.push_back(HttpJob::StreamBegin(req));
            self.state = State::StreamBody { remaining: content_length, close };
        } else if content_length > 0 {
            let mut req = Box::new(req);
            req.body.reserve(content_length);
            self.state = State::Body { req, remaining: content_length };
        } else {
            self.ready.push_back(HttpJob::Request(req));
        }
        true
    }
}

/// A parsed request head, before the body policy is applied.
struct Head {
    method: Method,
    path: String,
    query: Vec<(String, String)>,
    content_type: Option<String>,
    close: bool,
    content_length: usize,
    chunked: bool,
    expect_continue: bool,
}

/// Index of the first `\r\n` in `buf`, if complete.
fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

/// Parse a chunk-size line: hex digits, optionally followed by
/// `;extensions` (ignored). `None` on empty/invalid/overflowing sizes.
fn parse_chunk_size(line: &[u8]) -> Option<usize> {
    let mut size: usize = 0;
    let mut digits = 0usize;
    for &b in line {
        let v = match b {
            b'0'..=b'9' => b - b'0',
            b'a'..=b'f' => b - b'a' + 10,
            b'A'..=b'F' => b - b'A' + 10,
            b';' => break,
            _ => return None,
        };
        size = size.checked_mul(16)?.checked_add(v as usize)?;
        digits += 1;
    }
    if digits == 0 {
        None
    } else {
        Some(size)
    }
}

/// Parse a request head (`head` excludes the `\r\n\r\n` terminator).
/// Errors carry the HTTP status + message for the `Immediate` reply.
fn parse_head(head: &[u8]) -> Result<Head, (u16, &'static str)> {
    let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));
    let request_line = lines.next().unwrap_or(b"");
    let mut parts = request_line.split(|&b| b == b' ').filter(|t| !t.is_empty());
    let method = parts.next().ok_or((400, "malformed request line"))?;
    let target = parts.next().ok_or((400, "malformed request line"))?;
    let version = parts.next().ok_or((400, "malformed request line"))?;
    if parts.next().is_some() {
        return Err((400, "malformed request line"));
    }
    let http11 = match version {
        b"HTTP/1.1" => true,
        b"HTTP/1.0" => false,
        v if v.starts_with(b"HTTP/") => return Err((505, "http version not supported")),
        _ => return Err((400, "malformed request line")),
    };
    let method = match method {
        b"GET" => Method::Get,
        b"POST" => Method::Post,
        _ => Method::Other,
    };
    if target.first() != Some(&b'/') {
        return Err((400, "bad request target"));
    }
    let target = std::str::from_utf8(target).map_err(|_| (400, "bad request target"))?;
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let query: Vec<(String, String)> = query_str
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect();

    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    let mut close_header = false;
    let mut keep_alive = false;
    let mut content_type = None;
    let mut expect_continue = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let colon = line
            .iter()
            .position(|&b| b == b':')
            .ok_or((400, "malformed header line"))?;
        let name = &line[..colon];
        let value = std::str::from_utf8(&line[colon + 1..])
            .map_err(|_| (400, "malformed header line"))?
            .trim();
        if name.eq_ignore_ascii_case(b"content-length") {
            let n: usize = value.parse().map_err(|_| (400, "bad content-length"))?;
            if content_length.is_some_and(|prev| prev != n) {
                return Err((400, "conflicting content-length"));
            }
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case(b"transfer-encoding") {
            if !value.eq_ignore_ascii_case("chunked") {
                return Err((400, "unsupported transfer-encoding"));
            }
            chunked = true;
        } else if name.eq_ignore_ascii_case(b"connection") {
            for token in value.split(',') {
                let token = token.trim();
                if token.eq_ignore_ascii_case("close") {
                    close_header = true;
                } else if token.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        } else if name.eq_ignore_ascii_case(b"content-type") {
            content_type = Some(value.to_string());
        } else if name.eq_ignore_ascii_case(b"expect") && value.eq_ignore_ascii_case("100-continue")
        {
            expect_continue = true;
        }
    }
    if chunked && content_length.is_some() {
        // Request-smuggling guard: refuse double-framed bodies.
        return Err((400, "both content-length and chunked"));
    }
    let close = if http11 { close_header } else { !keep_alive };
    Ok(Head {
        method,
        path: path.to_string(),
        query,
        content_type,
        close,
        content_length: content_length.unwrap_or(0),
        chunked,
        expect_continue,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{IpAddr, Ipv4Addr};

    fn machine() -> HttpMachine {
        HttpMachine::new(Vec::new(), None, IpAddr::V4(Ipv4Addr::LOCALHOST))
    }

    /// Drain every currently parseable job.
    fn drain(m: &mut HttpMachine) -> Vec<HttpJob> {
        std::iter::from_fn(|| m.next_job()).collect()
    }

    /// Render a job stream for equality checks, coalescing adjacent
    /// `StreamChunk`s — tearing legitimately splits a body across more
    /// chunk jobs, but the concatenated bytes must be identical.
    fn normalize(jobs: Vec<HttpJob>) -> Vec<String> {
        let mut out = Vec::new();
        let mut body: Vec<u8> = Vec::new();
        for j in jobs {
            match j {
                HttpJob::StreamChunk(d) => body.extend_from_slice(&d),
                other => {
                    if !body.is_empty() {
                        out.push(format!("chunk:{}", String::from_utf8_lossy(&body)));
                        body.clear();
                    }
                    out.push(format!("{other:?}"));
                }
            }
        }
        if !body.is_empty() {
            out.push(format!("chunk:{}", String::from_utf8_lossy(&body)));
        }
        out
    }

    #[test]
    fn simple_get_parses() {
        let mut m = machine();
        m.push(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        let Some(HttpJob::Request(req)) = m.next_job() else { panic!("expected request") };
        assert_eq!(req.method, Method::Get);
        assert_eq!(req.path, "/healthz");
        assert!(!req.close);
        assert!(req.body.is_empty());
        assert!(m.next_job().is_none());
        assert_eq!(m.buffered(), 0);
    }

    #[test]
    fn post_with_body_and_params() {
        let mut m = machine();
        m.push(b"POST /encode?alphabet=url&wrap=76 HTTP/1.1\r\n");
        m.push(b"Content-Length: 5\r\nConnection: close\r\n\r\nhello");
        let Some(HttpJob::Request(req)) = m.next_job() else { panic!("expected request") };
        assert_eq!(req.method, Method::Post);
        assert_eq!(req.path, "/encode");
        assert_eq!(req.query_param("alphabet"), Some("url"));
        assert_eq!(req.query_param("wrap"), Some("76"));
        assert!(req.close);
        assert_eq!(req.body, b"hello");
    }

    /// Byte-at-a-time (maximally torn) feeding yields the same job
    /// stream as a one-shot push — the incremental parser's oracle.
    #[test]
    fn torn_feed_matches_one_shot_oracle() {
        let wire: Vec<u8> = [
            b"POST /encode HTTP/1.1\r\nContent-Length: 3\r\n\r\nabc".as_slice(),
            b"GET /metrics?x=1 HTTP/1.1\r\n\r\n",
            b"POST /decode HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"3\r\nZm9\r\n1\r\nv\r\n0\r\n\r\n",
            b"GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n",
        ]
        .concat();
        let mut oracle = machine();
        oracle.push(&wire);
        let expect = normalize(drain(&mut oracle));
        assert!(expect.len() >= 6, "oracle produced {expect:?}");

        for step in [1usize, 2, 3, 7, 64] {
            let mut m = machine();
            let mut got = Vec::new();
            for piece in wire.chunks(step) {
                m.push(piece);
                got.extend(drain(&mut m));
            }
            assert_eq!(normalize(got), expect, "step={step}");
        }
    }

    #[test]
    fn pipelined_requests_come_out_in_order() {
        let mut m = machine();
        m.push(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\nGET /c HTTP/1.1\r\n\r\n");
        let paths: Vec<String> = std::iter::from_fn(|| m.next_job())
            .map(|j| match j {
                HttpJob::Request(r) => r.path,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(paths, ["/a", "/b", "/c"]);
    }

    #[test]
    fn chunked_body_streams_with_jobs() {
        let mut m = machine();
        m.push(b"POST /decode HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        assert!(matches!(m.next_job(), Some(HttpJob::StreamBegin(_))));
        m.push(b"4\r\nWxyz\r\n");
        match m.next_job() {
            Some(HttpJob::StreamChunk(d)) => assert_eq!(d, b"Wxyz"),
            other => panic!("unexpected {other:?}"),
        }
        assert!(m.next_job().is_none());
        m.push(b"0\r\nx-trailer: 1\r\n\r\n");
        assert!(matches!(m.next_job(), Some(HttpJob::StreamEnd { close: false })));
        assert!(m.next_job().is_none());
    }

    #[test]
    fn large_content_length_streams() {
        let mut m = machine();
        let n = STREAM_THRESHOLD + 1;
        m.push(format!("POST /decode HTTP/1.1\r\nContent-Length: {n}\r\n\r\n").as_bytes());
        assert!(matches!(m.next_job(), Some(HttpJob::StreamBegin(_))));
        m.push(&vec![b'A'; n - 1]);
        let mut got = 0usize;
        while let Some(j) = m.next_job() {
            match j {
                HttpJob::StreamChunk(d) => got += d.len(),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, n - 1);
        m.push(b"A");
        assert!(matches!(m.next_job(), Some(HttpJob::StreamChunk(_))));
        assert!(matches!(m.next_job(), Some(HttpJob::StreamEnd { close: false })));
        // Body bytes were consumed eagerly — nothing pending.
        assert_eq!(m.buffered(), 0);
    }

    #[test]
    fn oversized_header_is_431_and_poisons() {
        let mut m = machine();
        m.push(b"GET / HTTP/1.1\r\n");
        m.push(&vec![b'a'; HEADER_CAP + 1]);
        match m.next_job() {
            Some(HttpJob::Immediate { status: 431, close: true, .. }) => {}
            other => panic!("unexpected {other:?}"),
        }
        m.push(b"\r\n\r\nGET /x HTTP/1.1\r\n\r\n");
        assert!(m.next_job().is_none(), "poisoned machine must not keep parsing");
    }

    #[test]
    fn malformed_requests_are_400() {
        for wire in [
            b"BOGUS\r\n\r\n".as_slice(),
            b"GET /x HTTP/2.0\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"GET nopath HTTP/1.1\r\n\r\n",
        ] {
            let mut m = machine();
            m.push(wire);
            match m.next_job() {
                Some(HttpJob::Immediate { status: 400 | 505, close: true, .. }) => {}
                other => panic!("{}: unexpected {other:?}", String::from_utf8_lossy(wire)),
            }
        }
    }

    #[test]
    fn http10_defaults_to_close() {
        let mut m = machine();
        m.push(b"GET / HTTP/1.0\r\n\r\n");
        let Some(HttpJob::Request(req)) = m.next_job() else { panic!() };
        assert!(req.close, "HTTP/1.0 without keep-alive closes");
    }

    #[test]
    fn expect_continue_emits_interim() {
        let mut m = machine();
        m.push(b"POST /encode HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n");
        assert!(matches!(m.next_job(), Some(HttpJob::Immediate { status: 100, .. })));
        assert!(m.next_job().is_none());
        m.push(b"ok");
        let Some(HttpJob::Request(req)) = m.next_job() else { panic!() };
        assert_eq!(req.body, b"ok");
    }

    #[test]
    fn rate_limited_post_is_429_and_body_swallowed() {
        let rl = RateLimiter::new(1.0).unwrap();
        let ip = IpAddr::V4(Ipv4Addr::LOCALHOST);
        let mut m = HttpMachine::new(Vec::new(), Some(rl), ip);
        // First POST spends the single burst token.
        m.push(b"POST /encode HTTP/1.1\r\nContent-Length: 2\r\n\r\nok");
        assert!(matches!(m.next_job(), Some(HttpJob::Request(_))));
        // Second is refused but its body is swallowed, so the pipelined
        // GET behind it still parses.
        m.push(b"POST /encode HTTP/1.1\r\nContent-Length: 2\r\n\r\nok");
        m.push(b"GET /healthz HTTP/1.1\r\n\r\n");
        assert!(matches!(m.next_job(), Some(HttpJob::Immediate { status: 429, .. })));
        match m.next_job() {
            Some(HttpJob::Request(r)) => assert_eq!(r.path, "/healthz"),
            other => panic!("unexpected {other:?}"),
        }
        // GETs are never rate limited.
        m.push(b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(matches!(m.next_job(), Some(HttpJob::Request(_))));
    }
}
