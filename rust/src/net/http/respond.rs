//! Gateway routing and response assembly (runs on the worker threads).
//!
//! [`respond`] turns one [`HttpWork`] into response bytes plus a
//! close-after-flush flag, mirroring the native protocol's
//! `dispatch_into_clocked`:
//!
//! * `POST /encode` / `POST /decode` / `POST /datauri` with a buffered
//!   body go through `Router::process_into` into an [`HttpSink`], so
//!   the reply payload is written in place by the same tiered kernels
//!   as the native zero-copy path;
//! * the same routes with a streamed body (chunked transfer, or
//!   `Content-Length` above the buffering threshold) drive the
//!   session's streaming codecs under the reserved [`HTTP_STREAM_ID`],
//!   each input slice answered by one output chunk — a decode larger
//!   than the native `MAX_FRAME` completes in bounded memory;
//! * `GET /healthz`, `GET /metrics` and `GET /debug/trace` are the ops
//!   surface: the health check flips to `503` while draining, the
//!   metrics endpoint renders the global counters plus the per-shard
//!   breakdown as `b64simd_*`-prefixed text, and the trace endpoint
//!   dumps every shard's flight recorder as JSON (`?n=` caps events
//!   per shard).
//!
//! Query parameters (`alphabet=standard|url|imap`,
//! `codec=<registry name>`, `mode=strict|forgiving`, `ws=none|crlf|all`,
//! `wrap=<n>`) are plain ASCII tokens. `alphabet=` keeps resolving
//! against [`Alphabet::by_name`] exactly as before; the `codec=`
//! parameter resolves against the connection's
//! [`crate::codec::CodecRegistry`] instead, which adds `hex`, the two
//! base32 variants, and any alphabets registered on this connection via
//! `POST /codecs` (`?name=<name>&pad=<byte>` with the 64-byte table as
//! the body; `GET /codecs` lists the registry as `id name` rows).
//!
//! Error model: one response per request, always. A request whose
//! *head* is unroutable or ill-parameterized gets its full error
//! response at `StreamBegin` time; the body keeps streaming in but
//! every subsequent chunk finds no open stream and produces no output.
//! A mid-body codec error cannot be reported in a status line that is
//! already on the wire, so the connection closes without the terminal
//! `0\r\n\r\n` chunk — deliberately truncated chunked framing, which
//! every conforming client treats as a failed transfer.

use std::time::Instant;

use crate::base64::mime::MimeCodec;
use crate::base64::{Alphabet, Mode, Whitespace};
use crate::codec::CodecSel;
use crate::coordinator::state::{SessionState, StreamError};
use crate::coordinator::{Metrics, Request, RequestKind, Router};
use crate::obs::clock::ReqClock;

use super::sink::HttpSink;
use super::{HttpJob, HttpRequest, HttpWork, Method, HTTP_STREAM_ID};

/// Produce the response for one job. `buf` is the connection's pooled
/// response buffer (appended to, returned with the response bytes);
/// the second return is close-after-flush. Unclocked convenience
/// wrapper over [`respond_clocked`].
pub fn respond(
    work: HttpWork,
    router: &Router,
    session: &mut SessionState,
    buf: Vec<u8>,
) -> (Vec<u8>, bool) {
    respond_clocked(work, router, session, buf, None)
}

/// [`respond`] with an optional request stage clock: codec routes
/// stamp kernel/sink inside the router, everything else (ops routes,
/// immediates, stream plumbing) stamps here, so every job that
/// produces bytes attributes its time to a stage.
pub fn respond_clocked(
    work: HttpWork,
    router: &Router,
    session: &mut SessionState,
    mut buf: Vec<u8>,
    clock: Option<&ReqClock>,
) -> (Vec<u8>, bool) {
    let HttpWork { job, draining } = work;
    let metrics = router.metrics();
    match job {
        HttpJob::Immediate { status, message, close } => {
            if status == 429 {
                Metrics::inc(&metrics.rate_limited, 1);
            }
            if status == 100 {
                // Interim reply: bare status line, no body, request
                // still to come.
                buf.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                return (buf, false);
            }
            let close = close || draining;
            write_simple(&mut buf, status, reason_for(status), &message, close);
            if let Some(c) = clock {
                c.stamp_kernel();
                c.stamp_sink();
            }
            (buf, close)
        }
        HttpJob::Request(req) => {
            Metrics::inc(&metrics.http_requests, 1);
            handle_request(req, router, session, draining, buf, clock)
        }
        HttpJob::StreamBegin(req) => {
            Metrics::inc(&metrics.http_requests, 1);
            let out = stream_begin(req, session, draining, buf);
            if let Some(c) = clock {
                c.stamp_kernel();
                c.stamp_sink();
            }
            out
        }
        HttpJob::StreamChunk(data) => {
            let start = Instant::now();
            match session.chunk(HTTP_STREAM_ID, &data) {
                Ok(out) => {
                    if let Some(c) = clock {
                        c.stamp_kernel();
                    }
                    write_chunk(&mut buf, &out);
                    if let Some(c) = clock {
                        c.stamp_sink();
                    }
                    // Streamed bodies never pass through the router, so
                    // the per-request latency histogram is fed here —
                    // one sample per body slice.
                    metrics.latency.record(start.elapsed());
                    (buf, false)
                }
                // Begin was refused (error already answered): swallow.
                Err(StreamError::UnknownStream(_)) => (buf, false),
                Err(_) => {
                    // Mid-body codec error after a 200 head is on the wire:
                    // close without the terminal chunk (see module docs).
                    session.abort(HTTP_STREAM_ID);
                    (buf, true)
                }
            }
        }
        HttpJob::StreamEnd { close } => {
            let close = close || draining;
            let start = Instant::now();
            match session.finish(HTTP_STREAM_ID) {
                Ok(out) => {
                    if let Some(c) = clock {
                        c.stamp_kernel();
                    }
                    write_chunk(&mut buf, &out);
                    buf.extend_from_slice(b"0\r\n\r\n");
                    if let Some(c) = clock {
                        c.stamp_sink();
                    }
                    metrics.latency.record(start.elapsed());
                    (buf, close)
                }
                Err(StreamError::UnknownStream(_)) => (buf, close),
                Err(_) => (buf, true),
            }
        }
    }
}

/// Route a buffered request.
fn handle_request(
    req: HttpRequest,
    router: &Router,
    session: &mut SessionState,
    draining: bool,
    mut buf: Vec<u8>,
    clock: Option<&ReqClock>,
) -> (Vec<u8>, bool) {
    let close = req.close || draining;
    let stamp = |c: Option<&ReqClock>| {
        if let Some(c) = c {
            c.stamp_kernel();
            c.stamp_sink();
        }
    };
    match (req.method, req.path.as_str()) {
        (Method::Get, "/healthz") => {
            if draining {
                write_simple(&mut buf, 503, "Service Unavailable", "draining\n", true);
                stamp(clock);
                (buf, true)
            } else {
                write_simple(&mut buf, 200, "OK", "ok\n", close);
                stamp(clock);
                (buf, close)
            }
        }
        (Method::Get, "/metrics") => {
            let body = router.metrics().render_text();
            let ct = "text/plain; version=0.0.4";
            write_response(&mut buf, 200, "OK", ct, &[], body.as_bytes(), close);
            stamp(clock);
            (buf, close)
        }
        (Method::Get, "/debug/trace") => {
            // Recent flight-recorder events from every registered shard,
            // merged and time-ordered; `n` caps events per shard.
            let per_shard = req
                .query_param("n")
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(128);
            let body = crate::obs::recorder::dump_json(per_shard);
            write_response(&mut buf, 200, "OK", "application/json", &[], body.as_bytes(), close);
            stamp(clock);
            (buf, close)
        }
        (Method::Post, "/encode") => {
            codec_request(req, router, session, CodecRoute::Encode, close, buf, clock)
        }
        (Method::Post, "/datauri") => {
            codec_request(req, router, session, CodecRoute::DataUri, close, buf, clock)
        }
        (Method::Post, "/decode") => {
            codec_request(req, router, session, CodecRoute::Decode, close, buf, clock)
        }
        (Method::Get, "/codecs") => {
            // The connection's codec registry as plain `id name` rows —
            // built-ins first, then this connection's registrations.
            let mut body = String::new();
            for (id, name) in session.codecs().list() {
                body.push_str(&format!("{id} {name}\n"));
            }
            write_response(&mut buf, 200, "OK", "text/plain", &[], body.as_bytes(), close);
            stamp(clock);
            (buf, close)
        }
        (Method::Post, "/codecs") => {
            // Register a custom base64 alphabet: `?name=<name>` and an
            // optional `?pad=<decimal byte>` (default '='), the 64-byte
            // table as the request body. Success answers the assigned
            // id; the name is then usable in `codec=` on this
            // connection, mirroring the native CodecRegister frame.
            let reply = register_codec(&req, session);
            match reply {
                Ok(id) => write_simple(&mut buf, 200, "OK", &format!("{id}\n"), close),
                Err(message) => {
                    write_simple(&mut buf, 400, "Bad Request", &format!("{message}\n"), close)
                }
            }
            stamp(clock);
            (buf, close)
        }
        (_, "/codecs") => {
            write_response(
                &mut buf,
                405,
                "Method Not Allowed",
                "text/plain",
                &[("Allow", "GET, POST")],
                b"method not allowed\n",
                close,
            );
            stamp(clock);
            (buf, close)
        }
        (_, "/healthz" | "/metrics" | "/debug/trace") => {
            write_response(
                &mut buf,
                405,
                "Method Not Allowed",
                "text/plain",
                &[("Allow", "GET")],
                b"method not allowed\n",
                close,
            );
            stamp(clock);
            (buf, close)
        }
        (_, "/encode" | "/decode" | "/datauri") => {
            write_response(
                &mut buf,
                405,
                "Method Not Allowed",
                "text/plain",
                &[("Allow", "POST")],
                b"method not allowed\n",
                close,
            );
            stamp(clock);
            (buf, close)
        }
        _ => {
            write_simple(&mut buf, 404, "Not Found", "not found\n", close);
            stamp(clock);
            (buf, close)
        }
    }
}

/// Validate and apply a `POST /codecs` registration against the
/// connection's registry; `Ok` carries the assigned codec id.
fn register_codec(req: &HttpRequest, session: &mut SessionState) -> Result<u16, String> {
    let name = req.query_param("name").ok_or("missing name parameter")?.to_string();
    let pad = match req.query_param("pad") {
        None => b'=',
        Some(v) => v.parse::<u8>().map_err(|_| format!("bad pad value: {v}"))?,
    };
    let chars: [u8; 64] =
        req.body[..].try_into().map_err(|_| "codec table must be 64 bytes".to_string())?;
    session.codecs_mut().register(&name, &chars, pad).map_err(|e| e.to_string())
}

/// The three codec routes.
#[derive(Clone, Copy, PartialEq, Eq)]
enum CodecRoute {
    Encode,
    Decode,
    DataUri,
}

/// Dispatch a buffered codec request through the router into an
/// [`HttpSink`].
fn codec_request(
    req: HttpRequest,
    router: &Router,
    session: &SessionState,
    route: CodecRoute,
    close: bool,
    mut buf: Vec<u8>,
    clock: Option<&ReqClock>,
) -> (Vec<u8>, bool) {
    let params = match Params::of(&req, route, session) {
        Ok(p) => p,
        Err(message) => {
            write_simple(&mut buf, 400, "Bad Request", &format!("{message}\n"), close);
            return (buf, close);
        }
    };
    if let Some(wrap) = params.wrap {
        // Wrapped (MIME) encode: the router has no wrap notion, so this
        // path encodes via the codec directly. Bodies here are bounded
        // by the buffering threshold, so a Content-Length response is
        // simplest. Building the codec validates the wrap value.
        // Params rejects wrap on non-base64 codecs, so the alphabet is
        // always extractable here.
        let CodecSel::Base64(alphabet) = params.codec else {
            unreachable!("Params rejects wrap on non-base64 codecs")
        };
        let codec = match MimeCodec::new(alphabet).with_line_len(wrap) {
            Ok(c) => c,
            Err(e) => {
                write_simple(&mut buf, 400, "Bad Request", &format!("{e}\n"), close);
                return (buf, close);
            }
        };
        let start = Instant::now();
        let body = codec.encode(&req.body);
        if let Some(c) = clock {
            c.stamp_kernel();
        }
        write_response(&mut buf, 200, "OK", "text/plain", &[], &body, close);
        if let Some(c) = clock {
            c.stamp_sink();
        }
        // Wrapped encodes bypass the router, so feed the request
        // latency histogram here (the audit twin of the streamed path).
        router.metrics().latency.record(start.elapsed());
        return (buf, close);
    }
    let (kind, content_type) = match route {
        CodecRoute::Encode | CodecRoute::DataUri => (RequestKind::Encode, "text/plain"),
        CodecRoute::Decode => (RequestKind::Decode, "application/octet-stream"),
    };
    let prefix = (route == CodecRoute::DataUri).then(|| format!("data:{};base64,", mime_of(&req)));
    let mut sink = HttpSink::new(buf, content_type, close, prefix);
    let request = Request {
        id: 0,
        kind,
        payload: req.body,
        codec: params.codec,
        mode: params.mode,
        ws: params.ws,
    };
    match router.process_into_clocked(request, &mut sink, clock) {
        Ok(()) => (sink.into_buf(), close),
        Err(_) => {
            // Reply would not fit the sink's framing; connection-fatal,
            // same as the native path's oversized frame.
            let mut buf = sink.into_buf();
            write_simple(&mut buf, 500, "Internal Server Error", "response too large\n", true);
            (buf, true)
        }
    }
}

/// Open the session stream for a streamed-body request and put the
/// response head on the wire, or answer the error for an unroutable
/// head (the connection then swallows the body; see module docs).
fn stream_begin(
    req: HttpRequest,
    session: &mut SessionState,
    draining: bool,
    mut buf: Vec<u8>,
) -> (Vec<u8>, bool) {
    // A defunct stream can linger if a peer vanished mid-body and the
    // connection is being reused (it cannot, but stay defensive).
    session.abort(HTTP_STREAM_ID);
    let close = req.close || draining;
    let route = match (req.method, req.path.as_str()) {
        (Method::Post, "/encode") => CodecRoute::Encode,
        (Method::Post, "/datauri") => CodecRoute::DataUri,
        (Method::Post, "/decode") => CodecRoute::Decode,
        (Method::Post, "/codecs") => {
            // Registration tables are 64 bytes; a body large enough to
            // stream (or chunked framing) is never a valid table.
            write_simple(&mut buf, 400, "Bad Request", "codec table must be 64 bytes\n", close);
            return (buf, false);
        }
        (_, "/encode" | "/decode" | "/datauri" | "/healthz" | "/metrics" | "/codecs") => {
            write_simple(&mut buf, 405, "Method Not Allowed", "method not allowed\n", close);
            return (buf, false);
        }
        _ => {
            write_simple(&mut buf, 404, "Not Found", "not found\n", close);
            return (buf, false);
        }
    };
    let params = match Params::of(&req, route, session) {
        Ok(p) => p,
        Err(message) => {
            write_simple(&mut buf, 400, "Bad Request", &format!("{message}\n"), close);
            return (buf, false);
        }
    };
    let opened = match (route, params.wrap) {
        (CodecRoute::Encode, Some(wrap)) => {
            // Params rejects wrap on non-base64 codecs, and
            // `open_codec_encode` routes base64-with-wrap through the
            // wrapped encoder.
            session.open_codec_encode(HTTP_STREAM_ID, params.codec, wrap)
        }
        (CodecRoute::Encode | CodecRoute::DataUri, None) => {
            session.open_codec_encode(HTTP_STREAM_ID, params.codec, 0)
        }
        (CodecRoute::Decode, None) => {
            session.open_codec_decode(HTTP_STREAM_ID, params.codec, params.mode, params.ws)
        }
        (CodecRoute::DataUri | CodecRoute::Decode, Some(_)) => unreachable!("Params rejects wrap"),
    };
    if let Err(e) = opened {
        write_simple(&mut buf, 400, "Bad Request", &format!("{e}\n"), close);
        return (buf, false);
    }
    let content_type = match route {
        CodecRoute::Decode => "application/octet-stream",
        _ => "text/plain",
    };
    buf.extend_from_slice(b"HTTP/1.1 200 OK\r\nContent-Type: ");
    buf.extend_from_slice(content_type.as_bytes());
    buf.extend_from_slice(b"\r\nTransfer-Encoding: chunked\r\n");
    if close {
        buf.extend_from_slice(b"Connection: close\r\n");
    }
    buf.extend_from_slice(b"\r\n");
    if route == CodecRoute::DataUri {
        write_chunk(&mut buf, format!("data:{};base64,", mime_of(&req)).as_bytes());
    }
    (buf, false)
}

/// Validated query parameters of a codec request.
struct Params {
    codec: CodecSel,
    mode: Mode,
    ws: Whitespace,
    wrap: Option<usize>,
}

impl Params {
    fn of(req: &HttpRequest, route: CodecRoute, session: &SessionState) -> Result<Params, String> {
        // `alphabet=` keeps its pre-registry resolution (the three
        // built-in base64 alphabets); `codec=` resolves against the
        // connection's registry, which also covers hex, base32 and any
        // names registered over `POST /codecs`.
        let codec = match (req.query_param("alphabet"), req.query_param("codec")) {
            (Some(_), Some(_)) => {
                return Err("specify alphabet or codec, not both".to_string());
            }
            (Some(name), None) => CodecSel::Base64(
                Alphabet::by_name(name).ok_or_else(|| format!("unknown alphabet: {name}"))?,
            ),
            (None, Some(name)) => session
                .codecs()
                .resolve(name)
                .ok_or_else(|| format!("unknown codec: {name}"))?,
            (None, None) => CodecSel::Base64(Alphabet::standard()),
        };
        if route == CodecRoute::DataUri && !matches!(codec, CodecSel::Base64(_)) {
            return Err(format!("data URIs require a base64 codec, not {}", codec.name()));
        }
        let mode = match req.query_param("mode") {
            None | Some("strict") => Mode::Strict,
            Some("forgiving") => Mode::Forgiving,
            Some(m) => return Err(format!("unknown mode: {m}")),
        };
        let ws = match req.query_param("ws") {
            None | Some("none") => Whitespace::None,
            Some("crlf") => Whitespace::CrLf,
            Some("all") => Whitespace::All,
            Some(w) => return Err(format!("unknown ws policy: {w}")),
        };
        let wrap = match req.query_param("wrap") {
            None => None,
            Some(v) => Some(v.parse::<usize>().map_err(|_| format!("bad wrap value: {v}"))?),
        };
        if wrap.is_some() && route != CodecRoute::Encode {
            return Err("wrap is only valid on /encode".to_string());
        }
        if wrap.is_some() && !matches!(codec, CodecSel::Base64(_)) {
            return Err(format!("codec {} does not support wrapped output", codec.name()));
        }
        if route == CodecRoute::Decode {
            Ok(Params { codec, mode, ws, wrap })
        } else {
            if req.query_param("mode").is_some() || req.query_param("ws").is_some() {
                return Err("mode/ws are only valid on /decode".to_string());
            }
            Ok(Params { codec, mode: Mode::Strict, ws: Whitespace::None, wrap })
        }
    }
}

/// The data URI's media type: the request's `Content-Type`, default
/// `application/octet-stream`.
fn mime_of(req: &HttpRequest) -> &str {
    req.content_type.as_deref().unwrap_or("application/octet-stream")
}

/// Append one chunked-transfer chunk (no-op for empty `data` — an
/// empty chunk would terminate the body).
fn write_chunk(buf: &mut Vec<u8>, data: &[u8]) {
    if data.is_empty() {
        return;
    }
    buf.extend_from_slice(format!("{:x}\r\n", data.len()).as_bytes());
    buf.extend_from_slice(data);
    buf.extend_from_slice(b"\r\n");
}

/// The `408 Request Timeout` notice the reactors send in place of the
/// native protocol's `0x82` timeout frames.
pub fn timeout_response(message: &str) -> Vec<u8> {
    let mut buf = Vec::new();
    write_simple(&mut buf, 408, "Request Timeout", &format!("{message}\n"), true);
    buf
}

/// The `500` sent when a worker panics mid-request (native twin: the
/// `0x82` "request handler panicked" frame). Always closes.
pub fn panic_response() -> Vec<u8> {
    let mut buf = Vec::new();
    write_simple(
        &mut buf,
        500,
        "Internal Server Error",
        "internal error: request handler panicked\n",
        true,
    );
    buf
}

/// The `503` refusal for an accept over the connection cap — the
/// gateway's analogue of the native busy frame. Always closes.
pub fn busy_response(open: usize, max: usize) -> Vec<u8> {
    let mut buf = Vec::new();
    let body = format!("busy: {open} connections open (limit {max})\n");
    write_simple(&mut buf, 503, "Service Unavailable", &body, true);
    buf
}

/// Append a complete `text/plain` response with a `Content-Length`
/// body.
pub(crate) fn write_simple(buf: &mut Vec<u8>, status: u16, reason: &str, body: &str, close: bool) {
    write_response(buf, status, reason, "text/plain", &[], body.as_bytes(), close);
}

/// Append a complete response: status line, `Content-Type`,
/// `Content-Length`, extra headers, optional `Connection: close`, body.
fn write_response(
    buf: &mut Vec<u8>,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &[u8],
    close: bool,
) {
    buf.extend_from_slice(format!("HTTP/1.1 {status} {reason}\r\n").as_bytes());
    buf.extend_from_slice(format!("Content-Type: {content_type}\r\n").as_bytes());
    buf.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
    for (name, value) in extra {
        buf.extend_from_slice(format!("{name}: {value}\r\n").as_bytes());
    }
    if close {
        buf.extend_from_slice(b"Connection: close\r\n");
    }
    buf.extend_from_slice(b"\r\n");
    buf.extend_from_slice(body);
}

/// Canonical reason phrase for the statuses the gateway emits.
fn reason_for(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::block::BlockCodec;
    use crate::coordinator::backend::rust_factory;
    use crate::coordinator::RouterConfig;

    fn router() -> Router {
        Router::new(rust_factory(), RouterConfig::default())
    }

    fn get(path: &str) -> HttpRequest {
        HttpRequest {
            method: Method::Get,
            path: path.to_string(),
            query: Vec::new(),
            content_type: None,
            close: false,
            body: Vec::new(),
        }
    }

    fn post(target: &str, body: &[u8]) -> HttpRequest {
        let (path, query_str) = target.split_once('?').unwrap_or((target, ""));
        HttpRequest {
            method: Method::Post,
            path: path.to_string(),
            query: query_str
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| {
                    let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
                    (k.to_string(), v.to_string())
                })
                .collect(),
            content_type: None,
            close: false,
            body: body.to_vec(),
        }
    }

    fn run(router: &Router, req: HttpRequest) -> (String, Vec<u8>, bool) {
        let mut session = SessionState::new(4);
        let work = HttpWork { job: HttpJob::Request(req), draining: false };
        let (out, close) = respond(work, router, &mut session, Vec::new());
        let (head, body) = split_response(&out);
        (head, body, close)
    }

    /// Split one response into head text and de-framed body bytes
    /// (handles both Content-Length and single-chunk chunked replies).
    fn split_response(out: &[u8]) -> (String, Vec<u8>) {
        let at = out.windows(4).position(|w| w == b"\r\n\r\n").expect("complete head") + 4;
        let head = String::from_utf8(out[..at - 4].to_vec()).unwrap();
        let mut body = Vec::new();
        if head.contains("Transfer-Encoding: chunked") {
            let mut rest = &out[at..];
            loop {
                let eol = rest.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
                let size =
                    usize::from_str_radix(std::str::from_utf8(&rest[..eol]).unwrap(), 16).unwrap();
                rest = &rest[eol + 2..];
                if size == 0 {
                    assert_eq!(rest, b"\r\n", "terminal chunk ends the response");
                    break;
                }
                body.extend_from_slice(&rest[..size]);
                assert_eq!(&rest[size..size + 2], b"\r\n");
                rest = &rest[size + 2..];
            }
        } else {
            body.extend_from_slice(&out[at..]);
        }
        (head, body)
    }

    #[test]
    fn healthz_ok_and_draining() {
        let rt = router();
        let (head, body, close) = run(&rt, get("/healthz"));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(body, b"ok\n");
        assert!(!close);
        let mut session = SessionState::new(4);
        let work = HttpWork { job: HttpJob::Request(get("/healthz")), draining: true };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        let (head, body) = split_response(&out);
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert_eq!(body, b"draining\n");
        assert!(close, "draining health check closes");
    }

    #[test]
    fn encode_roundtrips_against_block_codec() {
        let rt = router();
        let data = b"hello, gateway".to_vec();
        let (head, body, _) = run(&rt, post("/encode", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(body, BlockCodec::new(Alphabet::standard()).encode(&data));
        let (head, decoded, _) = run(&rt, post("/decode", &body));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(decoded, data);
    }

    #[test]
    fn decode_error_is_422() {
        let rt = router();
        let (head, body, close) = run(&rt, post("/decode", b"not!!base64"));
        assert!(head.starts_with("HTTP/1.1 422"), "{head}");
        assert!(String::from_utf8_lossy(&body).contains("invalid byte"), "{body:?}");
        assert!(!close, "a 422 keeps the connection");
    }

    #[test]
    fn datauri_prefixes_mime() {
        let rt = router();
        let mut req = post("/datauri", b"\x89PNG");
        req.content_type = Some("image/png".to_string());
        let (head, body, _) = run(&rt, req);
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        let expect = format!(
            "data:image/png;base64,{}",
            String::from_utf8(BlockCodec::new(Alphabet::standard()).encode(b"\x89PNG")).unwrap()
        );
        assert_eq!(String::from_utf8(body).unwrap(), expect);
    }

    #[test]
    fn wrapped_encode_and_invalid_wrap() {
        let rt = router();
        let data = vec![0xA5u8; 100];
        let (head, body, _) = run(&rt, post("/encode?wrap=8", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        let oracle = MimeCodec::new(Alphabet::standard()).with_line_len(8).unwrap().encode(&data);
        assert_eq!(body, oracle);
        let (head, body, _) = run(&rt, post("/encode?wrap=7", &data));
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        assert!(
            String::from_utf8_lossy(&body).contains("invalid wrap line length 7"),
            "{body:?}"
        );
    }

    #[test]
    fn unknown_route_and_method() {
        let rt = router();
        let (head, _, _) = run(&rt, get("/nope"));
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
        let (head, _, _) = run(&rt, get("/encode"));
        assert!(head.starts_with("HTTP/1.1 405"), "{head}");
        assert!(head.contains("Allow: POST"), "{head}");
        let (head, _, _) = run(&rt, post("/metrics", b""));
        assert!(head.contains("Allow: GET"), "{head}");
    }

    #[test]
    fn bad_params_are_400() {
        let rt = router();
        for target in [
            "/encode?alphabet=rot13",
            "/decode?mode=wat",
            "/decode?ws=vertical",
            "/decode?wrap=76",
            "/encode?mode=forgiving",
        ] {
            let (head, _, _) = run(&rt, post(target, b"AAAA"));
            assert!(head.starts_with("HTTP/1.1 400"), "{target}: {head}");
        }
    }

    #[test]
    fn metrics_endpoint_renders_text() {
        let rt = router();
        let _ = run(&rt, post("/encode", b"count me"));
        let (head, body, _) = run(&rt, get("/metrics"));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        let text = String::from_utf8(body).unwrap();
        assert!(text.contains("b64simd_requests_total"), "{text}");
        assert!(text.contains("b64simd_http_requests_total"), "{text}");
    }

    #[test]
    fn streamed_encode_roundtrip() {
        let rt = router();
        let mut session = SessionState::new(4);
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let mut wire = Vec::new();
        let work = HttpWork { job: HttpJob::StreamBegin(post("/encode", b"")), draining: false };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        wire.extend_from_slice(&out);
        for piece in data.chunks(7777) {
            let work =
                HttpWork { job: HttpJob::StreamChunk(piece.to_vec()), draining: false };
            let (out, close) = respond(work, &rt, &mut session, Vec::new());
            assert!(!close);
            wire.extend_from_slice(&out);
        }
        let work = HttpWork { job: HttpJob::StreamEnd { close: false }, draining: false };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        wire.extend_from_slice(&out);
        let (head, body) = split_response(&wire);
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains("Transfer-Encoding: chunked"), "{head}");
        assert_eq!(body, BlockCodec::new(Alphabet::standard()).encode(&data));
        assert_eq!(session.open_count(), 0, "stream closed");
    }

    #[test]
    fn streamed_decode_error_truncates() {
        let rt = router();
        let mut session = SessionState::new(4);
        let work = HttpWork { job: HttpJob::StreamBegin(post("/decode", b"")), draining: false };
        let (_, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        let work =
            HttpWork { job: HttpJob::StreamChunk(b"!!!!not base64".to_vec()), draining: false };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(close, "mid-stream decode error closes");
        assert!(out.is_empty(), "no terminal chunk after a mid-stream error");
        assert_eq!(session.open_count(), 0);
    }

    #[test]
    fn streamed_begin_error_swallows_body() {
        let rt = router();
        let mut session = SessionState::new(4);
        let work = HttpWork {
            job: HttpJob::StreamBegin(post("/decode?mode=wat", b"")),
            draining: false,
        };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close, "keep reading the body");
        let (head, _) = split_response(&out);
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        // Body chunks find no stream and answer nothing.
        let work = HttpWork { job: HttpJob::StreamChunk(b"AAAA".to_vec()), draining: false };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(out.is_empty() && !close);
        let work = HttpWork { job: HttpJob::StreamEnd { close: false }, draining: false };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(out.is_empty() && !close, "exactly one response per request");
    }

    #[test]
    fn rate_limited_immediate_counts_metric() {
        let rt = router();
        let mut session = SessionState::new(4);
        let work = HttpWork {
            job: HttpJob::Immediate {
                status: 429,
                message: "rate limit exceeded\n".into(),
                close: false,
            },
            draining: false,
        };
        let (out, close) = respond(work, &rt, &mut session, Vec::new());
        let (head, _) = split_response(&out);
        assert!(head.starts_with("HTTP/1.1 429"), "{head}");
        assert!(!close);
        assert_eq!(
            rt.metrics().rate_limited.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn debug_trace_returns_json() {
        let rt = router();
        let (head, body, _) = run(&rt, get("/debug/trace"));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains("Content-Type: application/json"), "{head}");
        let text = String::from_utf8(body).unwrap();
        assert!(
            text.trim_start().starts_with('['),
            "trace body is a JSON array: {text}"
        );
        // Method guard matches the other ops routes.
        let (head, _, _) = run(&rt, post("/debug/trace", b""));
        assert!(head.starts_with("HTTP/1.1 405"), "{head}");
    }

    #[test]
    fn streamed_requests_feed_the_latency_histogram() {
        // The coverage-audit regression: bodies on the streaming path
        // bypass the router, so `respond` itself must record latency.
        let rt = router();
        let mut session = SessionState::new(4);
        let before = rt.metrics().latency.count();
        let work = HttpWork { job: HttpJob::StreamBegin(post("/decode", b"")), draining: false };
        let (_, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        let work = HttpWork { job: HttpJob::StreamChunk(b"aGVsbG8=".to_vec()), draining: false };
        let (_, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        let work = HttpWork { job: HttpJob::StreamEnd { close: false }, draining: false };
        let (_, close) = respond(work, &rt, &mut session, Vec::new());
        assert!(!close);
        assert!(
            rt.metrics().latency.count() > before,
            "streamed gateway requests must advance the latency count"
        );
        // The wrapped-encode bypass records too.
        let before = rt.metrics().latency.count();
        let (head, _, _) = run(&rt, post("/encode?wrap=76", &[0xA5u8; 64]));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(rt.metrics().latency.count() > before);
    }

    #[test]
    fn codec_param_routes_hex_and_base32() {
        let rt = router();
        let data = b"foobar".to_vec();
        let (head, hex, _) = run(&rt, post("/encode?codec=hex", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(hex, crate::codec::HexCodec::new().encode(&data));
        let (head, decoded, _) = run(&rt, post("/decode?codec=hex", &hex));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(decoded, data);
        let (head, b32, _) = run(&rt, post("/encode?codec=base32", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(b32, b"MZXW6YTBOI======");
        let (head, decoded, _) = run(&rt, post("/decode?codec=base32", &b32));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(decoded, data);
        // `codec=` also reaches the base64 aliases.
        let (head, b64, _) = run(&rt, post("/encode?codec=base64url", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(b64, BlockCodec::new(Alphabet::url()).encode(&data));
        for target in [
            "/encode?codec=hex&wrap=76",          // wrap needs a base64 codec
            "/encode?codec=hex&alphabet=standard", // pick one selector
            "/encode?codec=rot13",                // unknown name
            "/datauri?codec=hex",                 // data URIs are base64-only
        ] {
            let (head, _, _) = run(&rt, post(target, b"x"));
            assert!(head.starts_with("HTTP/1.1 400"), "{target}: {head}");
        }
    }

    #[test]
    fn codecs_register_then_use_on_same_session() {
        let rt = router();
        let mut session = SessionState::new(4);
        let run_in = |session: &mut SessionState, req: HttpRequest| {
            let work = HttpWork { job: HttpJob::Request(req), draining: false };
            let (out, _) = respond(work, &rt, session, Vec::new());
            split_response(&out)
        };
        let (head, body) = run_in(&mut session, get("/codecs"));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        let listing = String::from_utf8(body).unwrap();
        assert!(listing.contains("0 standard"), "{listing}");
        assert!(listing.contains("3 hex"), "{listing}");
        assert!(listing.contains("4 base32"), "{listing}");
        // Register standard-with-'!' (char 62 swapped) and round-trip
        // through it on the same connection.
        let mut chars = *Alphabet::standard().chars();
        chars[62] = b'!';
        let (head, body) = run_in(&mut session, post("/codecs?name=bang", &chars));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(body, b"64\n", "first dynamic id");
        let data = vec![0xFBu8; 3]; // leading 6 bits = 62 → '!'
        let (head, enc) = run_in(&mut session, post("/encode?codec=bang", &data));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(enc.contains(&b'!'), "{enc:?}");
        let (head, dec) = run_in(&mut session, post("/decode?codec=bang", &enc));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert_eq!(dec, data);
        let (head, body) = run_in(&mut session, get("/codecs"));
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(String::from_utf8(body).unwrap().contains("64 bang"));
        // Registrations are connection-scoped: a fresh session rejects
        // the name.
        let mut other = SessionState::new(4);
        let (head, _) = run_in(&mut other, post("/encode?codec=bang", b"x"));
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        // Bad registrations: short table, missing name, duplicate name.
        let (head, _) = run_in(&mut session, post("/codecs?name=short", &chars[..10]));
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        let (head, _) = run_in(&mut session, post("/codecs", &chars));
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        let (head, _) = run_in(&mut session, post("/codecs?name=bang", &chars));
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
    }

    #[test]
    fn timeout_and_panic_responses_close() {
        let t = String::from_utf8(timeout_response("timeout: idle connection")).unwrap();
        assert!(t.starts_with("HTTP/1.1 408"), "{t}");
        assert!(t.contains("Connection: close"), "{t}");
        let p = String::from_utf8(panic_response()).unwrap();
        assert!(p.starts_with("HTTP/1.1 500"), "{p}");
        assert!(p.contains("Connection: close"), "{p}");
    }
}
