//! Event-driven connection subsystem: sharded epoll readiness loops,
//! pooled nonblocking framing, zero-copy replies, batched fan-in to the
//! SIMD backend.
//!
//! The paper's codecs run at memcpy speed only while they stay fed. The
//! original transport spawned one blocking thread per TCP connection
//! and hard-capped at a few hundred — the wrong shape for many
//! mostly-idle clients, and the wrong shape for batching: work arrived
//! on as many threads as there were sockets. This module inverts that:
//! **many streams, a few readiness loops, a fixed worker set**, so
//! thousands of connections multiplex onto the handful of cores doing
//! actual SIMD work, and concurrent requests from different sockets
//! coalesce in the coordinator's batcher exactly as they would from a
//! thread pool.
//!
//! ## Architecture
//!
//! ```text
//!  clients ──► SO_REUSEPORT ──► [reactor shard × N (epoll, edge-triggered)]
//!              (kernel hash)      │  per-conn: FrameMachine ── inbox ─┐ WorkItem
//!                                 │            WriteQueue ◄─ adopt ─┐ ▼
//!                                 │                              [workers xM]
//!                                 ◄── per-shard eventfd ◄─ Completion │
//!                                                          Router::process_into
//!                                                          (batched / direct SIMD)
//! ```
//!
//! * [`sys`] — direct `extern "C"` bindings to `epoll_create1` /
//!   `epoll_ctl` / `epoll_wait`, `eventfd`, and the `SO_REUSEPORT`
//!   listener group (std already links libc; no crates), wrapped in
//!   owned-fd types;
//! * [`buffer`] — a free-list pool of read/write buffers. **Lifetimes:**
//!   a connection borrows two buffers at accept (frame accumulation +
//!   write queue) and returns them at close; buffers that ballooned
//!   past the retain cap are dropped instead of parked, so the pool's
//!   resident footprint stays bounded while steady-state accept/close
//!   churn never touches the allocator. Each shard owns its own pool —
//!   no cross-shard synchronization on the buffer path;
//! * [`frame`] — incremental framing: [`frame::FrameMachine`] peels
//!   complete length-prefixed frames out of arbitrarily torn reads,
//!   [`frame::WriteQueue`] survives partial writes until the next
//!   `EPOLLOUT`, and [`frame::ReplySink`] builds complete reply frames
//!   in place for the zero-copy response path;
//! * [`http`] — the HTTP/1.1 gateway: a second wire protocol on the
//!   same shards. Listeners carry a [`http::Protocol`] tag; accepted
//!   connections route to either the native `FrameMachine` or the
//!   gateway's `HttpMachine`, and both feed the same workers, session
//!   streaming state and metrics;
//! * `conn` — per-connection state and the backpressure caps
//!   (pipelining depth, write high-water mark) plus the lifecycle
//!   deadline timestamps (idle / read-stall / write-stall);
//! * `driver` — the epoll reactor shards plus the shared worker pool;
//! * `uring` — the io_uring reactor shards: the same shard/worker/
//!   lifecycle contract driven by submission/completion rings with
//!   kernel-registered read buffers instead of per-fd readiness
//!   syscalls (selected with `B64SIMD_TRANSPORT=uring`; falls back to
//!   epoll, with a logged notice, on kernels without io_uring);
//! * `timer` — the per-shard deadline wheel whose earliest entry
//!   becomes that reactor's `epoll_wait` timeout (slow-loris and
//!   write-stall peers are shed with a typed error frame);
//! * [`faults`] — deterministic, seeded syscall fault injection
//!   (`faults` cargo feature + `B64SIMD_FAULTS` plan; zero-cost
//!   identity shims when the feature is off).
//!
//! ## Reactor shards
//!
//! `ServerConfig::reactors` (env `B64SIMD_REACTORS`, default = the
//! host's cores) readiness loops each own a `SO_REUSEPORT` listener on
//! the same address; the kernel hashes incoming connections across
//! them, so there is no shared accept lock and no cross-shard state on
//! the socket path. Each shard owns its connection slab, buffer pool
//! and completion queue outright — the only shared pieces are the
//! worker pool (so cross-connection batching still spans every shard),
//! the connection-cap `ConnLimiter` (the busy frame fires on the
//! global cap regardless of which shard a connection hashed to) and
//! the metrics, where per-shard counters roll up into the global set.
//! `reactors = 1` is exactly the old single-loop transport.
//!
//! ## Readiness loop ↔ batcher handoff
//!
//! A loop owns its sockets and never executes codec work; workers
//! execute codec work and never touch a socket. A parsed request
//! travels as a `WorkItem` (connection token + message + shared session
//! state + the owning shard's completion queue and eventfd) over one
//! mpsc channel shared by every shard; the worker runs it through
//! [`crate::coordinator::Router`] — where cross-connection batching,
//! admission ([`crate::coordinator::backpressure::Gate`]) and the
//! deferred-error model live — and pushes the finished reply frame on
//! the owning shard's completion queue, signalling its eventfd. The
//! loop drains completions on that wakeup, hands the bytes to the
//! connection, and re-arms reading. At most one request per connection
//! is in flight, preserving the wire's request/response order;
//! connection-level admission is a
//! [`crate::coordinator::backpressure::ConnLimiter`] whose refusals are
//! answered with a typed busy frame rather than a silent drop.
//!
//! ## Zero-copy replies
//!
//! By default (`ServerConfig::zero_copy`, env `B64SIMD_ZEROCOPY`) a
//! worker does not serialize a reply `Message` at all: it opens a
//! frame in a [`frame::ReplySink`], reserves the length prefix, and
//! the router's sink entry points let the engine's `_policy` kernels
//! encode/decode the payload *in place* — for ≥ one-batch payloads the
//! non-temporal store path streams cache lines straight into the
//! socket-bound buffer. The loop then *adopts* the finished buffer
//! into the connection's [`frame::WriteQueue`] (a pointer swap when
//! the queue is drained) instead of memcpying it. The `Vec`
//! serialization path remains selectable as the differential
//! reference, and both paths produce byte-identical frames.
//!
//! Everything below `driver` is Linux-only (`epoll` / `io_uring`); the
//! portable pieces ([`buffer`], [`frame`]) are shared, and non-Linux
//! hosts fall back to the thread-per-connection transport
//! ([`crate::server::Transport::Threaded`]).

pub mod buffer;
pub mod faults;
pub mod frame;
pub mod http;

#[cfg(target_os = "linux")]
pub mod sys;

#[cfg(target_os = "linux")]
pub(crate) mod conn;

#[cfg(target_os = "linux")]
pub(crate) mod driver;

#[cfg(target_os = "linux")]
pub(crate) mod uring;

#[cfg(target_os = "linux")]
pub(crate) mod timer;

pub use buffer::BufferPool;
pub use frame::{FrameMachine, ReplySink, WriteQueue};
