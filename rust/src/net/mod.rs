//! Event-driven connection subsystem: epoll readiness loop, pooled
//! nonblocking framing, batched fan-in to the SIMD backend.
//!
//! The paper's codecs run at memcpy speed only while they stay fed. The
//! original transport spawned one blocking thread per TCP connection
//! and hard-capped at a few hundred — the wrong shape for many
//! mostly-idle clients, and the wrong shape for batching: work arrived
//! on as many threads as there were sockets. This module inverts that:
//! **many streams, one readiness loop, a fixed worker set**, so
//! thousands of connections multiplex onto the handful of cores doing
//! actual SIMD work, and concurrent requests from different sockets
//! coalesce in the coordinator's batcher exactly as they would from a
//! thread pool.
//!
//! ## Architecture
//!
//! ```text
//!  clients ──► accept ─► [readiness loop (epoll, edge-triggered)]
//!                          │  per-conn: FrameMachine ── inbox ─┐ WorkItem
//!                          │            WriteQueue ◄─ frame ─┐ ▼
//!                          │                                [workers xN]
//!                          ◄──────────── eventfd ◄─ Completion │
//!                                                     Router::process
//!                                                     (batched SIMD)
//! ```
//!
//! * [`sys`] — direct `extern "C"` bindings to `epoll_create1` /
//!   `epoll_ctl` / `epoll_wait` / `eventfd` (std already links libc; no
//!   crates), wrapped in owned-fd types;
//! * [`buffer`] — a free-list pool of read/write buffers. **Lifetimes:**
//!   a connection borrows two buffers at accept (frame accumulation +
//!   write queue) and returns them at close; buffers that ballooned
//!   past the retain cap are dropped instead of parked, so the pool's
//!   resident footprint stays bounded while steady-state accept/close
//!   churn never touches the allocator;
//! * [`frame`] — incremental framing: [`frame::FrameMachine`] peels
//!   complete length-prefixed frames out of arbitrarily torn reads,
//!   [`frame::WriteQueue`] survives partial writes until the next
//!   `EPOLLOUT`;
//! * [`conn`] — per-connection state and the backpressure caps
//!   (pipelining depth, write high-water mark);
//! * [`driver`] — the loop itself plus the worker pool.
//!
//! ## Readiness loop ↔ batcher handoff
//!
//! The loop owns every socket and never executes codec work; workers
//! execute codec work and never touch a socket. A parsed request
//! travels as a `WorkItem` (connection token + message + shared session
//! state) over an mpsc channel; the worker runs it through
//! [`crate::coordinator::Router`] — where cross-connection batching,
//! admission ([`crate::coordinator::backpressure::Gate`]) and the
//! deferred-error model live — serializes the reply frame, pushes it on
//! a completion queue and signals an eventfd. The loop drains
//! completions on that wakeup, queues the bytes, and re-arms reading.
//! At most one request per connection is in flight, preserving the
//! wire's request/response order; connection-level admission is a
//! [`crate::coordinator::backpressure::ConnLimiter`] whose refusals are
//! answered with a typed busy frame rather than a silent drop.
//!
//! Everything below [`driver`] is Linux-only (`epoll`); the portable
//! pieces ([`buffer`], [`frame`]) are shared, and non-Linux hosts fall
//! back to the thread-per-connection transport
//! ([`crate::server::Transport::Threaded`]).

pub mod buffer;
pub mod frame;

#[cfg(target_os = "linux")]
pub mod sys;

#[cfg(target_os = "linux")]
pub(crate) mod conn;

#[cfg(target_os = "linux")]
pub(crate) mod driver;

pub use buffer::BufferPool;
pub use frame::{FrameMachine, WriteQueue};
