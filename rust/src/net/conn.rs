//! Per-connection state owned by the readiness loop.
//!
//! A connection is a nonblocking socket plus the incremental machinery
//! its owning reactor shard needs between readiness events: the
//! [`FrameMachine`] accumulating torn request frames, the
//! [`WriteQueue`] holding partially written responses (replies arrive
//! as whole adopted buffers on the zero-copy path), a bounded inbox of
//! parsed-but-undispatched requests, and the chunked-stream
//! [`SessionState`] shared with whichever worker is executing this
//! connection's current request. A connection lives and dies on one
//! shard: its slab slot, buffers and epoll registration never cross
//! loops.
//!
//! Ordering contract: at most one request per connection is in flight
//! on the worker pool (`busy`), so responses go out in request order —
//! the same lockstep semantics the thread-per-connection transport
//! gives — while *different* connections' requests run concurrently
//! (across shards too, since the worker pool is shared), which is what
//! feeds the coordinator's cross-request batching.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::buffer::BufferPool;
use super::frame::{FrameMachine, WriteQueue};
use super::http::{HttpMachine, HttpWork};
use crate::coordinator::backpressure::ConnPermit;
use crate::coordinator::state::SessionState;
use crate::obs::clock::{Proto, ReqClock};
use crate::server::proto::{Message, ProtoError};

/// Parsed requests a connection may queue ahead of dispatch (pipelining
/// depth). Beyond this the loop stops reading the socket — kernel
/// buffers and TCP flow control push back on the client.
pub(crate) const INBOX_CAP: usize = 64;

/// Pending response bytes above which the loop stops reading new
/// requests from this connection until the socket drains (a client that
/// sends but never reads cannot balloon the write queue).
pub(crate) const WRITE_HIGH_WATER: usize = 4 << 20;

/// The per-connection request parser: which wire protocol this socket
/// speaks. Decided once at accept time by the listener's
/// [`super::http::Protocol`] tag and fixed for the connection's life;
/// everything downstream of parsing (inbox, workers, write queue,
/// deadlines) is protocol-agnostic.
pub(crate) enum Machine {
    /// Length-prefixed native frames.
    Native(FrameMachine),
    /// Incremental HTTP/1.1 requests (boxed: the HTTP parser state is
    /// much larger than `FrameMachine`, and native is the common case).
    Http(Box<HttpMachine>),
}

impl Machine {
    /// Feed raw socket bytes to the parser.
    pub fn push(&mut self, data: &[u8]) {
        match self {
            Machine::Native(m) => m.push(data),
            Machine::Http(m) => m.push(data),
        }
    }

    /// Bytes accumulated but not yet consumed as complete requests
    /// (drives the read-stall deadline at frame granularity).
    pub fn buffered(&self) -> usize {
        match self {
            Machine::Native(m) => m.buffered(),
            Machine::Http(m) => m.buffered(),
        }
    }

    /// Recover the accumulation buffer for the pool.
    pub fn into_buf(self) -> Vec<u8> {
        match self {
            Machine::Native(m) => m.into_buf(),
            Machine::Http(m) => m.into_buf(),
        }
    }
}

/// One parsed unit of work awaiting dispatch: a native request frame or
/// an HTTP job. Workers branch on this to pick the reply encoding.
pub(crate) enum Job {
    Native(Message),
    Http(HttpWork),
}

/// A parsed job paired with its request-lifecycle clock. The clock is
/// born (and parse-stamped) the moment the job leaves the protocol
/// machine, rides to the worker inside the [`super::driver`] work item,
/// and comes back with the completion so the drain step can record
/// stage latencies and park it on the [`WriteQueue`] for flush
/// attribution.
pub(crate) struct Inbound {
    pub job: Job,
    pub clock: ReqClock,
}

pub(crate) struct Conn {
    pub stream: TcpStream,
    pub machine: Machine,
    pub write: WriteQueue,
    pub inbox: VecDeque<Inbound>,
    /// Stream-session state; locked by at most one worker at a time
    /// (the single in-flight request) and never by the loop.
    pub session: Arc<Mutex<SessionState>>,
    /// Slab generation, folded into the epoll token so completions for
    /// a closed-and-reused slot are recognized as stale.
    pub epoch: u32,
    /// One request is on the worker pool; responses restore order.
    pub busy: bool,
    /// Edge-triggered read readiness, latched until `read` says
    /// `WouldBlock` (backpressure may pause reads while it stays set).
    pub readable: bool,
    /// Peer finished sending; close once every queued byte is answered.
    pub eof: bool,
    /// A malformed/oversized frame poisoned the stream: stop reading
    /// and parsing, but still answer the requests parsed before it
    /// (the threaded transport replies to each frame before reading
    /// the next, and the transports must answer byte-identically).
    pub corrupt: bool,
    /// Last observed progress (bytes read, bytes written, or a reply
    /// delivered); anchors the idle deadline once the connection is
    /// quiescent.
    pub last_activity: Instant,
    /// When the partial frame at the head of the accumulator started
    /// arriving. Reset every time a *complete* frame parses — progress
    /// is measured at frame granularity, so a slow-loris peer dripping
    /// header bytes cannot refresh the deadline — and cleared when the
    /// accumulator empties.
    pub frame_start: Option<Instant>,
    /// Last time the write queue shrank (or was empty); anchors the
    /// write-stall deadline while bytes are pending.
    pub write_progress: Instant,
    /// RAII connection-cap slot ([`ConnPermit`]); released on teardown.
    _permit: ConnPermit,
}

impl Conn {
    pub fn new(
        stream: TcpStream,
        epoch: u32,
        max_streams: usize,
        pool: &mut BufferPool,
        permit: ConnPermit,
        machine: Machine,
    ) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            machine,
            write: WriteQueue::new(pool.get()),
            inbox: VecDeque::new(),
            session: Arc::new(Mutex::new(SessionState::new(max_streams))),
            epoch,
            busy: false,
            // Latch optimistically: bytes may have landed between
            // `accept` and the epoll registration.
            readable: true,
            eof: false,
            corrupt: false,
            last_activity: now,
            frame_start: None,
            write_progress: now,
            _permit: permit,
        }
    }

    /// Peel buffered requests into the inbox (up to [`INBOX_CAP`]);
    /// returns how many were parsed. Native protocol errors are fatal
    /// for the connection; the HTTP machine never errors here — it
    /// reports malformed input as an in-band error-response job and
    /// poisons itself.
    pub fn parse_into_inbox(&mut self) -> Result<usize, ProtoError> {
        let mut parsed = 0;
        while self.inbox.len() < INBOX_CAP {
            let (job, proto) = match &mut self.machine {
                Machine::Native(m) => (m.next_frame()?.map(Job::Native), Proto::Native),
                Machine::Http(m) => (
                    m.next_job()
                        .map(|job| Job::Http(HttpWork { job, draining: false })),
                    Proto::Http,
                ),
            };
            match job {
                Some(job) => {
                    let clock = ReqClock::new(proto);
                    clock.stamp_parse();
                    self.inbox.push_back(Inbound { job, clock });
                    parsed += 1;
                }
                None => break,
            }
        }
        Ok(parsed)
    }

    /// Whether this connection speaks HTTP (controls the encoding of
    /// loop-originated notices: timeout and refusal responses).
    pub fn is_http(&self) -> bool {
        matches!(self.machine, Machine::Http(_))
    }

    /// Whether the loop should issue another `read` for this connection.
    pub fn wants_read(&self) -> bool {
        self.readable
            && !self.eof
            && self.inbox.len() < INBOX_CAP
            && self.write.pending() < WRITE_HIGH_WATER
    }

    /// Every parsed request answered and written: with `eof` set this
    /// is the close condition. A torn frame still sitting in the
    /// accumulator is *not* counted — the peer can never complete it
    /// after EOF, so it is discarded with the connection (the pump
    /// parses before checking this, so the remainder is never a
    /// complete frame).
    pub fn drained(&self) -> bool {
        !self.busy && self.inbox.is_empty() && self.write.pending() == 0
    }

    /// Return pooled buffers; the socket and the cap permit release on
    /// drop.
    pub fn teardown(self, pool: &mut BufferPool) {
        pool.put(self.machine.into_buf());
        pool.put(self.write.into_buf());
    }
}
