//! Deterministic syscall fault injection for the transport layer.
//!
//! The paper's discipline for inputs — every invalid byte must be
//! detected, at full speed, on every path — applies equally to the I/O
//! plane: every error arm in the readiness loop must be reachable and
//! tested, not just written. This module is a thin shim over the points
//! where the transport touches the kernel (`read`, `write`, `accept`,
//! `epoll_wait`, buffer-pool refill). Compiled without the `faults`
//! cargo feature every helper is an `#[inline(always)]` identity —
//! zero cost on the hot path. With the feature on, the
//! `B64SIMD_FAULTS` environment variable selects a *deterministic
//! seeded plan*:
//!
//! ```text
//! B64SIMD_FAULTS="seed=42,read.eintr=20,read.short=10,write.short=30,\
//!                 write.eagain=5,accept.fail=2,pool.empty=10,epoll.eintr=5,\
//!                 uring.setup.fail=3,uring.enter.eintr=5,cqe.short=25"
//! ```
//!
//! Each `point=percent` entry gives the probability (integer percent)
//! that the named injection point fires on a given call. Decisions come
//! from a per-thread xorshift64 generator seeded from `seed` plus a
//! per-thread counter, so a single-reactor run is exactly reproducible
//! and a sharded run is reproducible per thread. Injected faults are
//! *synthesized before* the real syscall (or applied to its buffer
//! length), so the kernel-visible behaviour stays valid — the server
//! under faults must still answer byte-identically to the
//! threaded-transport oracle, just along its error-recovery arms.
//!
//! The global injected-fault count is surfaced through
//! [`injected`] and mirrored into `Metrics::faults_injected` when a
//! stats report is taken.

#[cfg(feature = "faults")]
pub(crate) use imp::*;

#[cfg(feature = "faults")]
mod imp {
    use std::io::{self, Read};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::OnceLock;

    /// Probability (integer percent) per injection point.
    #[derive(Default, Debug, Clone, Copy)]
    struct Plan {
        seed: u64,
        read_eintr: u8,
        read_short: u8,
        write_short: u8,
        write_eagain: u8,
        accept_fail: u8,
        pool_empty: u8,
        epoll_eintr: u8,
        uring_setup_fail: u8,
        uring_enter_eintr: u8,
        cqe_short: u8,
    }

    fn plan() -> &'static Plan {
        static PLAN: OnceLock<Plan> = OnceLock::new();
        PLAN.get_or_init(|| {
            let mut p = Plan::default();
            let Ok(spec) = std::env::var("B64SIMD_FAULTS") else { return p };
            for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let Some((key, val)) = part.split_once('=') else {
                    crate::log_warn!("faults", "ignoring malformed B64SIMD_FAULTS entry '{part}'");
                    continue;
                };
                let Ok(n) = val.trim().parse::<u64>() else {
                    crate::log_warn!("faults", "ignoring non-numeric B64SIMD_FAULTS value '{part}'");
                    continue;
                };
                let pct = n.min(100) as u8;
                match key.trim() {
                    "seed" => p.seed = n,
                    "read.eintr" => p.read_eintr = pct,
                    "read.short" => p.read_short = pct,
                    "write.short" => p.write_short = pct,
                    "write.eagain" => p.write_eagain = pct,
                    "accept.fail" => p.accept_fail = pct,
                    "pool.empty" => p.pool_empty = pct,
                    "epoll.eintr" => p.epoll_eintr = pct,
                    "uring.setup.fail" => p.uring_setup_fail = pct,
                    "uring.enter.eintr" => p.uring_enter_eintr = pct,
                    "cqe.short" => p.cqe_short = pct,
                    other => {
                        crate::log_warn!("faults", "ignoring unknown B64SIMD_FAULTS key '{other}'")
                    }
                }
            }
            p
        })
    }

    /// Total faults injected, process-wide.
    static INJECTED: AtomicU64 = AtomicU64::new(0);

    /// Distinct seeds per thread so shards do not share one stream.
    static THREAD_SALT: AtomicU64 = AtomicU64::new(0);

    std::thread_local! {
        static RNG: std::cell::Cell<u64> = std::cell::Cell::new({
            let salt = THREAD_SALT.fetch_add(1, Ordering::Relaxed);
            // Never zero (xorshift's absorbing state).
            (plan().seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)) | 1
        });
    }

    fn next_u64() -> u64 {
        RNG.with(|cell| {
            let mut x = cell.get();
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            cell.set(x);
            x
        })
    }

    /// Stable FNV-1a hash of an injection-site name, recorded as the
    /// Fault event's `detail` so a trace dump identifies which site
    /// fired without carrying strings through the ring.
    pub(crate) fn site_hash(site: &str) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for b in site.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_0000_01B3);
        }
        h
    }

    /// Roll the dice for one injection point; counts a hit and records
    /// it as a flight-recorder Fault event on the calling shard's
    /// ambient recorder (workers have none; the count still advances).
    fn fire(percent: u8, site: &str) -> bool {
        if percent == 0 {
            return false;
        }
        let hit = next_u64() % 100 < percent as u64;
        if hit {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            crate::obs::recorder::record_here(
                crate::obs::recorder::EventKind::Fault,
                0,
                site_hash(site),
            );
            crate::log_debug!("faults", "injected fault at {site}");
        }
        hit
    }

    /// Faults injected so far (mirrored into `Metrics::faults_injected`
    /// by the stats path).
    pub fn injected() -> u64 {
        INJECTED.load(Ordering::Relaxed)
    }

    /// `read(2)` shim: may synthesize `EINTR` before the syscall, or
    /// truncate the buffer so the real read comes back short (≤ 7
    /// bytes), tearing frames across reads.
    pub(crate) fn read_stream(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<usize> {
        if fire(plan().read_eintr, "read.eintr") {
            return Err(io::ErrorKind::Interrupted.into());
        }
        let cap = if !buf.is_empty() && fire(plan().read_short, "read.short") {
            buf.len().min(7)
        } else {
            buf.len()
        };
        stream.read(&mut buf[..cap])
    }

    /// `accept(2)` shim: may synthesize the transient failures a
    /// listener backlog really produces (`ECONNABORTED`, `EINTR`).
    pub(crate) fn accept(listener: &TcpListener) -> io::Result<(TcpStream, SocketAddr)> {
        if fire(plan().accept_fail, "accept.fail") {
            let kind = if next_u64() % 2 == 0 {
                io::ErrorKind::ConnectionAborted
            } else {
                io::ErrorKind::Interrupted
            };
            return Err(kind.into());
        }
        listener.accept()
    }

    /// Should `BufferPool::get` pretend its free list is exhausted?
    pub(crate) fn pool_exhausted() -> bool {
        fire(plan().pool_empty, "pool.empty")
    }

    /// Should `Epoll::wait` behave as if a signal interrupted it once?
    pub(crate) fn epoll_eintr() -> bool {
        fire(plan().epoll_eintr, "epoll.eintr")
    }

    /// Should the (once-per-process) io_uring probe report the kernel
    /// unsupported? One roll at the cached probe rather than per setup
    /// call, so a plan produces a deterministic whole-process fallback
    /// to epoll instead of per-shard flakiness.
    pub(crate) fn uring_setup_fail() -> bool {
        fire(plan().uring_setup_fail, "uring.setup.fail")
    }

    /// Should `io_uring_enter` behave as if a signal interrupted it
    /// once? Exercises the same EINTR-retry arm `epoll.eintr` covers on
    /// the readiness loop.
    pub(crate) fn uring_enter_eintr() -> bool {
        fire(plan().uring_enter_eintr, "uring.enter.eintr")
    }

    /// Truncate a read op's length (≤ 7 bytes) before submission, so
    /// its completion comes back short and frames tear across reads —
    /// the CQE-side analogue of `read.short`.
    pub(crate) fn short_cqe(len: u32) -> u32 {
        if len > 7 && fire(plan().cqe_short, "cqe.short") {
            7
        } else {
            len
        }
    }

    /// `write(2)` shim wrapping the socket handed to
    /// `WriteQueue::write_to`: may synthesize `EAGAIN` (the queue keeps
    /// the bytes for a retry) or cap a write short (partial-write arm).
    pub(crate) struct FaultyWrite<'a, W: io::Write>(pub &'a mut W);

    impl<W: io::Write> io::Write for FaultyWrite<'_, W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if fire(plan().write_eagain, "write.eagain") {
                return Err(io::ErrorKind::WouldBlock.into());
            }
            let cap = if buf.len() > 1 && fire(plan().write_short, "write.short") {
                buf.len() / 2
            } else {
                buf.len()
            };
            self.0.write(&buf[..cap])
        }

        fn flush(&mut self) -> io::Result<()> {
            self.0.flush()
        }
    }

    /// Wrap a socket for fault-injected writes.
    pub(crate) fn wrap_write<W: io::Write>(w: &mut W) -> FaultyWrite<'_, W> {
        FaultyWrite(w)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn rng_streams_are_deterministic_per_thread() {
            // Two draws on this thread advance one xorshift stream;
            // restarting the process with the same seed would replay it.
            let a = next_u64();
            let b = next_u64();
            assert_ne!(a, b);
            assert_ne!(a, 0);
        }

        #[test]
        fn zero_percent_never_fires() {
            for _ in 0..1000 {
                assert!(!fire(0, "test.site"));
            }
        }

        #[test]
        fn site_hash_is_stable_and_distinct() {
            assert_eq!(site_hash("read.eintr"), site_hash("read.eintr"));
            assert_ne!(site_hash("read.eintr"), site_hash("write.short"));
        }
    }
}

/// Zero-cost identities when the `faults` feature is off.
#[cfg(not(feature = "faults"))]
mod off {
    #![allow(dead_code)]
    use std::io;
    use std::net::{SocketAddr, TcpListener, TcpStream};

    #[inline(always)]
    pub(crate) fn read_stream(
        stream: &mut TcpStream,
        buf: &mut [u8],
    ) -> io::Result<usize> {
        io::Read::read(stream, buf)
    }

    #[inline(always)]
    pub(crate) fn accept(listener: &TcpListener) -> io::Result<(TcpStream, SocketAddr)> {
        listener.accept()
    }

    #[inline(always)]
    pub(crate) fn pool_exhausted() -> bool {
        false
    }

    #[inline(always)]
    pub(crate) fn wrap_write<W: io::Write>(w: &mut W) -> &mut W {
        w
    }
}

#[cfg(not(feature = "faults"))]
pub(crate) use off::*;
