//! `b64simd` CLI — leader entrypoint for the codec service and tools.
//!
//! ```text
//! b64simd encode [--alphabet NAME | --codec NAME] [--stores POLICY] [--in FILE] [--out FILE]
//! b64simd decode [--alphabet NAME | --codec NAME] [--forgiving] [--stores POLICY] [--in FILE] [--out FILE]
//! b64simd serve  [--addr HOST:PORT] [--workers N] [--backend native|rust|pjrt]
//!                [--transport epoll|threaded] [--net-workers N] [--max-conns N]
//!                [--reactors N] [--zerocopy 0|1] [--http HOST:PORT]
//!                [--ratelimit REQS_PER_SEC]
//! b64simd selftest [--artifacts DIR]
//! b64simd model  [--figure 4 | --hardware]
//! b64simd opcount
//! ```
//!
//! Encode/decode run on the tier-dispatched `Engine` (AVX-512 VBMI →
//! AVX2 → SWAR → scalar block, detected once); set
//! `B64SIMD_TIER=avx512|avx2|swar|scalar` to force a tier. `--stores
//! temporal|nontemporal|auto|auto:<bytes>` (or `B64SIMD_STORES`) picks
//! the store policy for >LLC payloads — see `base64::stores`.
//!
//! `--codec NAME` selects any built-in registry codec — `standard`,
//! `url`, `imap`, `base64`, `base64url`, `hex`/`base16`, `base32`,
//! `base32hex` — through the same tier-dispatched kernels; `--alphabet`
//! keeps its base64-only meaning.

use std::io::{Read, Write};
use std::sync::Arc;

use b64simd::base64::{block::BlockCodec, Alphabet, Codec, Engine, Mode};
use b64simd::codec::{Base32Codec, CodecRegistry, CodecSel, HexCodec};
use b64simd::coordinator::backend::{native_factory, pjrt_factory, rust_factory};
use b64simd::coordinator::{Router, RouterConfig};
use b64simd::perfmodel::cache::{CacheModel, Machine, Op};
use b64simd::perfmodel::opcount;
use b64simd::runtime::{BlockExecutor, Manifest, Runtime};
use b64simd::server::{serve, ServerConfig};
use b64simd::workload::fig4_sizes;

/// Minimal flag parser: `--key value` and `--switch` styles.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn read_input(args: &Args) -> anyhow::Result<Vec<u8>> {
    match args.get("in") {
        Some(path) => Ok(std::fs::read(path)?),
        None => {
            let mut buf = Vec::new();
            std::io::stdin().read_to_end(&mut buf)?;
            Ok(buf)
        }
    }
}

fn write_output(args: &Args, data: &[u8]) -> anyhow::Result<()> {
    match args.get("out") {
        Some(path) => std::fs::write(path, data)?,
        None => {
            std::io::stdout().write_all(data)?;
            if data.last() != Some(&b'\n') && args.get("in").is_none() {
                // Friendly newline for terminal use.
                println!();
            }
        }
    }
    Ok(())
}

fn alphabet_arg(args: &Args) -> anyhow::Result<Alphabet> {
    let name = args.get("alphabet").unwrap_or("standard");
    Alphabet::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown alphabet '{name}'"))
}

/// Resolve `--codec` / `--alphabet` into a codec selector. `--codec`
/// accepts every built-in registry name (including `hex` and the two
/// base32 variants); `--alphabet` keeps its base64-only behaviour.
fn codec_arg(args: &Args) -> anyhow::Result<CodecSel> {
    match (args.get("codec"), args.get("alphabet")) {
        (Some(_), Some(_)) => anyhow::bail!("pass --alphabet or --codec, not both"),
        (Some(name), None) => CodecRegistry::new()
            .resolve(name)
            .ok_or_else(|| anyhow::anyhow!("unknown codec '{name}'")),
        (None, _) => Ok(CodecSel::Base64(alphabet_arg(args)?)),
    }
}

/// The `--stores` override for the non-base64 codecs, else the
/// process-wide default (`B64SIMD_STORES` / auto-at-LLC).
fn stores_arg(args: &Args) -> anyhow::Result<b64simd::base64::StorePolicy> {
    match args.get("stores") {
        Some(v) => b64simd::base64::StorePolicy::parse(v)
            .ok_or_else(|| anyhow::anyhow!("unknown store policy '{v}'")),
        None => Ok(b64simd::base64::stores::default_policy()),
    }
}

/// Apply a `--stores temporal|nontemporal|auto|auto:<bytes>` override to
/// a freshly built engine (the env override stays the default).
fn apply_stores_arg(engine: &mut Engine, args: &Args) -> anyhow::Result<()> {
    if let Some(v) = args.get("stores") {
        let policy = b64simd::base64::StorePolicy::parse(v)
            .ok_or_else(|| anyhow::anyhow!("unknown store policy '{v}'"))?;
        engine.set_policy(policy);
    }
    Ok(())
}

fn cmd_encode(args: &Args) -> anyhow::Result<()> {
    let sel = codec_arg(args)?;
    let data = read_input(args)?;
    let out = match sel {
        CodecSel::Base64(alphabet) => {
            let mut codec = Engine::new(alphabet);
            apply_stores_arg(&mut codec, args)?;
            codec.encode(&data)
        }
        CodecSel::Hex => {
            let codec = HexCodec::new();
            let mut out = vec![0u8; b64simd::codec::hex::encoded_len(data.len())];
            let n = codec.encode_slice_policy(&data, &mut out, stores_arg(args)?);
            out.truncate(n);
            out
        }
        CodecSel::Base32(variant) => {
            let codec = Base32Codec::new(variant);
            let mut out = vec![0u8; b64simd::codec::base32::encoded_len(data.len())];
            let n = codec.encode_slice_policy(&data, &mut out, stores_arg(args)?);
            out.truncate(n);
            out
        }
    };
    write_output(args, &out)
}

fn cmd_decode(args: &Args) -> anyhow::Result<()> {
    let sel = codec_arg(args)?;
    let mode = if args.has("forgiving") { Mode::Forgiving } else { Mode::Strict };
    let mut data = read_input(args)?;
    // Terminal convenience: strip one trailing newline.
    if data.last() == Some(&b'\n') {
        data.pop();
        if data.last() == Some(&b'\r') {
            data.pop();
        }
    }
    let decoded = match sel {
        CodecSel::Base64(alphabet) => {
            let mut codec = Engine::with_mode(alphabet, mode);
            apply_stores_arg(&mut codec, args)?;
            codec.decode(&data).map_err(|e| anyhow::anyhow!("{e}"))?
        }
        CodecSel::Hex => {
            let codec = HexCodec::new();
            let mut out = vec![0u8; b64simd::codec::hex::decoded_len(data.len())];
            let n = codec
                .decode_slice_policy(&data, &mut out, stores_arg(args)?)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            out.truncate(n);
            out
        }
        CodecSel::Base32(variant) => {
            let codec = Base32Codec::new(variant);
            let mut out = vec![0u8; b64simd::codec::base32::decoded_len_upper(data.len())];
            let n = codec
                .decode_slice_policy(&data, &mut out, mode, stores_arg(args)?)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            out.truncate(n);
            out
        }
    };
    write_output(args, &decoded)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr: std::net::SocketAddr = args.get("addr").unwrap_or("127.0.0.1:4648").parse()?;
    let workers: usize = args.get("workers").unwrap_or("2").parse()?;
    let backend_name = args.get("backend").unwrap_or("native");
    let factory = match backend_name {
        "pjrt" => pjrt_factory(Manifest::default_dir()),
        "rust" => rust_factory(),
        "native" => native_factory(),
        other => anyhow::bail!("unknown backend '{other}' (native|rust|pjrt)"),
    };
    let mut config = RouterConfig::default();
    config.scheduler.workers = workers;
    let router = Arc::new(Router::new(factory, config));
    let mut server_config = ServerConfig { addr, ..Default::default() };
    if let Some(t) = args.get("transport") {
        server_config.transport = b64simd::server::Transport::parse(t)
            .ok_or_else(|| anyhow::anyhow!("unknown transport '{t}' (epoll|threaded)"))?;
    }
    if let Some(n) = args.get("net-workers") {
        server_config.net_workers = n.parse()?;
    }
    if let Some(n) = args.get("max-conns") {
        server_config.max_connections = n.parse()?;
    }
    if let Some(n) = args.get("reactors") {
        server_config.reactors = n.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.get("zerocopy") {
        server_config.zero_copy = ServerConfig::parse_switch(v)
            .ok_or_else(|| anyhow::anyhow!("unknown zerocopy value '{v}' (0|1)"))?;
    }
    if let Some(h) = args.get("http") {
        server_config.http_addr = Some(h.parse().map_err(|e| {
            anyhow::anyhow!("invalid --http address '{h}': {e} (want e.g. 127.0.0.1:8040)")
        })?);
    }
    if let Some(r) = args.get("ratelimit") {
        let rate: f64 = r.parse()?;
        anyhow::ensure!(
            rate.is_finite() && rate >= 0.0,
            "invalid --ratelimit '{r}' (want requests/sec, 0 disables)"
        );
        server_config.rate_limit = rate;
    }
    let transport = server_config.transport;
    let (reactors, zero_copy) = (server_config.reactors, server_config.zero_copy);
    let handle = serve(router.clone(), server_config)?;
    b64simd::log_info!(
        "serve",
        "serving on {} (backend={backend_name}, workers={workers}, transport={}, reactors={reactors}, reply={})",
        handle.addr,
        transport.name(),
        if zero_copy { "zerocopy" } else { "vec" }
    );
    if let Some(http) = handle.http_addr {
        b64simd::log_info!("serve", "http gateway on {http}");
    }
    // SIGTERM/SIGINT request a graceful drain: stop accepting, answer
    // everything already parsed off the wire, flush, then exit 0 with a
    // final metrics report. SIGUSR1 dumps the per-shard flight-recorder
    // rings to stderr as JSON without disturbing the server. (Non-Linux
    // hosts keep the run-forever loop; the handler plumbing lives with
    // the rest of the Linux-only net code.)
    #[cfg(target_os = "linux")]
    {
        use b64simd::net::sys::{
            install_term_handler, install_usr1_handler, term_requested, usr1_requested,
        };
        install_term_handler()?;
        install_usr1_handler()?;
        let mut last_report = std::time::Instant::now();
        while !term_requested() {
            std::thread::sleep(std::time::Duration::from_millis(100));
            if usr1_requested() {
                b64simd::log_info!("serve", "SIGUSR1 received, dumping flight recorders");
                let dump = b64simd::obs::recorder::dump_json(128);
                let mut line = dump.into_bytes();
                line.push(b'\n');
                let _ = std::io::stderr().write_all(&line);
            }
            if last_report.elapsed() >= std::time::Duration::from_secs(30) {
                b64simd::log_info!("serve", "{}", router.metrics().report());
                last_report = std::time::Instant::now();
            }
        }
        b64simd::log_info!("serve", "termination signal received, draining connections");
        handle.shutdown();
        b64simd::log_info!("serve", "{}", router.metrics().report());
        return Ok(());
    }
    #[cfg(not(target_os = "linux"))]
    loop {
        std::thread::sleep(std::time::Duration::from_secs(30));
        b64simd::log_info!("serve", "{}", router.metrics().report());
    }
}

fn cmd_selftest(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let rt = Arc::new(Runtime::new(&dir)?);
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.manifest().artifacts.len());
    let ex = BlockExecutor::new(rt);
    anyhow::ensure!(ex.selftest()?, "roundtrip selftest FAILED");
    println!("roundtrip selftest: OK");
    // Cross-check PJRT against the Rust block codec on random data.
    let alphabet = Alphabet::standard();
    let data = b64simd::workload::random_bytes(48 * 100, 7);
    let pjrt = ex.encode_blocks(&data, alphabet.encode_table().as_bytes())?;
    let rust = BlockCodec::new(alphabet.clone()).encode(&data);
    anyhow::ensure!(pjrt == rust, "PJRT/Rust encode mismatch");
    let dec = ex.decode_blocks(&pjrt, alphabet.decode_table().as_bytes())?;
    anyhow::ensure!(dec.data == data, "PJRT decode mismatch");
    anyhow::ensure!(dec.err.iter().all(|e| e & 0x80 == 0), "spurious error flags");
    println!("PJRT vs Rust differential check: OK (100 blocks)");
    Ok(())
}

fn cmd_model(args: &Args) -> anyhow::Result<()> {
    let model = CacheModel::new(Machine::cannon_lake());
    if args.has("hardware") {
        let m = model.machine();
        println!("modeled machine: {} @ {} GHz (paper Table 2)", m.name, m.freq_ghz);
        for l in &m.levels {
            println!("  {:<5} {:>12} B  {:>6.1} GB/s", l.name, l.capacity, l.bandwidth_gbps);
        }
        return Ok(());
    }
    // Fig. 4 shape, modeled with the paper's machine parameters.
    println!("# modeled Fig.4 ({}), GB/s vs base64 bytes", model.machine().name);
    let sizes = fig4_sizes();
    for (label, op) in [("encode", Op::Encode), ("decode", Op::Decode)] {
        println!("\n## {label}");
        print!("{:>8}", "size");
        for name in ["memcpy", "scalar", "avx2", "avx512"] {
            print!("{name:>10}");
        }
        println!();
        for &s in &sizes {
            print!("{s:>8}");
            for name in ["memcpy", "scalar", "avx2", "avx512"] {
                let op = if name == "memcpy" { Op::Memcpy } else { op };
                print!("{:>10.2}", model.predict(name, op, s).gbps);
            }
            println!();
        }
    }
    Ok(())
}

fn usage() -> ! {
    // CLI usage text, not a log line: plain stderr, no level/timestamp.
    let _ = std::io::stderr().write_all(
        b"usage: b64simd <encode|decode|serve|selftest|model|opcount> [flags]\n\
          see README.md for details\n",
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else { usage() };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "encode" => cmd_encode(&args),
        "decode" => cmd_decode(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        "model" => cmd_model(&args),
        "opcount" => {
            print!("{}", opcount::render_table());
            Ok(())
        }
        _ => usage(),
    }
}
