//! The conventional per-byte lookup codec — the paper's "Chrome" baseline.
//!
//! Structure matches `modp_b64` (used by Chrome, constant ~1.5 GB/s encode
//! / 2.6 GB/s decode in the paper): a 3-byte-at-a-time encoder driven by a
//! 64-entry table and a 4-char-at-a-time decoder driven by a 128-entry
//! table with a sentinel for invalid bytes. No SWAR, no blocks — this is
//! the codec the vectorized ones are measured against (Fig. 4, Table 3).

use super::validate::{decode_tail, split_tail, DecodeError, Mode};
use super::{encoded_len, Alphabet, Codec};

/// Per-byte table-lookup codec.
#[derive(Debug, Clone)]
pub struct ScalarCodec {
    alphabet: Alphabet,
    mode: Mode,
}

impl ScalarCodec {
    pub fn new(alphabet: Alphabet) -> Self {
        Self { alphabet, mode: Mode::Strict }
    }

    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        Self { alphabet, mode }
    }

    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }
}

impl Codec for ScalarCodec {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn encode_into(&self, input: &[u8], out: &mut Vec<u8>) -> usize {
        let table = self.alphabet.encode_table();
        let pad = self.alphabet.pad();
        let start = out.len();
        out.reserve(encoded_len(input.len()));
        let mut chunks = input.chunks_exact(3);
        for chunk in &mut chunks {
            let (s1, s2, s3) = (chunk[0], chunk[1], chunk[2]);
            out.push(table.lookup(s1 >> 2));
            out.push(table.lookup((s1 << 4) | (s2 >> 4)));
            out.push(table.lookup((s2 << 2) | (s3 >> 6)));
            out.push(table.lookup(s3));
        }
        match chunks.remainder() {
            [] => {}
            [s1] => {
                out.push(table.lookup(s1 >> 2));
                out.push(table.lookup(s1 << 4));
                out.push(pad);
                out.push(pad);
            }
            [s1, s2] => {
                out.push(table.lookup(s1 >> 2));
                out.push(table.lookup((s1 << 4) | (s2 >> 4)));
                out.push(table.lookup(s2 << 2));
                out.push(pad);
            }
            _ => unreachable!(),
        }
        out.len() - start
    }

    fn decode_into(&self, input: &[u8], out: &mut Vec<u8>) -> Result<usize, DecodeError> {
        let table = self.alphabet.decode_table();
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let start = out.len();
        out.reserve(body.len() / 4 * 3 + 3);
        for (q, quad) in body.chunks_exact(4).enumerate() {
            let mut vals = [0u8; 4];
            for i in 0..4 {
                let c = quad[i];
                let v = table.lookup(c);
                // The OR trick covers non-ASCII (c >= 0x80) as well.
                if (c | v) & 0x80 != 0 {
                    return Err(DecodeError::InvalidByte { offset: q * 4 + i, byte: c });
                }
                vals[i] = v;
            }
            out.push((vals[0] << 2) | (vals[1] >> 4));
            out.push((vals[1] << 4) | (vals[2] >> 2));
            out.push((vals[2] << 6) | vals[3]);
        }
        decode_tail(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            out,
        )?;
        Ok(out.len() - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec() -> ScalarCodec {
        ScalarCodec::new(Alphabet::standard())
    }

    #[test]
    fn rfc4648_test_vectors() {
        // The canonical vectors from RFC 4648 §10.
        let cases: &[(&[u8], &[u8])] = &[
            (b"", b""),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foob", b"Zm9vYg=="),
            (b"fooba", b"Zm9vYmE="),
            (b"foobar", b"Zm9vYmFy"),
        ];
        let c = codec();
        for (raw, enc) in cases {
            assert_eq!(c.encode(raw), *enc);
            assert_eq!(c.decode(enc).unwrap(), *raw);
        }
    }

    #[test]
    fn decode_reports_exact_offset() {
        let c = codec();
        let err = c.decode(b"AAAA!AAA").unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 4, byte: b'!' });
    }

    #[test]
    fn decode_rejects_non_ascii() {
        let c = codec();
        let err = c.decode(&[b'A', b'A', 0xC3, b'A']).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 2, byte: 0xC3 });
    }

    #[test]
    fn url_variant() {
        let c = ScalarCodec::new(Alphabet::url());
        assert_eq!(c.encode(&[0xFB, 0xFF]), b"-_8=");
        assert_eq!(c.decode(b"-_8=").unwrap(), vec![0xFB, 0xFF]);
        assert!(codec().decode(b"-_8=").is_err());
    }

    #[test]
    fn forgiving_accepts_unpadded() {
        let c = ScalarCodec::with_mode(Alphabet::standard(), Mode::Forgiving);
        assert_eq!(c.decode(b"Zm8").unwrap(), b"fo");
        assert!(codec().decode(b"Zm8").is_err());
    }

    #[test]
    fn encode_into_appends() {
        let c = codec();
        let mut buf = b"prefix:".to_vec();
        let n = c.encode_into(b"foo", &mut buf);
        assert_eq!(n, 4);
        assert_eq!(buf, b"prefix:Zm9v");
    }
}
