//! The conventional per-byte lookup codec — the paper's "Chrome" baseline.
//!
//! Structure matches `modp_b64` (used by Chrome, constant ~1.5 GB/s encode
//! / 2.6 GB/s decode in the paper): a 3-byte-at-a-time encoder driven by a
//! 64-entry table and a 4-char-at-a-time decoder driven by a 128-entry
//! table with a sentinel for invalid bytes. No SWAR, no blocks — this is
//! the codec the vectorized ones are measured against (Fig. 4, Table 3).

use super::validate::{decode_quads_into, decode_tail_into, split_tail, DecodeError, Mode, Whitespace};
use super::{encoded_len, Alphabet, Codec};

/// Byte-at-a-time whitespace compaction — the reference implementation
/// of the engine's fused-decode staging step (and the compaction used by
/// the forced [`crate::base64::Tier::Scalar`] tier, so `B64SIMD_TIER=scalar`
/// exercises a fully scalar pipeline).
///
/// Copies non-skipped bytes from `src` to `dst` until `src` is exhausted
/// or `dst` is full; returns `(src_consumed, dst_written)`.
pub(crate) fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
    let (mut r, mut w) = (0usize, 0usize);
    while r < src.len() && w < dst.len() {
        let c = src[r];
        r += 1;
        if !ws.skips(c) {
            dst[w] = c;
            w += 1;
        }
    }
    (r, w)
}

/// Per-byte table-lookup codec.
#[derive(Debug, Clone)]
pub struct ScalarCodec {
    alphabet: Alphabet,
    mode: Mode,
}

impl ScalarCodec {
    /// Strict-mode codec for an alphabet.
    pub fn new(alphabet: Alphabet) -> Self {
        Self { alphabet, mode: Mode::Strict }
    }

    /// [`Self::new`] with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        Self { alphabet, mode }
    }

    /// The alphabet this codec was built for.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }
}

impl Codec for ScalarCodec {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let table = self.alphabet.encode_table();
        let pad = self.alphabet.pad();
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let mut w = 0;
        let mut chunks = input.chunks_exact(3);
        for chunk in &mut chunks {
            let (s1, s2, s3) = (chunk[0], chunk[1], chunk[2]);
            out[w] = table.lookup(s1 >> 2);
            out[w + 1] = table.lookup((s1 << 4) | (s2 >> 4));
            out[w + 2] = table.lookup((s2 << 2) | (s3 >> 6));
            out[w + 3] = table.lookup(s3);
            w += 4;
        }
        match chunks.remainder() {
            [] => {}
            [s1] => {
                out[w] = table.lookup(s1 >> 2);
                out[w + 1] = table.lookup(s1 << 4);
                out[w + 2] = pad;
                out[w + 3] = pad;
                w += 4;
            }
            [s1, s2] => {
                out[w] = table.lookup(s1 >> 2);
                out[w + 1] = table.lookup((s1 << 4) | (s2 >> 4));
                out[w + 2] = table.lookup(s2 << 2);
                out[w + 3] = pad;
                w += 4;
            }
            _ => unreachable!(),
        }
        debug_assert_eq!(w, total);
        w
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        // The OR trick inside `decode_quads_into` covers non-ASCII bytes
        // (c >= 0x80) as well.
        let w = decode_quads_into(body, self.alphabet.decode_table().as_bytes(), 0, out)?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec() -> ScalarCodec {
        ScalarCodec::new(Alphabet::standard())
    }

    #[test]
    fn rfc4648_test_vectors() {
        // The canonical vectors from RFC 4648 §10.
        let cases: &[(&[u8], &[u8])] = &[
            (b"", b""),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foob", b"Zm9vYg=="),
            (b"fooba", b"Zm9vYmE="),
            (b"foobar", b"Zm9vYmFy"),
        ];
        let c = codec();
        for (raw, enc) in cases {
            assert_eq!(c.encode(raw), *enc);
            assert_eq!(c.decode(enc).unwrap(), *raw);
        }
    }

    #[test]
    fn decode_reports_exact_offset() {
        let c = codec();
        let err = c.decode(b"AAAA!AAA").unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 4, byte: b'!' });
    }

    #[test]
    fn decode_rejects_non_ascii() {
        let c = codec();
        let err = c.decode(&[b'A', b'A', 0xC3, b'A']).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 2, byte: 0xC3 });
    }

    #[test]
    fn url_variant() {
        let c = ScalarCodec::new(Alphabet::url());
        assert_eq!(c.encode(&[0xFB, 0xFF]), b"-_8=");
        assert_eq!(c.decode(b"-_8=").unwrap(), vec![0xFB, 0xFF]);
        assert!(codec().decode(b"-_8=").is_err());
    }

    #[test]
    fn forgiving_accepts_unpadded() {
        let c = ScalarCodec::with_mode(Alphabet::standard(), Mode::Forgiving);
        assert_eq!(c.decode(b"Zm8").unwrap(), b"fo");
        assert!(codec().decode(b"Zm8").is_err());
    }

    #[test]
    fn encode_into_appends() {
        let c = codec();
        let mut buf = b"prefix:".to_vec();
        let n = c.encode_into(b"foo", &mut buf);
        assert_eq!(n, 4);
        assert_eq!(buf, b"prefix:Zm9v");
    }

    #[test]
    fn slice_api_roundtrip() {
        let c = codec();
        let mut enc = [0u8; 8];
        let n = c.encode_slice(b"foobar", &mut enc);
        assert_eq!((n, &enc[..]), (8, &b"Zm9vYmFy"[..]));
        let mut dec = [0u8; 6];
        let n = c.decode_slice(&enc, &mut dec).unwrap();
        assert_eq!((n, &dec[..]), (6, &b"foobar"[..]));
    }

    #[test]
    fn compact_ws_reference_semantics() {
        let src = b"ab\r\ncd e\tf";
        let mut dst = [0u8; 16];
        let (r, w) = compact_ws(src, &mut dst, Whitespace::CrLf);
        assert_eq!((r, w), (src.len(), 8));
        assert_eq!(&dst[..w], b"abcd e\tf");
        let (r, w) = compact_ws(src, &mut dst, Whitespace::All);
        assert_eq!((r, w), (src.len(), 6));
        assert_eq!(&dst[..w], b"abcdef");
        // Stops when dst fills, reporting exactly what was consumed.
        let mut tiny = [0u8; 3];
        let (r, w) = compact_ws(src, &mut tiny, Whitespace::All);
        assert_eq!(w, 3);
        assert_eq!(&tiny, b"abc");
        assert_eq!(&src[..r], b"ab\r\nc");
    }

    #[test]
    fn decode_into_restores_on_error() {
        let c = codec();
        let mut buf = b"keep".to_vec();
        assert!(c.decode_into(b"AAAA!AAA", &mut buf).is_err());
        assert_eq!(buf, b"keep");
    }
}
