//! Store-policy subsystem: non-temporal (streaming) stores and software
//! prefetch for payloads that overflow the cache hierarchy.
//!
//! The paper's memcpy-speed claim is stated for data that does *not* fit
//! in L1; once the working set overflows the last-level cache, ordinary
//! (temporal) stores cost twice — the output line is first read into the
//! cache (read-for-ownership) and later written back — and the freshly
//! decoded bytes evict the input stream that is still being read. The
//! AVX-512 transcoding line of work (Muła & Lemire 2019; Clausecker &
//! Lemire 2022) shows the remaining lever on the >L2 gap is streaming
//! stores plus software prefetch of the input; this module packages both
//! behind a [`StorePolicy`] that the [`Engine`](super::engine::Engine)
//! threads through every encode/decode entry point.
//!
//! ## Policy semantics
//!
//! * [`StorePolicy::Temporal`] — the pre-policy behaviour: plain stores,
//!   output travels through the cache hierarchy. Always correct, best
//!   for cache-resident payloads (the output is often read right back).
//! * [`StorePolicy::NonTemporal`] — kernels produce into an L1-resident
//!   staging block and the staged bytes move to the destination with
//!   cache-line streaming stores (`_mm512_stream_si512` on the AVX-512
//!   tier, `_mm256_stream_si256` on AVX2, plain copies on the SWAR and
//!   scalar tiers — the policy *degrades gracefully* where the ISA has
//!   no streaming store, producing byte-identical output either way).
//! * [`StorePolicy::Auto`]`(threshold)` — picks per call: non-temporal
//!   when the call's working set (input + output bytes) exceeds the
//!   threshold, temporal otherwise. The default threshold comes from the
//!   detected last-level cache size
//!   ([`perfmodel::cache::host_caches`](crate::perfmodel::cache::host_caches)):
//!   working sets beyond the LLC round-trip DRAM anyway, so bypassing
//!   the caches saves the read-for-ownership traffic without hurting any
//!   payload that could have stayed resident.
//!
//! The process-wide default is [`StorePolicy::auto`], overridable with
//! `B64SIMD_STORES=temporal|nontemporal|auto|auto:<bytes>` (parsed once,
//! like `B64SIMD_TIER`).
//!
//! ## The alignment-peel invariant
//!
//! Streaming stores are only architecturally useful — and on x86 only
//! *valid* for the 64-byte forms — when they hit **full, cache-line-
//! aligned lines**: a partial-line streaming write forces the line into
//! the write-combining buffer twice and `_mm512_stream_si512` requires a
//! 64-byte-aligned address outright. `copy_for`'s kernels therefore
//! peel the copy into three phases:
//!
//! 1. **head** — plain stores up to the first 64-byte-aligned destination
//!    address (0..63 bytes);
//! 2. **body** — whole aligned cache lines via the tier's streaming
//!    store (unaligned *loads* from the staging block are fine);
//! 3. **tail** — plain stores for the sub-line remainder.
//!
//! No byte is ever written by both a streaming and a plain store, and a
//! destination line that straddles two staged batches is written by two
//! plain stores (each batch's tail/head peel), never by a partial
//! streaming store. `align_offset` failure (permitted by its contract)
//! degrades the whole copy to plain stores.
//!
//! ## The `sfence` contract
//!
//! Non-temporal stores are weakly ordered: they become globally visible
//! only after an `sfence`. The rule in this crate is **whoever issues NT
//! stores fences once at kernel exit, on the issuing thread**:
//!
//! * the line-copy kernels behind `copy_for` never fence — they are
//!   called once per staged batch and a fence per batch would serialize
//!   the write-combining buffers;
//! * every NT-mode engine entry point (`encode_slice_nt`,
//!   `decode_span_nt`, `decode_slice_ws_policy`, the wrapped encoder)
//!   calls [`fence`] exactly once before returning — on success *and* on
//!   the error path, so a failed decode never leaves unfenced stores
//!   behind;
//! * parallel paths (`encode_par`/`decode_par`) run the NT entry points
//!   on the worker threads, so each worker fences its own stores before
//!   the scope joins.
//!
//! On non-x86 targets every helper here is a plain copy / no-op and the
//! contract holds vacuously.

use std::sync::OnceLock;

use super::engine::Tier;

/// Cache-line granule of the streaming-store kernels (and a harmless
/// copy granule on targets without them).
pub const CACHE_LINE: usize = 64;

/// How engine kernels store their output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorePolicy {
    /// Plain stores through the cache hierarchy (the pre-policy path).
    Temporal,
    /// Streaming stores via an L1 staging block (plain stores where the
    /// tier has no streaming form).
    NonTemporal,
    /// Non-temporal when a call's working set (input + output bytes)
    /// exceeds this many bytes, temporal otherwise.
    Auto(usize),
}

impl StorePolicy {
    /// [`StorePolicy::Auto`] at the detected host threshold (last-level
    /// cache size, floored at 1 MiB so a bogus topology reading cannot
    /// push small payloads off the cache path).
    pub fn auto() -> StorePolicy {
        StorePolicy::Auto(auto_threshold())
    }

    /// Parse a `B64SIMD_STORES` value.
    pub fn parse(s: &str) -> Option<StorePolicy> {
        match s {
            "temporal" => Some(StorePolicy::Temporal),
            "nontemporal" | "nt" => Some(StorePolicy::NonTemporal),
            "auto" => Some(StorePolicy::auto()),
            _ => s
                .strip_prefix("auto:")
                .and_then(|t| t.parse().ok())
                .map(StorePolicy::Auto),
        }
    }

    /// Benchmark/series label.
    pub fn name(self) -> &'static str {
        match self {
            StorePolicy::Temporal => "temporal",
            StorePolicy::NonTemporal => "nontemporal",
            StorePolicy::Auto(_) => "auto",
        }
    }

    /// Resolve the policy for one call: should a working set of
    /// `working_set` bytes (input + output) use the streaming path?
    #[inline]
    pub fn use_nontemporal(self, working_set: usize) -> bool {
        match self {
            StorePolicy::Temporal => false,
            StorePolicy::NonTemporal => true,
            StorePolicy::Auto(threshold) => working_set > threshold,
        }
    }
}

/// The `Auto` threshold: the detected last-level cache capacity (see
/// [`crate::perfmodel::cache::host_caches`]), floored at 1 MiB.
pub fn auto_threshold() -> usize {
    crate::perfmodel::cache::host_caches().llc.max(1 << 20)
}

/// Process-wide default policy: the `B64SIMD_STORES` env override if
/// set and parseable, else [`StorePolicy::auto`]. Parsed exactly once.
pub fn default_policy() -> StorePolicy {
    static POLICY: OnceLock<StorePolicy> = OnceLock::new();
    *POLICY.get_or_init(|| {
        if let Ok(v) = std::env::var("B64SIMD_STORES") {
            if let Some(p) = StorePolicy::parse(&v) {
                return p;
            }
            crate::log_warn!("stores", "ignoring unknown B64SIMD_STORES value '{v}'");
        }
        StorePolicy::auto()
    })
}

/// A staged-batch copy kernel: `copy(dst, src)` with `dst.len() ==
/// src.len()`. The tier variants stream whole aligned cache lines (see
/// the module docs); callers own the exit [`fence`].
pub(crate) type CopyFn = fn(&mut [u8], &[u8]);

/// The copy kernel matching an engine tier: streaming stores on the
/// SIMD tiers, plain stores as the SWAR/scalar fallback — so a forced
/// `B64SIMD_TIER=scalar` pipeline stays fully scalar even under
/// `B64SIMD_STORES=nontemporal`.
pub(crate) fn copy_for(tier: Tier) -> CopyFn {
    #[cfg(target_arch = "x86_64")]
    {
        match tier {
            Tier::Avx512 => return copy_nt_avx512,
            Tier::Avx2 => return copy_nt_avx2,
            Tier::Swar | Tier::Scalar => {}
        }
    }
    let _ = tier;
    copy_plain
}

/// Plain-store fallback (also the head/tail peel everywhere).
fn copy_plain(dst: &mut [u8], src: &[u8]) {
    dst.copy_from_slice(src);
}

/// Head/tail peel bookkeeping: copy the unaligned head with plain
/// stores and return `(head, lines)` — the offset of the first aligned
/// line and the count of whole lines to stream. `lines == 0` when the
/// span never reaches an aligned line (tiny copies degrade to plain).
#[cfg(target_arch = "x86_64")]
fn peel_head(dst: &mut [u8], src: &[u8]) -> (usize, usize) {
    debug_assert_eq!(dst.len(), src.len());
    let head = match dst.as_ptr().align_offset(CACHE_LINE) {
        usize::MAX => dst.len(), // align_offset may refuse; degrade to plain
        off => off.min(dst.len()),
    };
    dst[..head].copy_from_slice(&src[..head]);
    (head, (dst.len() - head) / CACHE_LINE)
}

#[cfg(target_arch = "x86_64")]
fn copy_nt_avx512(dst: &mut [u8], src: &[u8]) {
    let (head, lines) = peel_head(dst, src);
    // SAFETY: `copy_for` only hands this out for the (clamped, hence
    // available) AVX-512 tier; both slices cover `lines * 64` bytes
    // past `head`, and `dst + head` is 64-byte aligned whenever
    // `lines > 0` (a copy too short to reach an aligned line peels
    // entirely into the head and passes `lines == 0`, a no-op).
    unsafe {
        super::avx512::raw::nt_store_lines(
            dst.as_mut_ptr().add(head),
            src.as_ptr().add(head),
            lines,
        );
    }
    let tail = head + lines * CACHE_LINE;
    dst[tail..].copy_from_slice(&src[tail..]);
}

#[cfg(target_arch = "x86_64")]
fn copy_nt_avx2(dst: &mut [u8], src: &[u8]) {
    let (head, lines) = peel_head(dst, src);
    // SAFETY: as for `copy_nt_avx512`, with the AVX2 tier clamp; when
    // `lines > 0` the 64-byte-aligned destination keeps both 32-byte
    // halves aligned for `_mm256_stream_si256`.
    unsafe {
        super::avx2::nt_store_lines(dst.as_mut_ptr().add(head), src.as_ptr().add(head), lines);
    }
    let tail = head + lines * CACHE_LINE;
    dst[tail..].copy_from_slice(&src[tail..]);
}

/// Copy `src` into `dst` with the best streaming-store kernel the host
/// supports (plain copy where there is none), then [`fence`]. This is
/// the standalone "NT memcpy" used by `benches/nt_stores.rs` to measure
/// the store path in isolation; engine code uses the per-tier
/// `copy_for` kernels and fences once per call instead.
pub fn nt_memcpy(dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "nt_memcpy requires equal lengths");
    (best_copy())(dst, src);
    fence();
}

fn best_copy() -> CopyFn {
    static BEST: OnceLock<CopyFn> = OnceLock::new();
    *BEST.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx512f") {
                return copy_nt_avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return copy_nt_avx2;
            }
        }
        copy_plain
    })
}

/// Publish all pending non-temporal stores (`sfence`). See the module
/// docs for who calls this and when; a no-op on targets without
/// streaming stores.
#[inline]
pub fn fence() {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: `sfence` has no preconditions on x86_64 (SSE is baseline).
    unsafe {
        std::arch::x86_64::_mm_sfence()
    };
}

/// How far ahead of the kernel the input stream is prefetched, per
/// tier, in bytes. The SIMD tiers chew through a staged batch faster
/// than DRAM can answer a demand miss, so they look a full batch ahead;
/// the SWAR/scalar tiers are compute-bound and the hardware prefetcher
/// already keeps up — software prefetch would only add instructions.
pub fn prefetch_distance(tier: Tier) -> usize {
    match tier {
        Tier::Avx512 => 4096,
        Tier::Avx2 => 2048,
        Tier::Swar | Tier::Scalar => 0,
    }
}

/// Issue a T0 prefetch for every cache line of `src` (a hint; no-op off
/// x86_64). Callers bound `src` by [`prefetch_distance`].
#[inline]
pub fn prefetch_read(src: &[u8]) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let mut p = 0;
        while p < src.len() {
            // SAFETY: prefetch never faults; the pointer stays in-slice.
            unsafe { _mm_prefetch::<_MM_HINT_T0>(src.as_ptr().add(p) as *const i8) };
            p += CACHE_LINE;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = src;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_names() {
        assert_eq!(StorePolicy::parse("temporal"), Some(StorePolicy::Temporal));
        assert_eq!(StorePolicy::parse("nontemporal"), Some(StorePolicy::NonTemporal));
        assert_eq!(StorePolicy::parse("nt"), Some(StorePolicy::NonTemporal));
        assert_eq!(StorePolicy::parse("auto:12345"), Some(StorePolicy::Auto(12345)));
        assert!(matches!(StorePolicy::parse("auto"), Some(StorePolicy::Auto(_))));
        assert_eq!(StorePolicy::parse("mmx"), None);
        assert_eq!(StorePolicy::parse("auto:x"), None);
    }

    #[test]
    fn policy_resolution() {
        assert!(!StorePolicy::Temporal.use_nontemporal(usize::MAX));
        assert!(StorePolicy::NonTemporal.use_nontemporal(0));
        let auto = StorePolicy::Auto(100);
        assert!(!auto.use_nontemporal(99));
        assert!(!auto.use_nontemporal(100));
        assert!(auto.use_nontemporal(101));
    }

    #[test]
    fn auto_threshold_is_at_least_a_mebibyte() {
        assert!(auto_threshold() >= 1 << 20);
        if let StorePolicy::Auto(t) = StorePolicy::auto() {
            assert_eq!(t, auto_threshold());
        } else {
            panic!("StorePolicy::auto() must be Auto");
        }
    }

    /// Every copy kernel must be byte-identical to a plain copy across
    /// lengths and destination alignments (the peel edges).
    #[test]
    fn copy_kernels_match_plain_copy_at_every_alignment() {
        let kernels: Vec<(&str, CopyFn)> = vec![
            ("plain", copy_plain as CopyFn),
            ("tier", copy_for(crate::base64::engine::detected_tier())),
            ("best", best_copy()),
        ];
        for (name, copy) in kernels {
            for len in [0usize, 1, 63, 64, 65, 127, 128, 200, 4095, 4096, 4097] {
                let src: Vec<u8> = (0..len).map(|i| (i * 131 % 251) as u8).collect();
                // Slide the destination across a cache line to hit every
                // head-peel length.
                let mut backing = vec![0u8; len + 2 * CACHE_LINE];
                for off in [0usize, 1, 7, 31, 63] {
                    let dst = &mut backing[off..off + len];
                    dst.fill(0xEE);
                    copy(dst, &src);
                    assert_eq!(dst, &src[..], "{name} len={len} off={off}");
                }
            }
        }
        fence();
    }

    #[test]
    fn nt_memcpy_roundtrip() {
        let src: Vec<u8> = (0..10_000).map(|i| (i % 256) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        nt_memcpy(&mut dst, &src);
        assert_eq!(dst, src);
    }

    #[test]
    fn prefetch_is_safe_on_any_slice() {
        prefetch_read(&[]);
        prefetch_read(&[1, 2, 3]);
        prefetch_read(&vec![7u8; 5000]);
        assert_eq!(prefetch_distance(Tier::Scalar), 0);
        assert_eq!(prefetch_distance(Tier::Swar), 0);
        assert!(prefetch_distance(Tier::Avx512) >= prefetch_distance(Tier::Avx2));
    }
}
