//! Decoding error model and RFC 4648 padding/strictness semantics.

/// Where and why a decode failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// A byte outside the variant's alphabet at `offset` in the input.
    InvalidByte { offset: usize, byte: u8 },
    /// Input length is not a multiple of 4 (strict mode, padded input).
    InvalidLength { len: usize },
    /// Padding appears before the final quantum or is malformed.
    InvalidPadding { offset: usize },
    /// The final quantum encodes trailing bits that are not zero
    /// (non-canonical encoding, e.g. "aGk=" vs "aGl=").
    TrailingBits { offset: usize },
    /// A deferred (batched) validation failed; the per-row flags narrowed
    /// it to `block_row`, but the exact byte was not recomputed.
    InvalidBlock { block_row: usize },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidByte { offset, byte } => {
                write!(f, "invalid base64 byte 0x{byte:02x} at offset {offset}")
            }
            Self::InvalidLength { len } => {
                write!(f, "invalid base64 length {len} (not a multiple of 4)")
            }
            Self::InvalidPadding { offset } => write!(f, "invalid padding at offset {offset}"),
            Self::TrailingBits { offset } => {
                write!(f, "non-zero trailing bits in final quantum at offset {offset}")
            }
            Self::InvalidBlock { block_row } => {
                write!(f, "invalid base64 character in block row {block_row}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

impl DecodeError {
    /// Apply `f` to the error's input offset, if it carries one
    /// (`InvalidLength`/`InvalidBlock` carry a length/row, which is left
    /// untouched). This is the single place offset rebasing is defined —
    /// span-relative → absolute, stripped → original, carry-index → raw
    /// stream — so every variant is covered once.
    pub fn map_offset(self, f: impl FnOnce(usize) -> usize) -> DecodeError {
        match self {
            DecodeError::InvalidByte { offset, byte } => {
                DecodeError::InvalidByte { offset: f(offset), byte }
            }
            DecodeError::InvalidPadding { offset } => {
                DecodeError::InvalidPadding { offset: f(offset) }
            }
            DecodeError::TrailingBits { offset } => {
                DecodeError::TrailingBits { offset: f(offset) }
            }
            other => other,
        }
    }
}

/// Whitespace tolerance of the decode path (the MIME workload's knob).
///
/// RFC 2045 wraps encoded lines at 76 characters with CRLF and requires
/// decoders to ignore the line structure; lenient MIME bodies also carry
/// space/tab. The engine's fused decode ([`crate::base64::Engine::decode_slice_ws`])
/// compacts skipped bytes in-register/in-word *inside* the SIMD loop
/// instead of running a separate strip pass, so the policy costs roughly
/// one masked compaction per 64 input bytes rather than an extra pass
/// over memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Whitespace {
    /// No bytes are skipped (strict RFC 4648; the paper's codecs).
    #[default]
    None,
    /// Skip CR and LF (RFC 2045 line wrapping).
    CrLf,
    /// Skip CR, LF, space and horizontal tab (lenient MIME bodies).
    All,
}

impl Whitespace {
    /// True iff the policy skips byte `c`.
    #[inline(always)]
    pub fn skips(self, c: u8) -> bool {
        match self {
            Whitespace::None => false,
            Whitespace::CrLf => c == b'\r' || c == b'\n',
            Whitespace::All => matches!(c, b'\r' | b'\n' | b' ' | b'\t'),
        }
    }
}

/// Offset in `input` of its `n`-th (0-based) non-skipped byte.
///
/// Cold-path helper used to translate error offsets from the *stripped*
/// coordinate space (what the fused whitespace decode works in) back to
/// the original input. Returns `input.len()` if there are fewer than
/// `n + 1` significant bytes.
pub fn nth_significant_offset(input: &[u8], n: usize, ws: Whitespace) -> usize {
    let mut seen = 0usize;
    for (i, &c) in input.iter().enumerate() {
        if !ws.skips(c) {
            if seen == n {
                return i;
            }
            seen += 1;
        }
    }
    input.len()
}

/// Translate a [`DecodeError`] whose offsets refer to the stripped stream
/// into one whose offsets refer to the original (whitespace-bearing)
/// input. `InvalidLength` carries a *length*, not an offset, and keeps
/// counting significant characters.
pub fn rebase_ws_error(e: DecodeError, input: &[u8], ws: Whitespace) -> DecodeError {
    e.map_offset(|offset| nth_significant_offset(input, offset, ws))
}

/// Decoding strictness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mode {
    /// RFC 4648 §3.5 strict: canonical padding required, canonical zero
    /// trailing bits enforced, no whitespace. This is what the paper's
    /// codecs implement (they reject any byte outside the table).
    #[default]
    Strict,
    /// Padding optional (accept unpadded input); trailing bits ignored.
    /// Still rejects alphabet-foreign bytes.
    Forgiving,
}

/// Split a padded base64 input into (full-quantum body, final quantum).
///
/// Returns `(body, tail)` where `body.len() % 4 == 0` and `tail` is the
/// final ≤4-char quantum *if* it contains padding or is partial; `tail`
/// is empty when the input is a clean multiple of 4 with no padding.
pub fn split_tail<'a>(input: &'a [u8], pad: u8, mode: Mode) -> Result<(&'a [u8], &'a [u8]), DecodeError> {
    if input.is_empty() {
        return Ok((input, &[]));
    }
    match mode {
        Mode::Strict => {
            if input.len() % 4 != 0 {
                return Err(DecodeError::InvalidLength { len: input.len() });
            }
            let last4 = &input[input.len() - 4..];
            if last4.contains(&pad) {
                Ok((&input[..input.len() - 4], last4))
            } else {
                Ok((input, &[]))
            }
        }
        Mode::Forgiving => {
            // Trim at the first pad or take len rounded down to 4.
            let body_len = input.len() & !3;
            let first_pad = input.iter().position(|&c| c == pad);
            match first_pad {
                Some(p) => {
                    let q_start = p & !3;
                    Ok((&input[..q_start], &input[q_start..]))
                }
                None if body_len == input.len() => Ok((input, &[])),
                None => Ok((&input[..body_len], &input[body_len..])),
            }
        }
    }
}

/// The paper's §3.2 validation identity over a 128-entry decode table:
/// `(c | dtable[c & 0x7F]) & 0x80 != 0` iff `c` is outside the alphabet
/// (the OR folds non-ASCII bytes, whose MSB the 7-bit lookup would alias,
/// into the same test). Every deferred-error re-scan routes through here.
#[inline(always)]
pub fn byte_is_invalid(c: u8, dtable: &[u8; 128]) -> bool {
    (c | dtable[(c & 0x7F) as usize]) & 0x80 != 0
}

/// Offset of the first alphabet-foreign byte in `input`, if any.
/// This is the cold-path re-scan after a deferred error accumulator fires.
pub fn first_invalid(input: &[u8], dtable: &[u8; 128]) -> Option<usize> {
    input.iter().position(|&c| byte_is_invalid(c, dtable))
}

/// True iff `row` contains at least one alphabet-foreign byte — the
/// per-row flag contract of the coordinator's batched decode path.
pub fn row_has_invalid(row: &[u8], dtable: &[u8; 128]) -> bool {
    row.iter().any(|&c| byte_is_invalid(c, dtable))
}

/// Decode whole 4-char quanta (no padding allowed) into a caller-provided
/// slice, writing exactly `body.len() / 4 * 3` bytes at `out[0..]`.
/// `base_offset` positions error reports in the original input.
pub fn decode_quads_into(
    body: &[u8],
    dtable: &[u8; 128],
    base_offset: usize,
    out: &mut [u8],
) -> Result<usize, DecodeError> {
    debug_assert_eq!(body.len() % 4, 0);
    let mut w = 0;
    for (q, quad) in body.chunks_exact(4).enumerate() {
        let mut vals = [0u8; 4];
        for (i, &c) in quad.iter().enumerate() {
            let v = dtable[(c & 0x7F) as usize];
            if (c | v) & 0x80 != 0 {
                return Err(DecodeError::InvalidByte { offset: base_offset + q * 4 + i, byte: c });
            }
            vals[i] = v;
        }
        out[w] = (vals[0] << 2) | (vals[1] >> 4);
        out[w + 1] = (vals[1] << 4) | (vals[2] >> 2);
        out[w + 2] = (vals[2] << 6) | vals[3];
        w += 3;
    }
    Ok(w)
}

/// Core of the tail decode: resolve the final quantum into up to 3 raw
/// bytes without touching any output buffer.
fn decode_tail_parts(
    tail: &[u8],
    pad: u8,
    mode: Mode,
    base_offset: usize,
    value_of: impl Fn(u8) -> Option<u8>,
) -> Result<([u8; 3], usize), DecodeError> {
    if tail.is_empty() {
        return Ok(([0; 3], 0));
    }
    // Split data chars from padding.
    let data_len = tail.iter().position(|&c| c == pad).unwrap_or(tail.len());
    let data = &tail[..data_len];
    let padding = &tail[data_len..];
    // Everything after the first pad must be pad (strict), and the padded
    // quantum must be exactly 4 long.
    if !padding.iter().all(|&c| c == pad) {
        return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
    }
    if mode == Mode::Strict {
        if !padding.is_empty() && tail.len() != 4 {
            return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
        }
        if padding.len() > 2 {
            return Err(DecodeError::InvalidPadding { offset: base_offset + data_len });
        }
    }
    let mut vals = [0u8; 4];
    for (i, &c) in data.iter().enumerate() {
        vals[i] = value_of(c).ok_or(DecodeError::InvalidByte {
            offset: base_offset + i,
            byte: c,
        })?;
    }
    let mut bytes = [0u8; 3];
    let written = match data.len() {
        0 => 0,
        1 => return Err(DecodeError::InvalidLength { len: base_offset + 1 }),
        2 => {
            if mode == Mode::Strict && vals[1] & 0x0F != 0 {
                return Err(DecodeError::TrailingBits { offset: base_offset + 1 });
            }
            bytes[0] = (vals[0] << 2) | (vals[1] >> 4);
            1
        }
        3 => {
            if mode == Mode::Strict && vals[2] & 0x03 != 0 {
                return Err(DecodeError::TrailingBits { offset: base_offset + 2 });
            }
            bytes[0] = (vals[0] << 2) | (vals[1] >> 4);
            bytes[1] = (vals[1] << 4) | (vals[2] >> 2);
            2
        }
        4 => {
            bytes[0] = (vals[0] << 2) | (vals[1] >> 4);
            bytes[1] = (vals[1] << 4) | (vals[2] >> 2);
            bytes[2] = (vals[2] << 6) | vals[3];
            3
        }
        _ => unreachable!("tail is at most 4 chars"),
    };
    Ok((bytes, written))
}

/// Decode the final quantum (0–4 chars, possibly padded) using `value_of`.
///
/// `base_offset` is the quantum's offset in the original input, used for
/// error reporting. Appends 0–3 bytes to `out`.
pub fn decode_tail(
    tail: &[u8],
    pad: u8,
    mode: Mode,
    base_offset: usize,
    value_of: impl Fn(u8) -> Option<u8>,
    out: &mut Vec<u8>,
) -> Result<usize, DecodeError> {
    let (bytes, n) = decode_tail_parts(tail, pad, mode, base_offset, value_of)?;
    out.extend_from_slice(&bytes[..n]);
    Ok(n)
}

/// Allocation-free variant of [`decode_tail`]: writes the 0–3 tail bytes
/// at `out[0..]` and returns the count. Panics if `out` is too small for
/// the bytes actually produced.
pub fn decode_tail_into(
    tail: &[u8],
    pad: u8,
    mode: Mode,
    base_offset: usize,
    value_of: impl Fn(u8) -> Option<u8>,
    out: &mut [u8],
) -> Result<usize, DecodeError> {
    let (bytes, n) = decode_tail_parts(tail, pad, mode, base_offset, value_of)?;
    assert!(out.len() >= n, "output buffer too small for the decoded tail");
    out[..n].copy_from_slice(&bytes[..n]);
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::Alphabet;

    fn vo(a: &Alphabet) -> impl Fn(u8) -> Option<u8> + '_ {
        move |c| a.value_of(c)
    }

    #[test]
    fn split_strict_no_pad() {
        let (body, tail) = split_tail(b"AAAABBBB", b'=', Mode::Strict).unwrap();
        assert_eq!(body, b"AAAABBBB");
        assert!(tail.is_empty());
    }

    #[test]
    fn split_strict_with_pad() {
        let (body, tail) = split_tail(b"AAAABB==", b'=', Mode::Strict).unwrap();
        assert_eq!(body, b"AAAA");
        assert_eq!(tail, b"BB==");
    }

    #[test]
    fn split_strict_bad_length() {
        assert!(matches!(
            split_tail(b"AAAAB", b'=', Mode::Strict),
            Err(DecodeError::InvalidLength { len: 5 })
        ));
    }

    #[test]
    fn split_forgiving_unpadded() {
        let (body, tail) = split_tail(b"AAAABB", b'=', Mode::Forgiving).unwrap();
        assert_eq!(body, b"AAAA");
        assert_eq!(tail, b"BB");
    }

    #[test]
    fn tail_decodes_two_chars() {
        let a = Alphabet::standard();
        let mut out = vec![];
        // "aA==" is the canonical encoding of the single byte 'h'.
        let n = decode_tail(b"aA==", b'=', Mode::Strict, 0, vo(&a), &mut out).unwrap();
        assert_eq!((n, out.as_slice()), (1, &b"h"[..]));
    }

    #[test]
    fn tail_rejects_noncanonical_trailing_bits() {
        let a = Alphabet::standard();
        let mut out = vec![];
        // 'l' = 37 = 0b100101 has low bits set -> non-canonical for 2-char tail.
        assert!(matches!(
            decode_tail(b"al==", b'=', Mode::Strict, 0, vo(&a), &mut out),
            Err(DecodeError::TrailingBits { .. })
        ));
        // Forgiving mode accepts it.
        let mut out = vec![];
        decode_tail(b"al==", b'=', Mode::Forgiving, 0, vo(&a), &mut out).unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn tail_rejects_pad_then_data() {
        let a = Alphabet::standard();
        let mut out = vec![];
        assert!(matches!(
            decode_tail(b"a=b=", b'=', Mode::Strict, 8, vo(&a), &mut out),
            Err(DecodeError::InvalidPadding { offset: 9 })
        ));
    }

    #[test]
    fn tail_rejects_single_char() {
        let a = Alphabet::standard();
        let mut out = vec![];
        assert!(matches!(
            decode_tail(b"a", b'=', Mode::Forgiving, 0, vo(&a), &mut out),
            Err(DecodeError::InvalidLength { .. })
        ));
    }

    #[test]
    fn tail_rejects_invalid_byte_with_offset() {
        let a = Alphabet::standard();
        let mut out = vec![];
        assert!(matches!(
            decode_tail(b"a!==", b'=', Mode::Strict, 100, vo(&a), &mut out),
            Err(DecodeError::InvalidByte { offset: 101, byte: b'!' })
        ));
    }

    #[test]
    fn invalid_byte_identity_matches_value_of() {
        let a = Alphabet::standard();
        let dtable = a.decode_table().as_bytes();
        for c in 0..=255u8 {
            assert_eq!(byte_is_invalid(c, dtable), a.value_of(c).is_none(), "c={c:#x}");
        }
    }

    #[test]
    fn first_invalid_and_row_flags() {
        let a = Alphabet::standard();
        let dtable = a.decode_table().as_bytes();
        assert_eq!(first_invalid(b"AAAA", dtable), None);
        assert_eq!(first_invalid(b"AA!A", dtable), Some(2));
        assert!(!row_has_invalid(b"Zm9v", dtable));
        assert!(row_has_invalid(&[b'Z', 0xC3, b'9', b'v'], dtable));
    }

    #[test]
    fn decode_quads_into_slice() {
        let a = Alphabet::standard();
        let mut out = [0u8; 6];
        let n = decode_quads_into(b"Zm9vYmFy", a.decode_table().as_bytes(), 0, &mut out).unwrap();
        assert_eq!((n, &out[..]), (6, &b"foobar"[..]));
        let err = decode_quads_into(b"Zm9vY!Fy", a.decode_table().as_bytes(), 100, &mut out);
        assert_eq!(err, Err(DecodeError::InvalidByte { offset: 105, byte: b'!' }));
    }

    #[test]
    fn tail_into_slice_matches_vec_path() {
        let a = Alphabet::standard();
        let mut buf = [0u8; 3];
        let n = decode_tail_into(b"aA==", b'=', Mode::Strict, 0, vo(&a), &mut buf).unwrap();
        assert_eq!((n, buf[0]), (1, b'h'));
    }

    #[test]
    fn whitespace_policy_membership() {
        assert!(!Whitespace::None.skips(b'\r'));
        assert!(Whitespace::CrLf.skips(b'\r'));
        assert!(Whitespace::CrLf.skips(b'\n'));
        assert!(!Whitespace::CrLf.skips(b' '));
        assert!(Whitespace::All.skips(b' '));
        assert!(Whitespace::All.skips(b'\t'));
        assert!(!Whitespace::All.skips(b'A'));
    }

    #[test]
    fn nth_significant_maps_past_skipped_bytes() {
        let input = b"ab\r\ncd \te";
        assert_eq!(nth_significant_offset(input, 0, Whitespace::CrLf), 0);
        assert_eq!(nth_significant_offset(input, 2, Whitespace::CrLf), 4);
        assert_eq!(nth_significant_offset(input, 4, Whitespace::All), 8);
        // ' ' is significant under CrLf but not under All.
        assert_eq!(nth_significant_offset(input, 4, Whitespace::CrLf), 6);
        // Out of range clamps to len.
        assert_eq!(nth_significant_offset(input, 99, Whitespace::All), input.len());
    }

    #[test]
    fn rebase_ws_error_translates_offsets_only() {
        let input = b"Zm9v\r\n!mFy";
        let e = rebase_ws_error(
            DecodeError::InvalidByte { offset: 4, byte: b'!' },
            input,
            Whitespace::CrLf,
        );
        assert_eq!(e, DecodeError::InvalidByte { offset: 6, byte: b'!' });
        let e = rebase_ws_error(DecodeError::InvalidLength { len: 9 }, input, Whitespace::CrLf);
        assert_eq!(e, DecodeError::InvalidLength { len: 9 });
    }

    #[test]
    fn tail_full_quantum() {
        let a = Alphabet::standard();
        let mut out = vec![];
        let n = decode_tail(b"aGVs", b'=', Mode::Strict, 0, vo(&a), &mut out).unwrap();
        assert_eq!((n, out.as_slice()), (3, &b"hel"[..]));
    }
}
