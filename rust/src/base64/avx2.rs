//! The 2018 AVX2 codec (Muła & Lemire, ACM TWEB 12(3)) with real
//! intrinsics — the baseline the paper measures its 7×/5.6×
//! instruction-count reduction against.
//!
//! Encode, 11 instructions per 24 input bytes (§3.1 of the 2019 paper):
//! `vpshufb` reshuffle, then the 5-op field step (`vpand`, `vpmulhuw`,
//! `vpand`, `vpmullw`, `vpor`), then the 5-op range-arithmetic alphabet
//! mapping (`vpsubusb`, `vpcmpgtb`, `vpsubb`, `vpshufb`, `vpaddb`).
//!
//! Decode, 14 instructions per 32 input chars (§3.2): hi/lo-nibble
//! classification (2× `vpshufb` + `vpand`/`vpsrld`/`vptest`-class ops),
//! the roll addition, `vpmaddubsw` + `vpmaddwd` packing, and the in-lane
//! + cross-lane compaction (`vpshufb` + `vpermd`).
//!
//! Faithful to the original in its *limitation* too: the range arithmetic
//! bakes the alphabet's byte ranges into constants, so this codec only
//! supports range-structured alphabets (standard-layout; base64url's '_'
//! collides with 'P'..'Z' in the hi-nibble classifier) — exactly the
//! versatility gap the 2019 paper's table-driven AVX-512 design removes.

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::block::BlockCodec;
use super::validate::{decode_quads_into, decode_tail_into, split_tail, DecodeError, Mode};
#[cfg(target_arch = "x86_64")]
use super::validate::Whitespace;
use super::{encoded_len, Alphabet, Codec};

/// Bytes consumed per encode iteration (two 12-byte lane loads).
const ENC_IN: usize = 24;
/// Chars produced per encode iteration.
const ENC_OUT: usize = 32;
/// Chars consumed per decode iteration.
const DEC_IN: usize = 32;
/// Bytes produced per decode iteration.
const DEC_OUT: usize = 24;

/// The 2018 AVX2 codec (standard-alphabet family only).
pub struct Avx2Codec {
    alphabet: Alphabet,
    mode: Mode,
    scalar_twin: BlockCodec,
    /// pshufb offset table for the encoder's range arithmetic.
    enc_offsets: [i8; 16],
    /// lo-nibble classification row, derived from the alphabet's 62/63
    /// characters (both must live in the 0x2X column).
    dec_lut_lo: [i8; 16],
    /// hi-nibble roll offsets; slot 1 is reached via the `eq(c63)` fixup.
    dec_roll: [i8; 16],
    /// The alphabet's value-63 character (the `vpcmpeqb` constant).
    c63: u8,
}

impl Avx2Codec {
    /// True iff the host can run this codec.
    pub fn available() -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    /// The alphabet must have the 2018 codec's range structure:
    /// contiguous A–Z-like, a–z-like and 0–9-like runs (standard/imap
    /// qualify; arbitrary tables do not — use the AVX-512 or block codec).
    pub fn supports(alphabet: &Alphabet) -> bool {
        Self::supports_chars(alphabet.chars())
    }

    /// [`Self::supports`] on a raw 64-byte alphabet table (the form the
    /// coordinator backends receive over the wire).
    pub fn supports_chars(c: &[u8; 64]) -> bool {
        let contiguous = |range: std::ops::Range<usize>| {
            range.clone().skip(1).all(|i| c[i] == c[i - 1] + 1)
        };
        // The decoder's nibble classifier needs the standard letter/digit
        // ranges, and both extra characters in the 0x21..=0x2F column
        // with distinct low nibbles.
        c[..26] == *b"ABCDEFGHIJKLMNOPQRSTUVWXYZ"
            && c[26..52] == *b"abcdefghijklmnopqrstuvwxyz"
            && c[52..62] == *b"0123456789"
            && contiguous(0..26)
            && (0x21..=0x2F).contains(&c[62])
            && (0x21..=0x2F).contains(&c[63])
            && c[62] & 0x0F != c[63] & 0x0F
    }

    /// Panics unless [`Self::available`] and [`Self::supports`] hold.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_mode(alphabet, Mode::Strict)
    }

    /// [`Self::new`] with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        assert!(Self::available(), "AVX2 not available on this CPU");
        assert!(Self::supports(&alphabet), "alphabet lacks the 2018 range structure");
        let c = alphabet.chars();
        let mut enc_offsets = [0i8; 16];
        enc_offsets[0] = c[0] as i8; // v in 0..26
        enc_offsets[1] = (c[26] as i16 - 26) as i8; // 26..52
        for (slot, off) in enc_offsets[2..12].iter_mut().enumerate() {
            let _ = slot;
            *off = (c[52] as i16 - 52) as i8; // 52..62
        }
        enc_offsets[12] = (c[62] as i16 - 62) as i8;
        enc_offsets[13] = (c[63] as i16 - 63) as i8;
        // lo-nibble classification row (see the bit assignments in the
        // 2018 paper): 0x10 everywhere, 0x01 for the 0x2X column except
        // the two extra chars, 0x02 for 0x3A..0x3F, 0x04 for '@'/'`',
        // 0x08 for 0x5B../0x7B...
        let mut dec_lut_lo = [0i8; 16];
        for (lo, e) in dec_lut_lo.iter_mut().enumerate() {
            let mut bits = 0x10u8;
            if lo != (c[62] & 0x0F) as usize && lo != (c[63] & 0x0F) as usize {
                bits |= 0x01;
            }
            if lo >= 0xA {
                bits |= 0x02;
            }
            if lo == 0 {
                bits |= 0x04;
            }
            if lo >= 0xB {
                bits |= 0x08;
            }
            *e = bits as i8;
        }
        let mut dec_roll = [0i8; 16];
        dec_roll[1] = (63i16 - c[63] as i16) as i8; // via the eq(c63) fixup
        dec_roll[2] = (62i16 - c[62] as i16) as i8;
        dec_roll[3] = 4; // '0'..'9' -> 52..61
        dec_roll[4] = -65; // 'A'..'O'
        dec_roll[5] = -65; // 'P'..'Z'
        dec_roll[6] = -71; // 'a'..'o'
        dec_roll[7] = -71; // 'p'..'z'
        let c63 = c[63];
        Self {
            scalar_twin: BlockCodec::with_mode(alphabet.clone(), mode),
            alphabet,
            mode,
            enc_offsets,
            dec_lut_lo,
            dec_roll,
            c63,
        }
    }

    /// The alphabet this codec was built for.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }
}

#[cfg(target_arch = "x86_64")]
mod kernels {
    use super::*;

    /// Encode whole 24-byte groups into `out[0..]`; returns bytes
    /// consumed. `out.len()` must be at least `input.len() / 24 * 32`;
    /// the caller must guarantee 4 spare *readable* bytes past the last
    /// consumed group (the 12-offset lane load reads `src+12..src+28`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn encode(input: &[u8], out: &mut [u8], offsets: &[i8; 16]) -> usize {
        let iters = input.len() / ENC_IN;
        if iters == 0 {
            return 0;
        }
        debug_assert!(out.len() >= iters * ENC_OUT);
        let dst_base = out.as_mut_ptr();
        // In-lane shuffle producing (s2,s1,s3,s2) per 32-bit group from
        // 12 source bytes per 128-bit lane.
        let reshuf = _mm_setr_epi8(1, 0, 2, 1, 4, 3, 5, 4, 7, 6, 8, 7, 10, 9, 11, 10);
        let reshuf256 = _mm256_broadcastsi128_si256(reshuf);
        let mask_ac = _mm256_set1_epi32(0x0FC0_FC00u32 as i32);
        let mul_ac = _mm256_set1_epi32(0x0400_0040);
        let mask_bd = _mm256_set1_epi32(0x003F_03F0);
        let mul_bd = _mm256_set1_epi32(0x0100_0010);
        let c51 = _mm256_set1_epi8(51);
        let c25 = _mm256_set1_epi8(25);
        let offs = _mm256_broadcastsi128_si256(_mm_loadu_si128(offsets.as_ptr() as *const _));
        for i in 0..iters {
            let src = input.as_ptr().add(i * ENC_IN);
            // Two 12-byte lane loads (16-byte reads stay in bounds: the
            // caller guarantees >= 4 spare bytes or uses the last-iter copy).
            let lo = _mm_loadu_si128(src as *const _);
            let hi = _mm_loadu_si128(src.add(12) as *const _);
            let in256 = _mm256_set_m128i(hi, lo);
            // -- vpshufb: reshuffle to (s2,s1,s3,s2) per lane.
            let t = _mm256_shuffle_epi8(in256, reshuf256);
            // -- and/mulhi/and/mullo/or: extract the four 6-bit fields.
            let t0 = _mm256_and_si256(t, mask_ac);
            let t1 = _mm256_mulhi_epu16(t0, mul_ac);
            let t2 = _mm256_and_si256(t, mask_bd);
            let t3 = _mm256_mullo_epi16(t2, mul_bd);
            let idx = _mm256_or_si256(t1, t3);
            // -- range arithmetic: value -> ASCII.
            let sub = _mm256_subs_epu8(idx, c51);
            let gt = _mm256_cmpgt_epi8(idx, c25);
            let slot = _mm256_sub_epi8(sub, gt); // +1 where idx > 25
            let off = _mm256_shuffle_epi8(offs, slot);
            let chars = _mm256_add_epi8(idx, off);
            _mm256_storeu_si256(dst_base.add(i * ENC_OUT) as *mut _, chars);
        }
        iters * ENC_IN
    }

    /// Decode whole 32-char groups into `out[0..]`. Each iteration stores
    /// 32 bytes (8 of slack past its 24 real bytes), so only as many
    /// groups are vectorized as fit `out` with that slack — the caller
    /// decodes the remainder through the scalar quad path. Returns
    /// (consumed, first_error_offset).
    #[target_feature(enable = "avx2")]
    pub unsafe fn decode(
        input: &[u8],
        out: &mut [u8],
        lut_lo_row: &[i8; 16],
        roll_row: &[i8; 16],
        c63: u8,
    ) -> (usize, Option<usize>) {
        let iters = (input.len() / DEC_IN).min(out.len().saturating_sub(8) / DEC_OUT);
        if iters == 0 {
            return (0, None);
        }
        let dst_base = out.as_mut_ptr();
        // Nibble classification tables (standard ranges; 2018 paper).
        let lut_hi = _mm256_broadcastsi128_si256(_mm_setr_epi8(
            0x10, 0x10, 0x01, 0x02, 0x04, 0x08, 0x04, 0x08,
            0x10, 0x10, 0x10, 0x10, 0x10, 0x10, 0x10, 0x10,
        ));
        let lut_lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(
            lut_lo_row.as_ptr() as *const _,
        ));
        let lut_roll = _mm256_broadcastsi128_si256(_mm_loadu_si128(
            roll_row.as_ptr() as *const _,
        ));
        let mask_0f = _mm256_set1_epi8(0x0F);
        let c2f = _mm256_set1_epi8(c63 as i8);
        let madd1 = _mm256_set1_epi32(0x0140_0140);
        let madd2 = _mm256_set1_epi32(0x0001_1000);
        let pack = _mm256_broadcastsi128_si256(_mm_setr_epi8(
            2, 1, 0, 6, 5, 4, 10, 9, 8, 14, 13, 12, -1, -1, -1, -1,
        ));
        let perm = _mm256_setr_epi32(0, 1, 2, 4, 5, 6, 7, 7);
        for i in 0..iters {
            let src = input.as_ptr().add(i * DEC_IN);
            let chars = _mm256_loadu_si256(src as *const _);
            // -- classification: hi/lo nibble bitmask test.
            let hi_n = _mm256_and_si256(_mm256_srli_epi32::<4>(chars), mask_0f);
            let lo_n = _mm256_and_si256(chars, mask_0f);
            let hi_class = _mm256_shuffle_epi8(lut_hi, hi_n);
            let lo_class = _mm256_shuffle_epi8(lut_lo, lo_n);
            let bad = _mm256_and_si256(hi_class, lo_class);
            // The classification bits live in the low nibble: materialize
            // a per-byte mask by comparing against zero (the 2018 code
            // uses vptest for the all-clean fast path; we need per-byte
            // positions for exact error offsets).
            let good = _mm256_cmpeq_epi8(bad, _mm256_setzero_si256());
            // Non-ASCII bytes have their MSB set; movemask captures them
            // directly from `chars`.
            let bad_mask = !(_mm256_movemask_epi8(good) as u32)
                | _mm256_movemask_epi8(chars) as u32;
            if bad_mask != 0 {
                // Report the exact byte (cold path; matches scalar order).
                let lane = bad_mask.trailing_zeros() as usize;
                return (i * DEC_IN, Some(i * DEC_IN + lane));
            }
            // -- roll addition: ASCII -> 6-bit value.
            let eq_2f = _mm256_cmpeq_epi8(chars, c2f);
            let roll_idx = _mm256_add_epi8(eq_2f, hi_n); // hi_n - 1 where '/': index 1? no:
            // eq_2f is 0xFF (=-1) at '/', so hi_n + (-1) = 2-1 = 1 -> roll[1]=16. Elsewhere roll[hi].
            let roll = _mm256_shuffle_epi8(lut_roll, roll_idx);
            let vals = _mm256_add_epi8(chars, roll);
            // -- vpmaddubsw + vpmaddwd packing.
            let merged = _mm256_maddubs_epi16(vals, madd1);
            let packed = _mm256_madd_epi16(merged, madd2);
            // -- in-lane compaction + cross-lane fixup.
            let shuf = _mm256_shuffle_epi8(packed, pack);
            let compact = _mm256_permutevar8x32_epi32(shuf, perm);
            _mm256_storeu_si256(dst_base.add(i * DEC_OUT) as *mut _, compact);
        }
        (iters * DEC_IN, None)
    }

    /// Stream `lines` whole cache lines from `src` to the 64-byte-aligned
    /// `dst` as two `_mm256_stream_si256` stores per line. No fence —
    /// see the `sfence` contract in [`crate::base64::stores`].
    ///
    /// # Safety
    /// `dst` must be 64-byte aligned when `lines > 0` (keeping both
    /// 32-byte halves aligned), both pointers must cover `lines * 64`
    /// bytes, and the host must support AVX2. A `lines == 0` call is a
    /// no-op and carries no alignment requirement.
    #[target_feature(enable = "avx2")]
    pub unsafe fn nt_store_lines(dst: *mut u8, src: *const u8, lines: usize) {
        debug_assert!(lines == 0 || dst as usize % 64 == 0, "NT stores require aligned lines");
        for i in 0..lines {
            let lo = _mm256_loadu_si256(src.add(i * 64) as *const _);
            let hi = _mm256_loadu_si256(src.add(i * 64 + 32) as *const _);
            _mm256_stream_si256(dst.add(i * 64) as *mut _, lo);
            _mm256_stream_si256(dst.add(i * 64 + 32) as *mut _, hi);
        }
    }

    /// Movemask-driven whitespace compaction (the engine's fused-decode
    /// staging step on AVX2-class hosts): 32-byte loads, `vpcmpeqb` per
    /// whitespace character OR-ed into one register, `vpmovmskb` to a
    /// 32-bit mask. Clean vectors are copied with a single store; dirty
    /// ones copy the significant run up to the first skipped byte.
    /// Returns `(src_consumed, dst_written)`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
        let cr = _mm256_set1_epi8(b'\r' as i8);
        let lf = _mm256_set1_epi8(b'\n' as i8);
        let sp = _mm256_set1_epi8(b' ' as i8);
        let ht = _mm256_set1_epi8(b'\t' as i8);
        let all = ws == Whitespace::All;
        let (mut r, mut w) = (0usize, 0usize);
        while r + 32 <= src.len() && w + 32 <= dst.len() {
            let v = _mm256_loadu_si256(src.as_ptr().add(r) as *const _);
            let mut m = _mm256_or_si256(_mm256_cmpeq_epi8(v, cr), _mm256_cmpeq_epi8(v, lf));
            if all {
                let m2 = _mm256_or_si256(_mm256_cmpeq_epi8(v, sp), _mm256_cmpeq_epi8(v, ht));
                m = _mm256_or_si256(m, m2);
            }
            let mask = _mm256_movemask_epi8(m) as u32;
            if mask == 0 {
                _mm256_storeu_si256(dst.as_mut_ptr().add(w) as *mut _, v);
                r += 32;
                w += 32;
            } else {
                // Copy the run below the first whitespace byte, skip it.
                let k = mask.trailing_zeros() as usize;
                std::ptr::copy_nonoverlapping(src.as_ptr().add(r), dst.as_mut_ptr().add(w), k);
                w += k;
                r += k + 1;
            }
        }
        let (rt, wt) = crate::base64::swar::compact_ws(&src[r..], &mut dst[w..], ws);
        (r + rt, w + wt)
    }
}

/// Crate-visible handle to [`kernels::nt_store_lines`] for the store
/// subsystem's per-tier copy kernels (see `base64::stores`).
#[cfg(target_arch = "x86_64")]
pub(crate) use kernels::nt_store_lines;

/// Safe wrapper over [`kernels::compact_ws`]; the engine stores this as
/// its compaction function on AVX2-class tiers.
#[cfg(target_arch = "x86_64")]
pub(crate) fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
    debug_assert!(Avx2Codec::available());
    // SAFETY: the engine only selects this function after
    // `Avx2Codec::available()` returned true.
    unsafe { kernels::compact_ws(src, dst, ws) }
}

impl Avx2Codec {
    /// Bulk slice core: encode whole 24-byte groups into `out[0..]` with
    /// the SIMD path, returning the bytes consumed (a multiple of 24).
    /// Stops 4 bytes short of the input end to keep the 16-byte lane
    /// loads in bounds; the caller's scalar epilogue covers the rest.
    pub(crate) fn encode_bulk(&self, input: &[u8], out: &mut [u8]) -> usize {
        #[cfg(target_arch = "x86_64")]
        {
            // Keep 16-byte loads in bounds: only iterate while 28 bytes
            // remain readable (12-offset lane load reads src+12..src+28).
            let safe_len = input.len().saturating_sub(4) / ENC_IN * ENC_IN;
            // SAFETY: availability asserted at construction.
            unsafe { kernels::encode(&input[..safe_len], out, &self.enc_offsets) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (input, out);
            0
        }
    }

    /// Bulk slice core: decode whole 32-char groups (no padding) into
    /// `out[0..]`, returning the chars consumed. Errors report offsets
    /// relative to `input`, normalized to scalar (first-byte) order.
    pub(crate) fn decode_bulk(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: availability asserted at construction.
            let (consumed, bad) =
                unsafe { kernels::decode(input, out, &self.dec_lut_lo, &self.dec_roll, self.c63) };
            if let Some(pos) = bad {
                // The SIMD path flags the lane; normalize to the first
                // invalid byte in scalar order for exact reporting.
                let from = pos / DEC_IN * DEC_IN;
                let off = input[from..]
                    .iter()
                    .position(|&c| self.alphabet.value_of(c).is_none())
                    .map(|p| from + p)
                    .expect("flagged group contains an invalid byte");
                return Err(DecodeError::InvalidByte { offset: off, byte: input[off] });
            }
            Ok(consumed)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (input, out);
            Ok(0)
        }
    }
}

impl Codec for Avx2Codec {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let consumed = self.encode_bulk(input, out);
        let w = consumed / 3 * 4;
        // Scalar epilogue (paper's "conventional code path").
        self.scalar_twin.encode_slice(&input[consumed..], &mut out[w..]);
        total
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let body_out = body.len() / 4 * 3;
        let consumed = self.decode_bulk(body, &mut out[..body_out])?;
        let mut w = consumed / 4 * 3;
        // Scalar remainder + tail.
        w += decode_quads_into(
            &body[consumed..],
            self.alphabet.decode_table().as_bytes(),
            consumed,
            &mut out[w..body_out],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;
    use crate::workload::random_bytes;

    fn skip() -> bool {
        if !Avx2Codec::available() {
            eprintln!("skipping: no AVX2 on this host");
            return true;
        }
        false
    }

    #[test]
    fn supports_standard_family_only() {
        assert!(Avx2Codec::supports(&Alphabet::standard()));
        assert!(Avx2Codec::supports(&Alphabet::imap())); // ',' = 0x2C, hi-nibble 2
        assert!(!Avx2Codec::supports(&Alphabet::url())); // '_' = 0x5F
        let mut chars = *crate::base64::alphabet::STANDARD;
        chars.swap(0, 1);
        assert!(!Avx2Codec::supports(&Alphabet::new("x", chars, b'=').unwrap()));
    }

    #[test]
    fn derived_tables_match_2018_constants_for_standard() {
        if skip() {
            return;
        }
        let c = Avx2Codec::new(Alphabet::standard());
        assert_eq!(
            c.dec_lut_lo,
            [0x15, 0x11, 0x11, 0x11, 0x11, 0x11, 0x11, 0x11,
             0x11, 0x11, 0x13, 0x1A, 0x1B, 0x1B, 0x1B, 0x1A]
        );
        assert_eq!(c.dec_roll[..8], [0, 16, 19, 4, -65, -65, -71, -71]);
        assert_eq!(c.c63, b'/');
    }

    #[test]
    fn rfc4648_vectors() {
        if skip() {
            return;
        }
        let c = Avx2Codec::new(Alphabet::standard());
        for (raw, enc) in [
            (&b""[..], &b""[..]),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foobar", b"Zm9vYmFy"),
        ] {
            assert_eq!(c.encode(raw), enc);
            assert_eq!(c.decode(enc).unwrap(), raw);
        }
    }

    #[test]
    fn agrees_with_scalar_across_lengths() {
        if skip() {
            return;
        }
        let s = ScalarCodec::new(Alphabet::standard());
        let c = Avx2Codec::new(Alphabet::standard());
        for len in 0..300usize {
            let data = random_bytes(len, 7000 + len as u64);
            assert_eq!(c.encode(&data), s.encode(&data), "len={len}");
            let enc = s.encode(&data);
            assert_eq!(c.decode(&enc).unwrap(), data, "len={len}");
        }
    }

    #[test]
    fn large_roundtrip() {
        if skip() {
            return;
        }
        let c = Avx2Codec::new(Alphabet::standard());
        let data = random_bytes(1 << 20, 3);
        let enc = c.encode(&data);
        assert_eq!(c.decode(&enc).unwrap(), data);
    }

    #[test]
    fn error_detection_positions() {
        if skip() {
            return;
        }
        let c = Avx2Codec::new(Alphabet::standard());
        let enc = c.encode(&random_bytes(96, 1));
        for pos in 0..enc.len() {
            let mut bad = enc.clone();
            bad[pos] = b'!';
            match c.decode(&bad) {
                Err(DecodeError::InvalidByte { offset, byte: b'!' }) => assert_eq!(offset, pos),
                other => panic!("pos {pos}: {other:?}"),
            }
        }
    }

    #[test]
    fn non_ascii_detected() {
        if skip() {
            return;
        }
        let c = Avx2Codec::new(Alphabet::standard());
        let mut enc = c.encode(&random_bytes(240, 9));
        for pos in [0usize, 31, 32, 100, 319] {
            let orig = enc[pos];
            enc[pos] = 0xE8;
            assert!(c.decode(&enc).is_err(), "pos={pos}");
            enc[pos] = orig;
        }
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn movemask_compaction_matches_scalar_reference() {
        if skip() {
            return;
        }
        use crate::base64::validate::Whitespace;
        let mut x: u32 = 0x5EED;
        for len in [0usize, 1, 31, 32, 33, 63, 64, 100, 256, 1000] {
            let src: Vec<u8> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                    match x >> 29 {
                        0 => b'\r',
                        1 => b'\n',
                        2 => b' ',
                        _ => b'A' + (x >> 24 & 0x0F) as u8,
                    }
                })
                .collect();
            for ws in [Whitespace::CrLf, Whitespace::All] {
                for cap in [len, len / 2, 7] {
                    let mut a = vec![0u8; cap];
                    let mut b = vec![0u8; cap];
                    let got = compact_ws(&src, &mut a, ws);
                    let want = crate::base64::scalar::compact_ws(&src, &mut b, ws);
                    assert_eq!(got, want, "len={len} cap={cap} ws={ws:?}");
                    assert_eq!(a[..got.1], b[..want.1], "len={len} cap={cap} ws={ws:?}");
                }
            }
        }
    }

    #[test]
    fn imap_variant_full_roundtrip() {
        if skip() {
            return;
        }
        // ',' (0x2C) replaces '/': the derived lo-nibble row and roll
        // table handle it; '+' stays in the roll[2] slot.
        let c = Avx2Codec::new(Alphabet::imap());
        let s = ScalarCodec::new(Alphabet::imap());
        for len in [0usize, 3, 33, 120, 1000] {
            let data = random_bytes(len, 40 + len as u64);
            let enc = c.encode(&data);
            assert_eq!(enc, s.encode(&data), "len={len}");
            assert_eq!(c.decode(&enc).unwrap(), data, "len={len}");
        }
        // '/' must now be invalid.
        assert!(c.decode(b"ab/0").is_err());
    }
}
