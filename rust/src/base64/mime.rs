//! RFC 2045 (MIME) line-wrapped base64 — the paper's motivating workload.
//!
//! MIME requires encoded lines of at most 76 characters separated by CRLF,
//! and decoders must ignore line breaks (and, leniently, other whitespace).
//! Both directions are thin zero-copy wrappers over the tier-dispatched
//! [`Engine`]: encode writes CRLFs inline during the store loop
//! ([`Engine::encode_wrapped_slice`]) and decode fuses the whitespace
//! skip into the SIMD loop ([`Engine::decode_slice_ws`]) — there is no
//! strip pass and no intermediate buffer, so the wrapped workload runs at
//! engine speed. Decode error offsets refer to the *original* input.

use super::engine::Engine;
use super::validate::{DecodeError, Mode, Whitespace};
use super::{decoded_len_upper, Alphabet};

/// Maximum encoded line length required by RFC 2045 §6.8.
pub const MIME_LINE_LEN: usize = 76;

/// A wrap line length outside the accepted domain (positive multiple
/// of 4) was requested via [`MimeCodec::with_line_len`]. Carries the
/// rejected length. This used to be an `assert!` — a typed error keeps
/// a hostile or buggy caller (e.g. a wire request carrying `wrap=1`)
/// from panicking the thread that builds the codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InvalidLineLen(pub usize);

impl std::fmt::Display for InvalidLineLen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid wrap line length {} (want a positive multiple of 4)", self.0)
    }
}

impl std::error::Error for InvalidLineLen {}

/// MIME base64 codec: wraps at `line_len`, skips CR/LF (and optionally
/// all whitespace) on decode.
pub struct MimeCodec {
    inner: Engine,
    line_len: usize,
    ws: Whitespace,
}

impl MimeCodec {
    /// RFC 2045 codec: 76-char lines, CRLF skipped on decode.
    pub fn new(alphabet: Alphabet) -> Self {
        Self {
            inner: Engine::with_mode(alphabet, Mode::Strict),
            line_len: MIME_LINE_LEN,
            ws: Whitespace::CrLf,
        }
    }

    /// Override the wrap line length (positive multiple of 4). Lengths
    /// outside that domain are rejected with a typed error rather than
    /// a panic, so untrusted wrap values can be validated by building
    /// the codec.
    pub fn with_line_len(mut self, line_len: usize) -> Result<Self, InvalidLineLen> {
        if line_len < 4 || line_len % 4 != 0 {
            return Err(InvalidLineLen(line_len));
        }
        self.line_len = line_len;
        Ok(self)
    }

    /// Also skip space/tab on decode (lenient MIME bodies).
    pub fn lenient_whitespace(mut self) -> Self {
        self.ws = Whitespace::All;
        self
    }

    /// The whitespace policy the decode path applies.
    pub fn whitespace(&self) -> Whitespace {
        self.ws
    }

    /// The engine this codec dispatches to (tier introspection).
    pub fn engine(&self) -> &Engine {
        &self.inner
    }

    /// Exact output size of [`Self::encode_slice`] for `n` input bytes.
    pub fn encoded_len(&self, n: usize) -> usize {
        self.inner.encoded_wrapped_len(n, self.line_len)
    }

    /// Encode with CRLF wrapping into `out[0..]`, returning the bytes
    /// written (always [`Self::encoded_len`]). The final line carries no
    /// trailing CRLF. Never allocates.
    pub fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        self.inner.encode_wrapped_slice(input, out, self.line_len)
    }

    /// Encode with CRLF wrapping. The final line carries no trailing CRLF.
    pub fn encode(&self, input: &[u8]) -> Vec<u8> {
        let mut out = vec![0u8; self.encoded_len(input.len())];
        let n = self.encode_slice(input, &mut out);
        debug_assert_eq!(n, out.len());
        out
    }

    /// Decode into `out[0..]`, ignoring CRLF (and all whitespace when
    /// lenient), returning the bytes written. `out` must hold
    /// `decoded_len_upper(input.len())` bytes. Error offsets refer to the
    /// original input. Never allocates.
    pub fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        self.inner.decode_slice_ws(input, out, self.ws)
    }

    /// Decode, ignoring CRLF (and all whitespace when lenient). Error
    /// offsets refer to the original input.
    pub fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        let mut out = vec![0u8; decoded_len_upper(input.len())];
        let n = self.decode_slice(input, &mut out)?;
        out.truncate(n);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec() -> MimeCodec {
        MimeCodec::new(Alphabet::standard())
    }

    #[test]
    fn wraps_at_76() {
        let data = vec![0xABu8; 200]; // 268 encoded chars -> 4 lines
        let enc = codec().encode(&data);
        let lines: Vec<&[u8]> = enc.split(|&c| c == b'\n').collect();
        for (i, line) in lines.iter().enumerate() {
            let line = line.strip_suffix(b"\r").unwrap_or(line);
            if i + 1 < lines.len() {
                assert_eq!(line.len(), 76);
            } else {
                assert!(line.len() <= 76 && !line.is_empty());
            }
        }
        assert_eq!(codec().decode(&enc).unwrap(), data);
    }

    #[test]
    fn short_input_no_crlf() {
        let enc = codec().encode(b"hi");
        assert!(!enc.contains(&b'\r'));
        assert_eq!(enc, b"aGk=");
    }

    #[test]
    fn decode_ignores_bare_lf() {
        assert_eq!(codec().decode(b"Zm9v\nYmFy").unwrap(), b"foobar");
    }

    #[test]
    fn strict_rejects_inner_space_lenient_accepts() {
        let c = codec();
        assert!(c.decode(b"Zm9v YmFy").is_err());
        let l = MimeCodec::new(Alphabet::standard()).lenient_whitespace();
        assert_eq!(l.decode(b"Zm9v YmFy").unwrap(), b"foobar");
    }

    #[test]
    fn decode_error_offsets_refer_to_original_input() {
        // '!' at original offset 6 (stripped offset 4): the old strip-pass
        // implementation reported 4.
        let err = codec().decode(b"Zm9v\r\n!mFy").unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 6, byte: b'!' });
        // Lenient: tabs/spaces also shift the mapping.
        let l = MimeCodec::new(Alphabet::standard()).lenient_whitespace();
        let err = l.decode(b" Zm9v\t\r\n!mFy").unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 8, byte: b'!' });
    }

    #[test]
    fn custom_line_len() {
        let c = MimeCodec::new(Alphabet::standard()).with_line_len(8).unwrap();
        let enc = c.encode(&[0u8; 12]); // 16 chars -> two 8-char lines
        assert_eq!(enc, b"AAAAAAAA\r\nAAAAAAAA");
    }

    #[test]
    fn bad_line_len_is_a_typed_error_not_a_panic() {
        // Regression: these were `assert!` panics, which let a hostile
        // wrap value kill the calling thread.
        assert_eq!(
            MimeCodec::new(Alphabet::standard()).with_line_len(7).err(),
            Some(InvalidLineLen(7))
        );
        assert_eq!(
            MimeCodec::new(Alphabet::standard()).with_line_len(0).err(),
            Some(InvalidLineLen(0))
        );
        let msg = InvalidLineLen(1).to_string();
        assert!(msg.contains("invalid wrap line length 1"), "{msg}");
    }

    #[test]
    fn slice_paths_roundtrip() {
        let c = codec();
        let data: Vec<u8> = (0..500u32).map(|i| (i * 7 % 256) as u8).collect();
        let mut enc = vec![0u8; c.encoded_len(data.len())];
        let n = c.encode_slice(&data, &mut enc);
        assert_eq!(n, enc.len());
        let mut dec = vec![0u8; decoded_len_upper(enc.len())];
        let m = c.decode_slice(&enc, &mut dec).unwrap();
        assert_eq!(&dec[..m], &data[..]);
    }

    #[test]
    fn large_roundtrip_through_wrapping() {
        let data: Vec<u8> = (0..10_000).map(|i| (i * 31 % 251) as u8).collect();
        let enc = codec().encode(&data);
        for line in enc.split(|&c| c == b'\n') {
            let line = line.strip_suffix(b"\r").unwrap_or(line);
            assert!(line.len() <= 76);
        }
        assert_eq!(codec().decode(&enc).unwrap(), data);
    }
}
