//! RFC 2045 (MIME) line-wrapped base64 — the paper's motivating workload.
//!
//! MIME requires encoded lines of at most 76 characters separated by CRLF,
//! and decoders must ignore line breaks (and, leniently, other whitespace).
//! The hot path is the tier-dispatched [`Engine`]; wrapping is a
//! post-pass on encode and a strip-pass on decode, both chunk-friendly.

use super::engine::Engine;
use super::validate::{DecodeError, Mode};
use super::{Alphabet, Codec};

/// Maximum encoded line length required by RFC 2045 §6.8.
pub const MIME_LINE_LEN: usize = 76;

/// MIME base64 codec: wraps at `line_len`, strips CR/LF (and optionally
/// all whitespace) on decode.
pub struct MimeCodec {
    inner: Engine,
    line_len: usize,
    /// When true, decode also skips space/tab (lenient MIME bodies).
    skip_all_whitespace: bool,
}

impl MimeCodec {
    pub fn new(alphabet: Alphabet) -> Self {
        Self {
            inner: Engine::with_mode(alphabet, Mode::Strict),
            line_len: MIME_LINE_LEN,
            skip_all_whitespace: false,
        }
    }

    pub fn with_line_len(mut self, line_len: usize) -> Self {
        assert!(line_len >= 4 && line_len % 4 == 0, "line length must be a positive multiple of 4");
        self.line_len = line_len;
        self
    }

    pub fn lenient_whitespace(mut self) -> Self {
        self.skip_all_whitespace = true;
        self
    }

    /// Encode with CRLF wrapping. The final line carries no trailing CRLF.
    pub fn encode(&self, input: &[u8]) -> Vec<u8> {
        let flat = self.inner.encode(input);
        let lines = flat.len().div_ceil(self.line_len);
        let mut out = Vec::with_capacity(flat.len() + lines.saturating_sub(1) * 2);
        for (i, line) in flat.chunks(self.line_len).enumerate() {
            if i > 0 {
                out.extend_from_slice(b"\r\n");
            }
            out.extend_from_slice(line);
        }
        out
    }

    /// Decode, ignoring CRLF (and all whitespace when lenient). Offsets in
    /// errors refer to the *stripped* stream.
    pub fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        let stripped: Vec<u8> = input
            .iter()
            .copied()
            .filter(|&c| {
                !(c == b'\r'
                    || c == b'\n'
                    || (self.skip_all_whitespace && (c == b' ' || c == b'\t')))
            })
            .collect();
        self.inner.decode(&stripped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec() -> MimeCodec {
        MimeCodec::new(Alphabet::standard())
    }

    #[test]
    fn wraps_at_76() {
        let data = vec![0xABu8; 200]; // 268 encoded chars -> 4 lines
        let enc = codec().encode(&data);
        let lines: Vec<&[u8]> = enc.split(|&c| c == b'\n').collect();
        for (i, line) in lines.iter().enumerate() {
            let line = line.strip_suffix(b"\r").unwrap_or(line);
            if i + 1 < lines.len() {
                assert_eq!(line.len(), 76);
            } else {
                assert!(line.len() <= 76 && !line.is_empty());
            }
        }
        assert_eq!(codec().decode(&enc).unwrap(), data);
    }

    #[test]
    fn short_input_no_crlf() {
        let enc = codec().encode(b"hi");
        assert!(!enc.contains(&b'\r'));
        assert_eq!(enc, b"aGk=");
    }

    #[test]
    fn decode_ignores_bare_lf() {
        assert_eq!(codec().decode(b"Zm9v\nYmFy").unwrap(), b"foobar");
    }

    #[test]
    fn strict_rejects_inner_space_lenient_accepts() {
        let c = codec();
        assert!(c.decode(b"Zm9v YmFy").is_err());
        let l = MimeCodec::new(Alphabet::standard()).lenient_whitespace();
        assert_eq!(l.decode(b"Zm9v YmFy").unwrap(), b"foobar");
    }

    #[test]
    fn custom_line_len() {
        let c = MimeCodec::new(Alphabet::standard()).with_line_len(8);
        let enc = c.encode(&[0u8; 12]); // 16 chars -> two 8-char lines
        assert_eq!(enc, b"AAAAAAAA\r\nAAAAAAAA");
    }

    #[test]
    #[should_panic]
    fn bad_line_len_panics() {
        MimeCodec::new(Alphabet::standard()).with_line_len(7);
    }

    #[test]
    fn large_roundtrip_through_wrapping() {
        let data: Vec<u8> = (0..10_000).map(|i| (i * 31 % 251) as u8).collect();
        let enc = codec().encode(&data);
        for line in enc.split(|&c| c == b'\n') {
            let line = line.strip_suffix(b"\r").unwrap_or(line);
            assert!(line.len() <= 76);
        }
        assert_eq!(codec().decode(&enc).unwrap(), data);
    }
}
