//! The paper's AVX-512 block algorithm, transliterated to scalar Rust.
//!
//! This is the *reference twin* of the Pallas kernel: the same
//! 48-byte-in / 64-char-out (encode) and 64-char-in / 48-byte-out
//! (decode) block structure, the same shuffle/multishift/lookup and
//! lookup/ternlog/madd/compact stages, the same deferred error
//! accumulator. It serves three purposes:
//!
//! 1. the coordinator's **tail path** (the paper's "conventional code
//!    path" for inputs not divisible by 48/64 — §3.1, §3.2);
//! 2. the differential-testing oracle for the PJRT executables;
//! 3. the object of the **macro-op accounting** in
//!    [`crate::perfmodel::opcount`]: each commented stage below is one
//!    AVX-512 instruction in the paper ([`ENCODE_SIMD_OPS`] = 3,
//!    [`DECODE_SIMD_OPS`] = 5 (+1 `vpmovb2m` per stream)).
//!
//! On real AVX-512 hardware every stage is one instruction over a 512-bit
//! register; here each stage is a 16-iteration lane loop the compiler
//! auto-vectorizes over the host's widest registers.

use super::validate::{
    decode_quads_into, decode_tail_into, first_invalid, split_tail, DecodeError, Mode,
};
use super::{encoded_len, Alphabet, Codec, B64_BLOCK, RAW_BLOCK};

/// SIMD instructions per encoded 64-byte register in the paper (§3.1):
/// `vpermb`, `vpmultishiftqb`, `vpermb`.
pub const ENCODE_SIMD_OPS: usize = 3;
/// SIMD instructions per decoded 64-byte register in the paper (§3.2):
/// `vpermi2b`, `vpternlogd`, `vpmaddubsw`, `vpmaddwd`, `vpermb`.
pub const DECODE_SIMD_OPS: usize = 5;
/// Stream-level instructions (§3.2): one `vpmovb2m` error-mask check.
pub const DECODE_STREAM_OPS: usize = 1;

/// The paper's multishift list (§3.1), applied per 32-bit shuffled group.
pub const MULTISHIFT: [u32; 4] = [10, 4, 22, 16];

/// Block codec implementing the paper's §3 algorithm.
#[derive(Debug, Clone)]
pub struct BlockCodec {
    alphabet: Alphabet,
    mode: Mode,
    /// Full-byte decode table: entry = 6-bit value, or 0x80 for every
    /// byte outside the alphabet *including all of [0x80, 0xFF]*. This
    /// folds the paper's `input | lookup` OR (which exists because
    /// `vpermi2b` ignores the index MSB) into the table itself — the
    /// scalar substrate has no 7-bit-index restriction, so the error
    /// accumulator only needs the lookup results.
    dtable256: [u8; 256],
}

impl BlockCodec {
    /// Strict-mode codec for an alphabet.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_mode(alphabet, Mode::Strict)
    }

    /// [`Self::new`] with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        let mut dtable256 = [0x80u8; 256];
        let half = alphabet.decode_table().as_bytes();
        dtable256[..128].copy_from_slice(half);
        Self { alphabet, mode, dtable256 }
    }

    /// The alphabet this codec was built for.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }

    /// Encode one 48-byte block into 64 base64 characters (paper §3.1).
    #[inline]
    pub fn encode_block(&self, input: &[u8; RAW_BLOCK], out: &mut [u8; B64_BLOCK]) {
        let table = self.alphabet.encode_table();
        for g in 0..16 {
            let (s1, s2, s3) = (
                input[3 * g] as u32,
                input[3 * g + 1] as u32,
                input[3 * g + 2] as u32,
            );
            // -- vpermb #1: (s1,s2,s3) -> (s2,s1,s3,s2) packed per lane.
            let t = s2 | (s1 << 8) | (s3 << 16) | (s2 << 24);
            // -- vpmultishiftqb: rotate-extract the four 6-bit fields.
            // -- vpermb #2: alphabet lookup (6 LSBs of each index).
            out[4 * g] = table.lookup((t >> MULTISHIFT[0]) as u8);
            out[4 * g + 1] = table.lookup((t >> MULTISHIFT[1]) as u8);
            out[4 * g + 2] = table.lookup((t >> MULTISHIFT[2]) as u8);
            out[4 * g + 3] = table.lookup((t >> MULTISHIFT[3]) as u8);
        }
    }

    /// Decode one 64-char block into 48 bytes, OR-ing `input | lookup`
    /// into `err` exactly like the paper's `vpternlogd` accumulator
    /// (paper §3.2). `err & 0x80 != 0` after the stream means some block
    /// contained an invalid character.
    #[inline]
    pub fn decode_block(&self, input: &[u8; B64_BLOCK], out: &mut [u8; RAW_BLOCK], err: &mut u8) {
        let t = &self.dtable256;
        let mut acc = 0u8;
        for (quad, dst) in input.chunks_exact(4).zip(out.chunks_exact_mut(3)) {
            // -- vpermi2b: table lookup (full-byte table; see `dtable256`
            //    for why the paper's `input |` OR is folded in).
            let v0 = t[quad[0] as usize] as u32;
            let v1 = t[quad[1] as usize] as u32;
            let v2 = t[quad[2] as usize] as u32;
            let v3 = t[quad[3] as usize] as u32;
            // -- vpternlogd: ERROR |= lookups.
            acc |= (v0 | v1 | v2 | v3) as u8;
            // -- vpmaddubsw: D + C*2^6 ; -- vpmaddwd: CD + AB*2^12.
            let w = (((v0 << 6) | v1) << 12) | (v2 << 6) | v3;
            // -- vpermb: compact 3-of-4 bytes, byte-order fixup.
            dst[0] = (w >> 16) as u8;
            dst[1] = (w >> 8) as u8;
            dst[2] = w as u8;
        }
        *err |= acc;
    }

    /// Bulk slice core: encode all whole 48-byte blocks of `input` into
    /// `out[0..]` (64 chars per block), returning the raw bytes consumed.
    /// The remainder (< 48 bytes) is the caller's scalar epilogue.
    pub(crate) fn encode_bulk(&self, input: &[u8], out: &mut [u8]) -> usize {
        let blocks = input.len() / RAW_BLOCK;
        for b in 0..blocks {
            let inp: &[u8; RAW_BLOCK] =
                input[b * RAW_BLOCK..(b + 1) * RAW_BLOCK].try_into().unwrap();
            let dst: &mut [u8; B64_BLOCK] =
                (&mut out[b * B64_BLOCK..(b + 1) * B64_BLOCK]).try_into().unwrap();
            self.encode_block(inp, dst);
        }
        blocks * RAW_BLOCK
    }

    /// Bulk slice core: decode all whole 64-char blocks of `body` into
    /// `out[0..]` (48 bytes per block) with deferred validation — the
    /// error accumulator is checked once at the end (the paper's
    /// `vpmovb2m` + branch per *stream*, not per block). On failure the
    /// input is re-scanned to report the exact offending byte. Returns
    /// the chars consumed.
    pub(crate) fn decode_bulk(&self, body: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let blocks = body.len() / B64_BLOCK;
        let mut err = 0u8;
        for b in 0..blocks {
            let inp: &[u8; B64_BLOCK] =
                body[b * B64_BLOCK..(b + 1) * B64_BLOCK].try_into().unwrap();
            let dst: &mut [u8; RAW_BLOCK] =
                (&mut out[b * RAW_BLOCK..(b + 1) * RAW_BLOCK]).try_into().unwrap();
            self.decode_block(inp, dst, &mut err);
        }
        // -- vpmovb2m + branch, once per stream.
        if err & 0x80 != 0 {
            let bad = first_invalid(&body[..blocks * B64_BLOCK], &self.dtable256_low())
                .expect("error accumulator set implies an invalid byte");
            return Err(DecodeError::InvalidByte { offset: bad, byte: body[bad] });
        }
        Ok(blocks * B64_BLOCK)
    }

    /// The low 128 entries of the folded decode table (the `vpermi2b`
    /// register pair), for the shared validation helpers.
    fn dtable256_low(&self) -> [u8; 128] {
        self.dtable256[..128].try_into().unwrap()
    }

    /// Encode all whole 48-byte blocks of `input`, appending to `out` and
    /// returning the number of raw bytes consumed (Vec wrapper over
    /// the crate-internal `encode_bulk` slice core).
    pub fn encode_full_blocks(&self, input: &[u8], out: &mut Vec<u8>) -> usize {
        let start = out.len();
        let blocks = input.len() / RAW_BLOCK;
        out.resize(start + blocks * B64_BLOCK, 0);
        self.encode_bulk(input, &mut out[start..])
    }

    /// Decode all whole 64-char blocks, appending to `out` (Vec wrapper
    /// over the crate-internal `decode_bulk` slice core; `out` is
    /// restored on error).
    pub fn decode_full_blocks(
        &self,
        input: &[u8],
        out: &mut Vec<u8>,
    ) -> Result<usize, DecodeError> {
        let start = out.len();
        let blocks = input.len() / B64_BLOCK;
        out.resize(start + blocks * RAW_BLOCK, 0);
        match self.decode_bulk(input, &mut out[start..]) {
            Ok(consumed) => Ok(consumed),
            Err(e) => {
                out.truncate(start);
                Err(e)
            }
        }
    }
}

impl Codec for BlockCodec {
    fn name(&self) -> &'static str {
        "block"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let consumed = self.encode_bulk(input, out);
        let mut w = consumed / 3 * 4;
        // Scalar epilogue for the sub-block remainder (paper §3.1).
        let table = self.alphabet.encode_table();
        let pad = self.alphabet.pad();
        let mut chunks = input[consumed..].chunks_exact(3);
        for chunk in &mut chunks {
            let (s1, s2, s3) = (chunk[0], chunk[1], chunk[2]);
            out[w] = table.lookup(s1 >> 2);
            out[w + 1] = table.lookup((s1 << 4) | (s2 >> 4));
            out[w + 2] = table.lookup((s2 << 2) | (s3 >> 6));
            out[w + 3] = table.lookup(s3);
            w += 4;
        }
        match chunks.remainder() {
            [] => {}
            [s1] => {
                out[w] = table.lookup(s1 >> 2);
                out[w + 1] = table.lookup(s1 << 4);
                out[w + 2] = pad;
                out[w + 3] = pad;
                w += 4;
            }
            [s1, s2] => {
                out[w] = table.lookup(s1 >> 2);
                out[w + 1] = table.lookup((s1 << 4) | (s2 >> 4));
                out[w + 2] = table.lookup(s2 << 2);
                out[w + 3] = pad;
                w += 4;
            }
            _ => unreachable!(),
        }
        debug_assert_eq!(w, total);
        w
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let consumed = self.decode_bulk(body, out)?;
        let mut w = consumed / 4 * 3;
        // Sub-block remainder: quantum-at-a-time scalar path.
        w += decode_quads_into(
            &body[consumed..],
            &self.dtable256_low(),
            consumed,
            &mut out[w..],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;

    fn codec() -> BlockCodec {
        BlockCodec::new(Alphabet::standard())
    }

    #[test]
    fn rfc4648_test_vectors() {
        let c = codec();
        for (raw, enc) in [
            (&b""[..], &b""[..]),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foobar", b"Zm9vYmFy"),
        ] {
            assert_eq!(c.encode(raw), enc);
            assert_eq!(c.decode(enc).unwrap(), raw);
        }
    }

    #[test]
    fn single_block_paper_shapes() {
        // Exactly one block: 48 raw bytes -> 64 chars, no padding.
        let c = codec();
        let data: Vec<u8> = (0u8..48).collect();
        let enc = c.encode(&data);
        assert_eq!(enc.len(), 64);
        assert!(!enc.contains(&b'='));
        assert_eq!(c.decode(&enc).unwrap(), data);
    }

    #[test]
    fn agrees_with_scalar_across_lengths() {
        let s = ScalarCodec::new(Alphabet::standard());
        let c = codec();
        let mut x: u32 = 7;
        for len in 0..260usize {
            let data: Vec<u8> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                    (x >> 13) as u8
                })
                .collect();
            assert_eq!(c.encode(&data), s.encode(&data), "len={len}");
            let enc = s.encode(&data);
            assert_eq!(c.decode(&enc).unwrap(), data, "len={len}");
        }
    }

    #[test]
    fn deferred_error_found_in_any_block() {
        let c = codec();
        let data = vec![0xA5u8; 48 * 5];
        let mut enc = c.encode(&data);
        for pos in [0usize, 63, 64, 190, 319] {
            let orig = enc[pos];
            enc[pos] = b'!';
            let err = c.decode(&enc).unwrap_err();
            assert_eq!(err, DecodeError::InvalidByte { offset: pos, byte: b'!' }, "pos={pos}");
            enc[pos] = orig;
        }
        assert_eq!(c.decode(&enc).unwrap(), data);
    }

    #[test]
    fn error_accumulator_catches_non_ascii() {
        let c = codec();
        let mut enc = c.encode(&[0u8; 48]);
        enc[10] = 0xE9;
        assert!(matches!(c.decode(&enc), Err(DecodeError::InvalidByte { offset: 10, byte: 0xE9 })));
    }

    #[test]
    fn failed_decode_leaves_out_empty() {
        let c = codec();
        let mut enc = c.encode(&[1u8; 96]);
        enc[70] = b'=';
        let mut out = b"keep".to_vec();
        assert!(c.decode_into(&enc, &mut out).is_err());
        assert_eq!(out, b"keep");
    }

    #[test]
    fn url_variant_block_path() {
        let c = BlockCodec::new(Alphabet::url());
        let data = vec![0xFBu8; 48];
        let enc = c.encode(&data);
        assert!(enc.contains(&b'-') || enc.contains(&b'_'));
        assert_eq!(c.decode(&enc).unwrap(), data);
    }

    #[test]
    fn multishift_constants_match_paper() {
        assert_eq!(MULTISHIFT, [10, 4, 22, 16]);
        assert_eq!(ENCODE_SIMD_OPS, 3);
        assert_eq!(DECODE_SIMD_OPS, 5);
    }
}
