//! Encode/decode lookup tables — the `vpermb`/`vpermi2b` register contents.

/// Sentinel for "not a base64 character" in [`DecodeTable`]. Chosen as
/// 0x80 exactly as in the paper: `input | table[input]` has its MSB set
/// iff the input byte was invalid (including all non-ASCII bytes).
pub const INVALID: u8 = 0x80;

/// 64-entry value -> ASCII table (the encoder's `vpermb` register).
#[derive(Clone, PartialEq, Eq)]
pub struct EncodeTable([u8; 64]);

impl EncodeTable {
    /// Table over the 64 alphabet characters.
    pub fn new(chars: &[u8; 64]) -> Self {
        Self(*chars)
    }

    /// Map a 6-bit value to its character. Like `vpermb`, only the six
    /// least significant bits of the index participate.
    #[inline(always)]
    pub fn lookup(&self, value: u8) -> u8 {
        self.0[(value & 0x3F) as usize]
    }

    /// Raw table, e.g. to feed the PJRT executable's table input.
    #[inline]
    pub fn as_bytes(&self) -> &[u8; 64] {
        &self.0
    }
}

/// 128-entry ASCII -> value table (the decoder's `vpermi2b` register
/// pair); [`INVALID`] everywhere outside the alphabet.
#[derive(Clone, PartialEq, Eq)]
pub struct DecodeTable([u8; 128]);

impl DecodeTable {
    /// Inverse table of the 64 alphabet characters.
    pub fn new(chars: &[u8; 64]) -> Self {
        let mut t = [INVALID; 128];
        for (value, &c) in chars.iter().enumerate() {
            debug_assert!(c < 0x80);
            t[c as usize] = value as u8;
        }
        Self(t)
    }

    /// Map a byte to its 6-bit value or [`INVALID`]. Like `vpermi2b`, the
    /// MSB of the index is ignored — callers must OR the input back in to
    /// flag non-ASCII bytes (which [`crate::base64::block`] does).
    #[inline(always)]
    pub fn lookup(&self, c: u8) -> u8 {
        self.0[(c & 0x7F) as usize]
    }

    /// Raw table, e.g. to feed the PJRT executable's table input.
    #[inline]
    pub fn as_bytes(&self) -> &[u8; 128] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::alphabet::STANDARD;

    #[test]
    fn roundtrip_all_values() {
        let e = EncodeTable::new(STANDARD);
        let d = DecodeTable::new(STANDARD);
        for v in 0..64u8 {
            assert_eq!(d.lookup(e.lookup(v)), v);
        }
    }

    #[test]
    fn vpermb_ignores_top_bits() {
        let e = EncodeTable::new(STANDARD);
        for v in 0..=255u8 {
            assert_eq!(e.lookup(v), e.lookup(v & 0x3F));
        }
    }

    #[test]
    fn invalid_has_msb_set() {
        let d = DecodeTable::new(STANDARD);
        for c in 0..128u8 {
            let is_b64 = STANDARD.contains(&c);
            assert_eq!(d.lookup(c) & 0x80 != 0, !is_b64, "c={c:#x}");
        }
    }

    #[test]
    fn or_trick_flags_non_ascii() {
        // The paper's §3.2 validation identity: (c | lookup(c)) & 0x80 != 0
        // iff c invalid, for ALL 256 byte values.
        let d = DecodeTable::new(STANDARD);
        for c in 0..=255u8 {
            let flagged = (c | d.lookup(c)) & 0x80 != 0;
            let is_b64 = c < 0x80 && STANDARD.contains(&c);
            assert_eq!(flagged, !is_b64, "c={c:#x}");
        }
    }
}
