//! Base64 variants as runtime data — the paper's versatility claim.
//!
//! Every codec in this crate (and the AOT-compiled PJRT executables) takes
//! the 64-byte alphabet / 128-byte decode table as *values*, mirroring the
//! paper's `vpermb`/`vpermi2b` table registers: "any 64-byte mapping is
//! feasible, even if determined dynamically at runtime" (§3.1).

use super::tables::{DecodeTable, EncodeTable};

/// RFC 4648 §4 standard alphabet (Table 1 of the paper).
pub const STANDARD: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// RFC 4648 §5 URL-and-filename-safe alphabet.
pub const URL: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789-_";

/// RFC 3501 IMAP mailbox-name variant.
pub const IMAP: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+,";

/// A validated base64 variant: 64 distinct ASCII characters plus the
/// padding character, with both direction tables precomputed.
#[derive(Clone, PartialEq, Eq)]
pub struct Alphabet {
    name: &'static str,
    chars: [u8; 64],
    pad: u8,
    encode: EncodeTable,
    decode: DecodeTable,
}

impl std::fmt::Debug for Alphabet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Alphabet")
            .field("name", &self.name)
            .field("chars", &String::from_utf8_lossy(&self.chars))
            .field("pad", &(self.pad as char))
            .finish()
    }
}

/// Errors produced when constructing an [`Alphabet`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AlphabetError {
    /// A character is not 7-bit ASCII.
    NonAscii(u8),
    /// A character appears twice (or padding collides with the alphabet).
    Duplicate(u8),
}

impl std::fmt::Display for AlphabetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NonAscii(c) => write!(f, "non-ASCII alphabet byte 0x{c:02x}"),
            Self::Duplicate(c) => write!(f, "duplicate alphabet byte 0x{c:02x}"),
        }
    }
}

impl std::error::Error for AlphabetError {}

impl Alphabet {
    /// Build a custom variant from 64 ASCII characters and a padding char.
    pub fn new(name: &'static str, chars: [u8; 64], pad: u8) -> Result<Self, AlphabetError> {
        let mut seen = [false; 128];
        for &c in chars.iter().chain(std::iter::once(&pad)) {
            if c >= 0x80 {
                return Err(AlphabetError::NonAscii(c));
            }
            if seen[c as usize] {
                return Err(AlphabetError::Duplicate(c));
            }
            seen[c as usize] = true;
        }
        let encode = EncodeTable::new(&chars);
        let decode = DecodeTable::new(&chars);
        Ok(Self { name, chars, pad, encode, decode })
    }

    /// The RFC 4648 standard variant ('+', '/', pad '=').
    pub fn standard() -> Self {
        Self::new("standard", *STANDARD, b'=').expect("standard alphabet is valid")
    }

    /// The RFC 4648 URL-safe variant ('-', '_', pad '=').
    pub fn url() -> Self {
        Self::new("url", *URL, b'=').expect("url alphabet is valid")
    }

    /// The RFC 3501 IMAP variant ('+', ',', pad '=').
    pub fn imap() -> Self {
        Self::new("imap", *IMAP, b'=').expect("imap alphabet is valid")
    }

    /// Look a variant up by name (CLI / server convenience).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "standard" => Some(Self::standard()),
            "url" => Some(Self::url()),
            "imap" => Some(Self::imap()),
            _ => None,
        }
    }

    /// The alphabet's registry name (`"standard"`, `"url"`, `"imap"`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The 64 alphabet characters — the encoder's `vpermb` register.
    pub fn chars(&self) -> &[u8; 64] {
        &self.chars
    }

    /// The padding character (usually '=').
    pub fn pad(&self) -> u8 {
        self.pad
    }

    /// value -> char table.
    pub fn encode_table(&self) -> &EncodeTable {
        &self.encode
    }

    /// char -> value table (128 entries, [`INVALID`](super::tables::INVALID)
    /// elsewhere) — the
    /// decoder's `vpermi2b` register pair.
    pub fn decode_table(&self) -> &DecodeTable {
        &self.decode
    }

    /// char -> 6-bit value, or `None` when outside the variant (including
    /// all non-ASCII bytes, which the 7-bit table lookup would alias).
    #[inline]
    pub fn value_of(&self, c: u8) -> Option<u8> {
        let v = self.decode.lookup(c);
        ((c | v) & 0x80 == 0).then_some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_matches_table1() {
        let a = Alphabet::standard();
        // Spot values from Table 1 of the paper.
        for (value, ch) in [(0u8, b'A'), (25, b'Z'), (26, b'a'), (51, b'z'), (52, b'0'), (61, b'9'), (62, b'+'), (63, b'/')] {
            assert_eq!(a.chars()[value as usize], ch);
            assert_eq!(a.value_of(ch), Some(value));
        }
    }

    #[test]
    fn url_variant_differs_only_at_62_63() {
        let s = Alphabet::standard();
        let u = Alphabet::url();
        assert_eq!(&s.chars()[..62], &u.chars()[..62]);
        assert_eq!(u.chars()[62], b'-');
        assert_eq!(u.chars()[63], b'_');
        assert_eq!(u.value_of(b'+'), None);
        assert_eq!(u.value_of(b'-'), Some(62));
    }

    #[test]
    fn duplicate_rejected() {
        let mut chars = *STANDARD;
        chars[10] = b'A';
        assert!(matches!(
            Alphabet::new("dup", chars, b'='),
            Err(AlphabetError::Duplicate(b'A'))
        ));
    }

    #[test]
    fn pad_collision_rejected() {
        assert!(matches!(
            Alphabet::new("padcol", *STANDARD, b'A'),
            Err(AlphabetError::Duplicate(b'A'))
        ));
    }

    #[test]
    fn non_ascii_rejected() {
        let mut chars = *STANDARD;
        chars[0] = 0xC3;
        assert!(matches!(
            Alphabet::new("bad", chars, b'='),
            Err(AlphabetError::NonAscii(0xC3))
        ));
    }

    #[test]
    fn custom_runtime_alphabet_roundtrips() {
        // Rotate the standard alphabet — a "determined at runtime" mapping.
        let mut chars = [0u8; 64];
        for i in 0..64 {
            chars[i] = STANDARD[(i + 17) % 64];
        }
        let a = Alphabet::new("rot17", chars, b'=').unwrap();
        for v in 0..64u8 {
            assert_eq!(a.value_of(a.chars()[v as usize]), Some(v));
        }
    }
}
