//! Zero-allocation engine with tiered runtime dispatch — the facade the
//! rest of the system encodes and decodes through.
//!
//! The paper's headline claim (base64 at almost the speed of `memcpy`)
//! only survives if the surrounding code adds no memory traffic of its
//! own. This module removes the two hot-path taxes the `Vec`-returning
//! codec API carried:
//!
//! * **allocation** — [`Engine::encode_slice`] / [`Engine::decode_slice`]
//!   write into caller-provided buffers and never touch the heap
//!   (asserted by the counting-allocator test in `rust/tests/alloc.rs`);
//! * **dynamic dispatch** — CPU feature detection runs exactly once
//!   (cached in a [`OnceLock`]) and the chosen tier's kernels are held as
//!   plain function pointers, not `Box<dyn Codec>` vtables.
//!
//! ## Tier selection
//!
//! Detection order, best first (the middle tiers are the ones both Muła &
//! Lemire papers treat as essential):
//!
//! 1. [`Tier::Avx512`] — `avx512f + avx512bw + avx512vbmi`: the paper's
//!    §3 instruction sequence ([`Avx512Codec`]);
//! 2. [`Tier::Avx2`] — the 2018 AVX2 codec ([`Avx2Codec`]); only used
//!    for alphabets with the 2018 range structure (base64url falls
//!    through to SWAR — exactly the versatility gap §5 describes);
//! 3. [`Tier::Swar`] — the wide-table u32 codec ([`SwarCodec`]);
//! 4. [`Tier::Scalar`] — the scalar block codec ([`BlockCodec`]), the
//!    portable floor (forced only; SWAR beats it everywhere).
//!
//! Set `B64SIMD_TIER=avx512|avx2|swar|scalar` to force a tier (clamped
//! to what the host supports), or construct one explicitly with
//! [`Engine::with_tier`].
//!
//! ## Parallel path
//!
//! For payloads larger than a core's L2 a single stream is memory-bound;
//! base64 is embarrassingly parallel on 48/64-byte boundaries, so
//! [`Engine::encode_par`] / [`Engine::decode_par`] split the input on
//! block boundaries across scoped threads and push aggregate throughput
//! past a single core's memcpy ceiling.
//!
//! ## Store policy
//!
//! Every entry point has a `_policy` twin taking a
//! [`StorePolicy`] (`Temporal | NonTemporal | Auto(threshold)`); the
//! plain methods resolve against the engine's default (the
//! `B64SIMD_STORES` env override, else `Auto` at the detected
//! last-level-cache size). Non-temporal mode produces into L1-resident
//! staging blocks and streams them to the destination with the tier's
//! cache-line stores (`_mm512_stream_si512` / `_mm256_stream_si256`,
//! plain stores on SWAR/scalar), prefetching the input a tier-scaled
//! distance ahead — see [`super::stores`] for the alignment-peel
//! invariant and the `sfence` contract. Output bytes and error offsets
//! are byte-identical under every policy.

use std::sync::OnceLock;

use super::avx2::Avx2Codec;
use super::avx512::Avx512Codec;
use super::block::BlockCodec;
use super::stores::{self, StorePolicy};
use super::swar::SwarCodec;
use super::validate::{
    decode_quads_into, decode_tail_into, rebase_ws_error, split_tail, Whitespace,
};
use super::{decoded_len, encoded_len, Alphabet, Codec, DecodeError, Mode, B64_BLOCK, RAW_BLOCK};

/// Inputs below this many bytes stay single-threaded in the `_par` paths
/// (roughly an L2 capacity: smaller payloads are compute- or
/// cache-resident and forking threads only adds latency).
pub const PAR_THRESHOLD: usize = 1 << 20;

/// One of the engine's dispatch tiers, best first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// The paper's §3 AVX-512 VBMI instruction sequence.
    Avx512,
    /// The 2018 AVX2 codec (standard-structure alphabets only).
    Avx2,
    /// Wide-table SWAR on plain u32/u64 registers.
    Swar,
    /// The scalar block codec — the portable floor.
    Scalar,
}

impl Tier {
    /// Benchmark/series label.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx512 => "avx512",
            Tier::Avx2 => "avx2",
            Tier::Swar => "swar",
            Tier::Scalar => "scalar",
        }
    }

    /// Parse a tier name (the `B64SIMD_TIER` env values).
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "avx512" => Some(Tier::Avx512),
            "avx2" => Some(Tier::Avx2),
            "swar" => Some(Tier::Swar),
            "scalar" | "block" => Some(Tier::Scalar),
            _ => None,
        }
    }

    /// True iff the host CPU can run this tier.
    pub fn available(self) -> bool {
        match self {
            Tier::Avx512 => Avx512Codec::available(),
            Tier::Avx2 => Avx2Codec::available(),
            Tier::Swar | Tier::Scalar => true,
        }
    }

    /// Every tier the host supports, best first.
    pub fn supported() -> Vec<Tier> {
        [Tier::Avx512, Tier::Avx2, Tier::Swar, Tier::Scalar]
            .into_iter()
            .filter(|t| t.available())
            .collect()
    }

    /// The next tier down the ladder (used to clamp forced tiers).
    fn fallback(self) -> Tier {
        match self {
            Tier::Avx512 => Tier::Avx2,
            Tier::Avx2 => Tier::Swar,
            Tier::Swar | Tier::Scalar => Tier::Scalar,
        }
    }

    /// Clamp to host capability: walk down until a tier is available.
    fn clamp(mut self) -> Tier {
        while !self.available() {
            self = self.fallback();
        }
        self
    }
}

/// One-time tier detection: CPUID probes (plus the `B64SIMD_TIER`
/// override) run on first call, the answer is cached for the process.
pub fn detected_tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(|| {
        if let Ok(forced) = std::env::var("B64SIMD_TIER") {
            if let Some(t) = Tier::parse(&forced) {
                return t.clamp();
            }
            crate::log_warn!("engine", "ignoring unknown B64SIMD_TIER value '{forced}'");
        }
        if Avx512Codec::available() {
            Tier::Avx512
        } else if Avx2Codec::available() {
            Tier::Avx2
        } else {
            Tier::Swar
        }
    })
}

/// The tier kernels as plain function pointers — the flat facade that
/// replaces `Box<dyn Codec>` dispatch on the hot path. Both pointers
/// follow the bulk contract: consume a whole-granule prefix of the
/// input, write its exact output at `out[0..]`, return bytes consumed.
#[derive(Clone, Copy)]
struct Kernels {
    encode_bulk: fn(&Engine, &[u8], &mut [u8]) -> usize,
    decode_bulk: fn(&Engine, &[u8], &mut [u8]) -> Result<usize, DecodeError>,
}

fn enc_avx512(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.avx512.as_ref().expect("avx512 tier state").encode_bulk(input, out)
}

fn dec_avx512(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.avx512.as_ref().expect("avx512 tier state").decode_bulk(input, out)
}

fn enc_avx2(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.avx2.as_ref().expect("avx2 tier state").encode_bulk(input, out)
}

fn dec_avx2(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.avx2.as_ref().expect("avx2 tier state").decode_bulk(input, out)
}

fn enc_swar(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.swar.as_ref().expect("swar tier state").encode_bulk(input, out)
}

fn dec_swar(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.swar.as_ref().expect("swar tier state").decode_bulk(input, out)
}

fn enc_scalar(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.block.encode_bulk(input, out)
}

fn dec_scalar(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.block.decode_bulk(input, out)
}

fn kernels_for(tier: Tier) -> Kernels {
    match tier {
        Tier::Avx512 => Kernels { encode_bulk: enc_avx512, decode_bulk: dec_avx512 },
        Tier::Avx2 => Kernels { encode_bulk: enc_avx2, decode_bulk: dec_avx2 },
        Tier::Swar => Kernels { encode_bulk: enc_swar, decode_bulk: dec_swar },
        Tier::Scalar => Kernels { encode_bulk: enc_scalar, decode_bulk: dec_scalar },
    }
}

/// Whitespace compaction kernel: copy non-skipped bytes from `src` into
/// `dst` until `src` is exhausted or `dst` is full, returning
/// `(src_consumed, dst_written)`. This is the staging step of the fused
/// whitespace decode.
type CompactFn = fn(&[u8], &mut [u8], Whitespace) -> (usize, usize);

/// Pick the best compaction the tier + host supports. The SIMD tiers
/// prefer `vpcompressb` (AVX-512 VBMI2) and fall back to AVX2 movemask
/// compaction, then word-at-a-time SWAR; the forced scalar tier keeps a
/// byte-at-a-time reference loop so `B64SIMD_TIER=scalar` really is a
/// fully scalar pipeline.
fn compact_for(tier: Tier) -> CompactFn {
    if tier == Tier::Scalar {
        return super::scalar::compact_ws;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if tier == Tier::Avx512 && Avx512Codec::vbmi2_available() {
            return super::avx512::compact_ws;
        }
        if matches!(tier, Tier::Avx512 | Tier::Avx2) && Avx2Codec::available() {
            return super::avx2::compact_ws;
        }
    }
    super::swar::compact_ws
}

/// The allocation-free, tier-dispatched codec facade.
pub struct Engine {
    alphabet: Alphabet,
    mode: Mode,
    tier: Tier,
    kernels: Kernels,
    /// Whitespace compaction for the fused decode (tier-matched).
    compact: CompactFn,
    /// Default store policy for the non-`_policy` entry points
    /// (`B64SIMD_STORES` override, else `Auto` at the detected LLC).
    policy: StorePolicy,
    /// Staged-batch copy kernel for the non-temporal path (tier-matched:
    /// streaming stores on the SIMD tiers, plain stores below).
    nt_copy: stores::CopyFn,
    /// Scalar block codec: the epilogue/tail path of every tier and the
    /// bulk path of [`Tier::Scalar`].
    block: BlockCodec,
    swar: Option<SwarCodec>,
    avx2: Option<Avx2Codec>,
    avx512: Option<Avx512Codec>,
}

impl Engine {
    /// The process-wide engine: standard alphabet, strict mode, best
    /// tier. Detection and table construction run exactly once.
    pub fn get() -> &'static Engine {
        static ENGINE: OnceLock<Engine> = OnceLock::new();
        ENGINE.get_or_init(|| Engine::new(Alphabet::standard()))
    }

    /// Engine for an alphabet at the host's best tier, strict mode.
    pub fn new(alphabet: Alphabet) -> Engine {
        Self::with_tier_mode(alphabet, Mode::Strict, detected_tier())
    }

    /// Engine with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Engine {
        Self::with_tier_mode(alphabet, mode, detected_tier())
    }

    /// Engine pinned to a tier (clamped to host capability — forcing
    /// `avx512` on a host without VBMI falls down the ladder).
    pub fn with_tier(alphabet: Alphabet, tier: Tier) -> Engine {
        Self::with_tier_mode(alphabet, Mode::Strict, tier)
    }

    /// Full constructor: alphabet + mode + tier.
    pub fn with_tier_mode(alphabet: Alphabet, mode: Mode, tier: Tier) -> Engine {
        let mut tier = tier.clamp();
        // The 2018 AVX2 range arithmetic only fits range-structured
        // alphabets; fall through to SWAR otherwise (paper §5).
        if tier == Tier::Avx2 && !Avx2Codec::supports(&alphabet) {
            tier = Tier::Swar;
        }
        let block = BlockCodec::with_mode(alphabet.clone(), mode);
        let swar = matches!(tier, Tier::Swar)
            .then(|| SwarCodec::with_mode(alphabet.clone(), mode));
        let avx2 = matches!(tier, Tier::Avx2)
            .then(|| Avx2Codec::with_mode(alphabet.clone(), mode));
        let avx512 = matches!(tier, Tier::Avx512)
            .then(|| Avx512Codec::with_mode(alphabet.clone(), mode));
        Engine {
            kernels: kernels_for(tier),
            compact: compact_for(tier),
            policy: stores::default_policy(),
            nt_copy: stores::copy_for(tier),
            alphabet,
            mode,
            tier,
            block,
            swar,
            avx2,
            avx512,
        }
    }

    /// The tier this engine dispatches to.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// The store policy the non-`_policy` entry points resolve against.
    pub fn policy(&self) -> StorePolicy {
        self.policy
    }

    /// Override the engine's default store policy (the `_policy` entry
    /// points take a per-call policy instead and ignore this).
    pub fn set_policy(&mut self, policy: StorePolicy) {
        self.policy = policy;
    }

    /// The alphabet this engine encodes/decodes.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }

    /// The strictness mode decode applies.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Exact output size of [`Self::encode_slice`] for `n` input bytes.
    pub fn encoded_len(&self, n: usize) -> usize {
        encoded_len(n)
    }

    /// Exact output size of [`Self::decode_slice`] for this input
    /// (counts trailing padding; does not validate).
    pub fn decoded_len_of(&self, input: &[u8]) -> usize {
        let pad = self.alphabet.pad();
        let pads = input.iter().rev().take(2).take_while(|&&c| c == pad).count();
        decoded_len(input.len(), pads)
    }

    /// Encode `input` into `out[0..]`, returning the bytes written
    /// (always `encoded_len(input.len())`). Never allocates; panics if
    /// `out` is too small. Stores resolve through the engine's default
    /// [`StorePolicy`] — see [`Self::encode_slice_policy`].
    #[inline]
    pub fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        self.encode_slice_policy(input, out, self.policy)
    }

    /// [`Self::encode_slice`] with an explicit per-call store policy.
    /// Output is byte-identical under every policy; `NonTemporal` (or
    /// `Auto` above its threshold) routes the stores through an
    /// L1-resident staging block and the tier's streaming-store copy,
    /// keeping a >LLC output from round-tripping the cache hierarchy.
    pub fn encode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        policy: StorePolicy,
    ) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        if policy.use_nontemporal(input.len() + total) {
            self.encode_slice_nt(input, &mut out[..total]);
        } else {
            self.encode_slice_temporal(input, &mut out[..total]);
        }
        total
    }

    /// Temporal encode core (the pre-policy hot path): tier bulk kernel
    /// plus the scalar epilogue for the sub-granule remainder and the
    /// padded final quantum. `out.len() == encoded_len(input.len())`.
    fn encode_slice_temporal(&self, input: &[u8], out: &mut [u8]) {
        let consumed = (self.kernels.encode_bulk)(self, input, out);
        let w = consumed / 3 * 4;
        // Epilogue: the paper's conventional path for the sub-granule
        // remainder and the padded final quantum.
        self.block.encode_slice(&input[consumed..], &mut out[w..]);
    }

    /// Streaming-store encode: fill an L1-resident staging block with
    /// the temporal core, then move each batch to `out` with the tier's
    /// non-temporal line copy (head/tail peeled to whole aligned cache
    /// lines), prefetching the next batch's input meanwhile. One
    /// `sfence` at exit publishes the weakly-ordered stores.
    fn encode_slice_nt(&self, input: &[u8], out: &mut [u8]) {
        // Staged output chars per round: a multiple of B64_BLOCK, small
        // enough that staging + the live input window stay cache-resident.
        const STAGE_OUT: usize = 4096;
        const STAGE_RAW: usize = STAGE_OUT / 4 * 3;
        let mut stage = [0u8; STAGE_OUT];
        let (mut r, mut w) = (0usize, 0usize);
        loop {
            let take = STAGE_RAW.min(input.len() - r);
            self.prefetch_ahead(input, r + take);
            // Whole-3-byte-multiple batches encode without padding, so
            // the staged outputs concatenate exactly; only the final
            // (short) batch can carry '='.
            let produced = encoded_len(take);
            self.encode_slice_temporal(&input[r..r + take], &mut stage[..produced]);
            (self.nt_copy)(&mut out[w..w + produced], &stage[..produced]);
            r += take;
            w += produced;
            if r == input.len() {
                break;
            }
        }
        debug_assert_eq!(w, out.len());
        stores::fence();
    }

    /// Software-prefetch the input window the *next* staged batch will
    /// read (tier-scaled distance; no-op on the SWAR/scalar tiers and
    /// at end of input).
    #[inline]
    fn prefetch_ahead(&self, src: &[u8], from: usize) {
        let d = stores::prefetch_distance(self.tier);
        if d > 0 && from < src.len() {
            stores::prefetch_read(&src[from..(from + d).min(src.len())]);
        }
    }

    /// Decode `input` into `out[0..]`, returning the bytes written.
    /// `out` must hold `decoded_len_of(input)` bytes (or the
    /// `decoded_len_upper` bound). Never allocates; on error the
    /// contents of `out` are unspecified. Stores resolve through the
    /// engine's default [`StorePolicy`].
    #[inline]
    pub fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        self.decode_slice_policy(input, out, self.policy)
    }

    /// [`Self::decode_slice`] with an explicit per-call store policy.
    /// Output bytes *and* `DecodeError` offsets are identical under
    /// every policy (the staged batches are scanned in stream order, so
    /// the first invalid byte wins exactly as in the one-shot pass).
    pub fn decode_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let body_out = body.len() / 4 * 3;
        assert!(out.len() >= body_out, "output buffer too small");
        if policy.use_nontemporal(input.len() + body_out) {
            self.decode_span_nt(body, &mut out[..body_out], 0)?;
            let t = decode_tail_into(
                tail,
                self.alphabet.pad(),
                self.mode,
                body.len(),
                |c| self.alphabet.value_of(c),
                &mut out[body_out..],
            )?;
            return Ok(body_out + t);
        }
        let consumed = (self.kernels.decode_bulk)(self, body, &mut out[..body_out])?;
        let mut w = consumed / 4 * 3;
        w += decode_quads_into(
            &body[consumed..],
            self.alphabet.decode_table().as_bytes(),
            consumed,
            &mut out[w..body_out],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }

    /// Decode a whole-quantum span through an L1 staging buffer, moving
    /// each staged batch to `out` with the tier's non-temporal line
    /// copy and prefetching the next batch's chars. Error offsets are
    /// rebased by `base`. Issues the contract `sfence` before returning
    /// — on success *and* error, and on the calling thread, so the
    /// parallel paths fence each worker's stores before the scope joins.
    fn decode_span_nt(&self, span: &[u8], out: &mut [u8], base: usize) -> Result<(), DecodeError> {
        const STAGE_B64: usize = 4096;
        const STAGE_RAW: usize = STAGE_B64 / 4 * 3;
        debug_assert_eq!(span.len() % 4, 0);
        let mut stage = [0u8; STAGE_RAW];
        let mut run = || -> Result<(), DecodeError> {
            let (mut r, mut w) = (0usize, 0usize);
            while r < span.len() {
                let take = STAGE_B64.min(span.len() - r);
                self.prefetch_ahead(span, r + take);
                let produced = take / 4 * 3;
                self.decode_span(&span[r..r + take], &mut stage[..produced], base + r)?;
                (self.nt_copy)(&mut out[w..w + produced], &stage[..produced]);
                r += take;
                w += produced;
            }
            Ok(())
        };
        let res = run();
        stores::fence();
        res
    }

    /// Exact output size of [`Self::encode_wrapped_slice`] for `n` input
    /// bytes at `line_len` characters per line. Panics on the same
    /// `line_len` values `encode_wrapped_slice` rejects, so a sizing
    /// mistake surfaces here rather than as a wrong buffer length.
    pub fn encoded_wrapped_len(&self, n: usize, line_len: usize) -> usize {
        assert!(
            line_len >= 4 && line_len % 4 == 0,
            "line length must be a positive multiple of 4"
        );
        let flat = encoded_len(n);
        if flat == 0 {
            0
        } else {
            flat + (flat - 1) / line_len * 2
        }
    }

    /// Encode `input` as CRLF-wrapped base64 (RFC 2045 style) into
    /// `out[0..]`, returning the bytes written. `line_len` must be a
    /// positive multiple of 4; the final line carries no trailing CRLF.
    ///
    /// The CRLFs are written inline as each line's characters are stored
    /// — there is no flat-encode-then-recopy pass, and nothing is
    /// allocated. Each full line is a whole number of 3-byte groups, so
    /// every line but the last runs the tier's bulk kernel with a short
    /// scalar epilogue and no padding.
    pub fn encode_wrapped_slice(&self, input: &[u8], out: &mut [u8], line_len: usize) -> usize {
        self.encode_wrapped_slice_policy(input, out, line_len, self.policy)
    }

    /// [`Self::encode_wrapped_slice`] with an explicit per-call store
    /// policy. Under the non-temporal path whole line groups (base64
    /// chars *and* their CRLFs) are composed in an L1 staging block and
    /// streamed out together; output is byte-identical either way.
    /// Degenerate line lengths that cannot fit the staging block fall
    /// back to the temporal path.
    pub fn encode_wrapped_slice_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        line_len: usize,
        policy: StorePolicy,
    ) -> usize {
        assert!(
            line_len >= 4 && line_len % 4 == 0,
            "line length must be a positive multiple of 4"
        );
        let total = self.encoded_wrapped_len(input.len(), line_len);
        assert!(out.len() >= total, "output buffer too small");
        const WRAP_STAGE: usize = 4096;
        if total == 0
            || line_len + 2 > WRAP_STAGE
            || !policy.use_nontemporal(input.len() + total)
        {
            return self.encode_wrapped_temporal(input, out, line_len, total);
        }
        let raw_per_line = line_len / 4 * 3;
        let lines_per_stage = WRAP_STAGE / (line_len + 2); // >= 1 by the guard above
        let mut stage = [0u8; WRAP_STAGE];
        let (mut r, mut w) = (0usize, 0usize);
        let mut done = false;
        while !done {
            let mut s = 0usize;
            for _ in 0..lines_per_stage {
                if input.len() - r > raw_per_line {
                    self.encode_slice_temporal(
                        &input[r..r + raw_per_line],
                        &mut stage[s..s + line_len],
                    );
                    r += raw_per_line;
                    s += line_len;
                    stage[s] = b'\r';
                    stage[s + 1] = b'\n';
                    s += 2;
                } else {
                    // Final line: no trailing CRLF, possibly padded.
                    let last = encoded_len(input.len() - r);
                    self.encode_slice_temporal(&input[r..], &mut stage[s..s + last]);
                    s += last;
                    r = input.len();
                    done = true;
                    break;
                }
            }
            self.prefetch_ahead(input, r);
            (self.nt_copy)(&mut out[w..w + s], &stage[..s]);
            w += s;
        }
        debug_assert_eq!(w, total);
        stores::fence();
        total
    }

    /// Temporal wrapped encode (the pre-policy path): CRLFs written
    /// inline as each line's characters are stored.
    fn encode_wrapped_temporal(
        &self,
        input: &[u8],
        out: &mut [u8],
        line_len: usize,
        total: usize,
    ) -> usize {
        let raw_per_line = line_len / 4 * 3;
        let (mut r, mut w) = (0usize, 0usize);
        while input.len() - r > raw_per_line {
            self.encode_slice_temporal(&input[r..r + raw_per_line], &mut out[w..w + line_len]);
            r += raw_per_line;
            w += line_len;
            out[w] = b'\r';
            out[w + 1] = b'\n';
            w += 2;
        }
        let last = encoded_len(input.len() - r);
        self.encode_slice_temporal(&input[r..], &mut out[w..w + last]);
        w += last;
        debug_assert_eq!(w, total);
        w
    }

    /// Decode `input` into `out[0..]`, skipping the bytes `ws` names,
    /// and return the bytes written. This is the fused single-pass MIME
    /// decode: whitespace is compacted into an on-stack staging block by
    /// the tier's compaction kernel (`vpcompressb` / AVX2 movemask /
    /// SWAR) and the staged characters run the same bulk decode kernels
    /// as [`Self::decode_slice`] — no allocation, no separate strip pass.
    ///
    /// Error offsets refer to the **original** input (not the stripped
    /// stream); `InvalidLength` counts significant characters. When the
    /// input carries several independent defects (say, a stray byte *and*
    /// a bad total length), the fused pass may report a different — but
    /// still genuine — one than a strip-then-decode pass would, because
    /// it cannot know the final length while blocks are still streaming.
    pub fn decode_slice_ws(
        &self,
        input: &[u8],
        out: &mut [u8],
        ws: Whitespace,
    ) -> Result<usize, DecodeError> {
        self.decode_slice_ws_policy(input, out, ws, self.policy)
    }

    /// [`Self::decode_slice_ws`] with an explicit per-call store policy.
    /// Under the non-temporal path each staged batch decodes into a raw
    /// staging block and streams to `out`; output bytes and error
    /// offsets are identical under every policy. The contract `sfence`
    /// is issued once before returning (also on the error path).
    pub fn decode_slice_ws_policy(
        &self,
        input: &[u8],
        out: &mut [u8],
        ws: Whitespace,
        policy: StorePolicy,
    ) -> Result<usize, DecodeError> {
        if ws == Whitespace::None {
            return self.decode_slice_policy(input, out, policy);
        }
        // Upper-bound working set: every input byte significant.
        let nt = policy.use_nontemporal(input.len() + input.len() / 4 * 3);
        let res = self
            .decode_ws_inner(input, out, ws, nt)
            .map_err(|e| rebase_ws_error(e, input, ws));
        if nt {
            stores::fence();
        }
        res
    }

    /// Fused decode core; error offsets are in *stripped* coordinates
    /// (the public wrapper rebases them onto the original input).
    fn decode_ws_inner(
        &self,
        input: &[u8],
        out: &mut [u8],
        ws: Whitespace,
        nt: bool,
    ) -> Result<usize, DecodeError> {
        // Staging block: 16 decode blocks (1 KiB) on the stack — big
        // enough to amortize the kernel call, small enough to stay in L1.
        const STAGE: usize = 16 * B64_BLOCK;
        let mut stage = [0u8; STAGE];
        // Raw-output staging for the NT path, allocated once beside the
        // char stage so the per-batch helper does not re-zero it.
        let mut raw = [0u8; 16 * RAW_BLOCK];
        let mut staged = 0usize; // valid chars in `stage`
        let mut pos = 0usize; // input cursor
        let mut base = 0usize; // stripped chars already decoded
        let mut w = 0usize; // bytes written to `out`
        loop {
            let (consumed, filled) = (self.compact)(&input[pos..], &mut stage[staged..], ws);
            pos += consumed;
            staged += filled;
            if pos == input.len() {
                break;
            }
            // The stage is full and input remains. Decode all but the
            // last block: the held-back chars cover the stream's final
            // (possibly padded) quantum, which must go through the tail
            // path below, and keep every bulk call block-aligned.
            debug_assert_eq!(staged, STAGE);
            let body = STAGE - B64_BLOCK;
            w += self.decode_ws_batch_policy(&stage[..body], &mut out[w..], base, nt, &mut raw)?;
            base += body;
            stage.copy_within(body..STAGE, 0);
            staged = B64_BLOCK;
        }
        // Final batch: apply the stream-level length/padding semantics.
        let total = base + staged;
        if self.mode == Mode::Strict && total % 4 != 0 {
            return Err(DecodeError::InvalidLength { len: total });
        }
        let (body, tail) = split_tail(&stage[..staged], self.alphabet.pad(), self.mode)
            .map_err(|e| match e {
                // split_tail only sees the residue; report the full count.
                DecodeError::InvalidLength { .. } => DecodeError::InvalidLength { len: total },
                other => other,
            })?;
        w += self.decode_ws_batch_policy(body, &mut out[w..], base, nt, &mut raw)?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            base + body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }

    /// [`Self::decode_ws_batch`] behind the store policy: the temporal
    /// path decodes straight into `out`; the non-temporal path decodes
    /// into the caller's raw staging block (sized for the 1 KiB char
    /// stage, zeroed once per stream) and streams it to `out` (no fence
    /// here — the top-level entry point fences once at exit).
    fn decode_ws_batch_policy(
        &self,
        body: &[u8],
        out: &mut [u8],
        base: usize,
        nt: bool,
        raw: &mut [u8; 16 * RAW_BLOCK],
    ) -> Result<usize, DecodeError> {
        if !nt {
            return self.decode_ws_batch(body, out, base);
        }
        let n = body.len() / 4 * 3;
        debug_assert!(n <= raw.len());
        self.decode_ws_batch(body, &mut raw[..n], base)?;
        assert!(out.len() >= n, "output buffer too small");
        (self.nt_copy)(&mut out[..n], &raw[..n]);
        Ok(n)
    }

    /// Decode a staged whole-quantum span (no padding) through the tier
    /// kernels; errors are offset by `base` (stripped coordinates).
    fn decode_ws_batch(
        &self,
        body: &[u8],
        out: &mut [u8],
        base: usize,
    ) -> Result<usize, DecodeError> {
        debug_assert_eq!(body.len() % 4, 0);
        let body_out = body.len() / 4 * 3;
        assert!(out.len() >= body_out, "output buffer too small");
        let out = &mut out[..body_out];
        let consumed =
            (self.kernels.decode_bulk)(self, body, out).map_err(|e| rebase(e, base))?;
        let w = consumed / 4 * 3;
        decode_quads_into(
            &body[consumed..],
            self.alphabet.decode_table().as_bytes(),
            base + consumed,
            &mut out[w..],
        )?;
        Ok(body_out)
    }

    /// Decode whole 4-char quanta (no padding expected) from `body`,
    /// appending to `out`; `out` is restored on error. Errors are
    /// relative to `body`. This is the bulk step the tiered streaming
    /// decoder drives between carry refills; the engine's `Auto` store
    /// policy applies, so a single huge streamed chunk bypasses the
    /// cache hierarchy like the one-shot path would.
    pub(crate) fn decode_quanta_into(
        &self,
        body: &[u8],
        out: &mut Vec<u8>,
    ) -> Result<(), DecodeError> {
        debug_assert_eq!(body.len() % 4, 0);
        let body_out = body.len() / 4 * 3;
        let start = out.len();
        out.resize(start + body_out, 0);
        let res = if self.policy.use_nontemporal(body.len() + body_out) {
            self.decode_span_nt(body, &mut out[start..], 0)
        } else {
            self.decode_ws_batch(body, &mut out[start..], 0).map(|_| ())
        };
        match res {
            Ok(()) => Ok(()),
            Err(e) => {
                out.truncate(start);
                Err(e)
            }
        }
    }

    /// Chunked multi-threaded encode for large payloads: splits the
    /// input on 48-byte block boundaries across `threads` scoped threads
    /// (0 = one per available core, capped at 8). Falls back to the
    /// single-threaded path below [`PAR_THRESHOLD`]. Output is
    /// byte-identical to [`Self::encode_slice`].
    pub fn encode_par(&self, input: &[u8], out: &mut [u8], threads: usize) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let threads = effective_threads(threads);
        if threads < 2 || input.len() < PAR_THRESHOLD {
            return self.encode_slice(input, out);
        }
        let blocks = input.len() / RAW_BLOCK;
        let span = blocks.div_ceil(threads) * RAW_BLOCK; // raw bytes per thread
        let bulk = blocks * RAW_BLOCK;
        let (bulk_in, tail_in) = input.split_at(bulk);
        let (bulk_out, tail_out) = out[..total].split_at_mut(bulk / 3 * 4);
        // Resolve the store policy once against the *whole* payload, so
        // the chunk policy does not depend on the thread count; each
        // worker's NT entry point fences its own stores before the scope
        // joins (the stores.rs contract).
        let chunk_policy = if self.policy.use_nontemporal(input.len() + total) {
            StorePolicy::NonTemporal
        } else {
            StorePolicy::Temporal
        };
        std::thread::scope(|s| {
            let mut rest_in = bulk_in;
            let mut rest_out = &mut bulk_out[..];
            while !rest_in.is_empty() {
                let n = span.min(rest_in.len());
                let (chunk_in, next_in) = rest_in.split_at(n);
                let (chunk_out, next_out) = std::mem::take(&mut rest_out).split_at_mut(n / 3 * 4);
                rest_in = next_in;
                rest_out = next_out;
                // Whole-block spans encode with no padding, so the
                // per-span outputs concatenate exactly.
                s.spawn(move || self.encode_slice_policy(chunk_in, chunk_out, chunk_policy));
            }
        });
        // The sub-block remainder (with padding) runs on this thread.
        self.block.encode_slice(tail_in, tail_out);
        total
    }

    /// Chunked multi-threaded decode: splits the whole-quantum body on
    /// 64-char block boundaries across scoped threads; the sub-block
    /// remainder and padded tail decode on the calling thread. Output
    /// and error reporting are byte-identical to [`Self::decode_slice`]
    /// except that when *multiple* spans contain invalid bytes the
    /// reported offset is the smallest among the failing spans' first
    /// errors (still always a genuinely invalid byte).
    pub fn decode_par(
        &self,
        input: &[u8],
        out: &mut [u8],
        threads: usize,
    ) -> Result<usize, DecodeError> {
        let threads = effective_threads(threads);
        if threads < 2 || input.len() < PAR_THRESHOLD {
            return self.decode_slice(input, out);
        }
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let body_out = body.len() / 4 * 3;
        assert!(out.len() >= body_out, "output buffer too small");
        let blocks = body.len() / B64_BLOCK;
        let span = blocks.div_ceil(threads) * B64_BLOCK; // chars per thread
        let bulk = blocks * B64_BLOCK;
        // Whole-payload policy resolution, as in `encode_par`; NT spans
        // fence on their own worker thread inside `decode_span_nt`.
        let nt = self.policy.use_nontemporal(input.len() + body_out);
        let first_err = std::sync::Mutex::new(None::<DecodeError>);
        std::thread::scope(|s| {
            let mut rest_in = &body[..bulk];
            let mut rest_out = &mut out[..bulk / 4 * 3];
            let mut base = 0usize;
            while !rest_in.is_empty() {
                let n = span.min(rest_in.len());
                let (chunk_in, next_in) = rest_in.split_at(n);
                let (chunk_out, next_out) = std::mem::take(&mut rest_out).split_at_mut(n / 4 * 3);
                rest_in = next_in;
                rest_out = next_out;
                let first_err = &first_err;
                let chunk_base = base;
                base += n;
                s.spawn(move || {
                    let r = if nt {
                        self.decode_span_nt(chunk_in, chunk_out, chunk_base)
                    } else {
                        self.decode_span(chunk_in, chunk_out, chunk_base)
                    };
                    if let Err(e) = r {
                        let mut slot = first_err.lock().unwrap();
                        let replace = match (&*slot, &e) {
                            (None, _) => true,
                            (
                                Some(DecodeError::InvalidByte { offset: prev, .. }),
                                DecodeError::InvalidByte { offset: new, .. },
                            ) => new < prev,
                            _ => false,
                        };
                        if replace {
                            *slot = Some(e);
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        // Sub-block remainder + tail on the calling thread.
        let mut w = bulk / 4 * 3;
        w += decode_quads_into(
            &body[bulk..],
            self.alphabet.decode_table().as_bytes(),
            bulk,
            &mut out[w..body_out],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }

    /// Decode one whole-quantum span (no padding) with offsets rebased
    /// to the original input.
    fn decode_span(&self, span: &[u8], out: &mut [u8], base: usize) -> Result<(), DecodeError> {
        let consumed = (self.kernels.decode_bulk)(self, span, out).map_err(|e| rebase(e, base))?;
        let w = consumed / 4 * 3;
        decode_quads_into(
            &span[consumed..],
            self.alphabet.decode_table().as_bytes(),
            base + consumed,
            &mut out[w..],
        )?;
        Ok(())
    }
}

/// Shift a span-relative error to absolute input coordinates.
fn rebase(e: DecodeError, base: usize) -> DecodeError {
    e.map_offset(|offset| base + offset)
}

fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    }
}

impl Codec for Engine {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        Engine::encode_slice(self, input, out)
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        Engine::decode_slice(self, input, out)
    }

    fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        // Exact-size allocation via the padding-aware length helper. The
        // helper over-counts for degenerate forgiving-mode inputs (3+
        // trailing pads), so trim to what was actually written.
        let mut out = vec![0u8; self.decoded_len_of(input)];
        let n = Engine::decode_slice(self, input, &mut out)?;
        out.truncate(n);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;
    use crate::workload::random_bytes;

    #[test]
    fn tier_ladder_clamps_to_host() {
        for t in [Tier::Avx512, Tier::Avx2, Tier::Swar, Tier::Scalar] {
            assert!(t.clamp().available());
        }
        assert_eq!(Tier::Scalar.clamp(), Tier::Scalar);
        assert!(Tier::supported().contains(&Tier::Swar));
        assert!(Tier::supported().contains(&Tier::Scalar));
    }

    #[test]
    fn tier_parse_names() {
        assert_eq!(Tier::parse("avx512"), Some(Tier::Avx512));
        assert_eq!(Tier::parse("swar"), Some(Tier::Swar));
        assert_eq!(Tier::parse("block"), Some(Tier::Scalar));
        assert_eq!(Tier::parse("mmx"), None);
    }

    #[test]
    fn get_is_cached_and_usable() {
        let e1 = Engine::get();
        let e2 = Engine::get();
        assert!(std::ptr::eq(e1, e2), "Engine::get must cache");
        assert_eq!(e1.tier(), detected_tier());
        let mut out = [0u8; 8];
        assert_eq!(e1.encode_slice(b"foobar", &mut out), 8);
        assert_eq!(&out, b"Zm9vYmFy");
    }

    #[test]
    fn slice_roundtrip_every_supported_tier() {
        let oracle = ScalarCodec::new(Alphabet::standard());
        for tier in Tier::supported() {
            let e = Engine::with_tier(Alphabet::standard(), tier);
            assert_eq!(e.tier(), tier);
            for len in [0usize, 1, 2, 3, 23, 24, 47, 48, 49, 200, 1000] {
                let data = random_bytes(len, len as u64);
                let mut enc = vec![0u8; e.encoded_len(len)];
                let n = e.encode_slice(&data, &mut enc);
                assert_eq!(&enc[..n], &oracle.encode(&data)[..], "{tier:?} len={len}");
                let mut dec = vec![0u8; e.decoded_len_of(&enc[..n])];
                let m = e.decode_slice(&enc[..n], &mut dec).unwrap();
                assert_eq!(&dec[..m], &data[..], "{tier:?} len={len}");
            }
        }
    }

    #[test]
    fn url_alphabet_on_avx2_tier_falls_back() {
        if !Tier::Avx2.available() {
            eprintln!("skipping: no AVX2");
            return;
        }
        let e = Engine::with_tier(Alphabet::url(), Tier::Avx2);
        assert_eq!(e.tier(), Tier::Swar, "url lacks the 2018 range structure");
        let data = random_bytes(100, 9);
        assert_eq!(e.decode(&e.encode(&data)).unwrap(), data);
    }

    #[test]
    fn decode_errors_match_scalar_offsets() {
        let oracle = ScalarCodec::new(Alphabet::standard());
        for tier in Tier::supported() {
            let e = Engine::with_tier(Alphabet::standard(), tier);
            let mut enc = e.encode(&random_bytes(300, 3));
            for pos in [0usize, 63, 64, 250] {
                let orig = enc[pos];
                enc[pos] = b'!';
                let want = oracle.decode(&enc).unwrap_err();
                let mut out = vec![0u8; e.decoded_len_of(&enc)];
                let got = e.decode_slice(&enc, &mut out).unwrap_err();
                assert_eq!(got, want, "{tier:?} pos={pos}");
                enc[pos] = orig;
            }
        }
    }

    #[test]
    fn par_paths_match_serial() {
        let e = Engine::get();
        // Cross the PAR_THRESHOLD so the scoped-thread path actually runs.
        let data = random_bytes(PAR_THRESHOLD + 12345, 7);
        let mut serial = vec![0u8; e.encoded_len(data.len())];
        let mut par = vec![0u8; e.encoded_len(data.len())];
        e.encode_slice(&data, &mut serial);
        let n = e.encode_par(&data, &mut par, 4);
        assert_eq!(n, serial.len());
        assert_eq!(par, serial);
        let mut dec = vec![0u8; e.decoded_len_of(&par)];
        let m = e.decode_par(&par, &mut dec, 4).unwrap();
        assert_eq!(&dec[..m], &data[..]);
    }

    #[test]
    fn par_decode_reports_errors() {
        let e = Engine::get();
        let data = random_bytes(PAR_THRESHOLD + 999, 11);
        let mut enc = e.encode(&data);
        let n = enc.len();
        enc[n / 2] = 0x07;
        let mut out = vec![0u8; e.decoded_len_of(&enc)];
        match e.decode_par(&enc, &mut out, 4) {
            Err(DecodeError::InvalidByte { offset, byte: 0x07 }) => assert_eq!(offset, n / 2),
            other => panic!("expected invalid byte, got {other:?}"),
        }
    }

    #[test]
    fn wrapped_encode_matches_manual_wrap() {
        let e = Engine::get();
        for (len, line_len) in [(0usize, 76usize), (1, 4), (57, 76), (58, 76), (200, 60), (4096, 76)] {
            let data = random_bytes(len, len as u64 + 1);
            let flat = e.encode(&data);
            let mut want = Vec::new();
            for (i, line) in flat.chunks(line_len).enumerate() {
                if i > 0 {
                    want.extend_from_slice(b"\r\n");
                }
                want.extend_from_slice(line);
            }
            let mut out = vec![0u8; e.encoded_wrapped_len(len, line_len)];
            let n = e.encode_wrapped_slice(&data, &mut out, line_len);
            assert_eq!(n, out.len(), "len={len} line={line_len}");
            assert_eq!(out, want, "len={len} line={line_len}");
        }
    }

    #[test]
    fn fused_ws_decode_roundtrips_wrapped_input() {
        for tier in Tier::supported() {
            let e = Engine::with_tier(Alphabet::standard(), tier);
            for len in [0usize, 1, 2, 3, 56, 57, 58, 100, 1000, 5000] {
                let data = random_bytes(len, 31 + len as u64);
                let mut wrapped = vec![0u8; e.encoded_wrapped_len(len, 76)];
                e.encode_wrapped_slice(&data, &mut wrapped, 76);
                let mut out = vec![0u8; super::super::decoded_len_upper(wrapped.len())];
                let n = e.decode_slice_ws(&wrapped, &mut out, Whitespace::CrLf).unwrap();
                assert_eq!(&out[..n], &data[..], "{tier:?} len={len}");
            }
        }
    }

    #[test]
    fn fused_ws_decode_reports_original_offsets() {
        let e = Engine::get();
        // "Zm9v\r\n!mFy": the '!' sits at stripped offset 4 but original
        // offset 6.
        let mut out = vec![0u8; 16];
        let err = e
            .decode_slice_ws(b"Zm9v\r\n!mFy", &mut out, Whitespace::CrLf)
            .unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 6, byte: b'!' });
        // Space rejected under CrLf, skipped under All.
        let err = e
            .decode_slice_ws(b"Zm9v YmFy\r\n", &mut out, Whitespace::CrLf)
            .unwrap_err();
        assert!(matches!(err, DecodeError::InvalidLength { len: 9 }), "{err:?}");
        let n = e
            .decode_slice_ws(b"Zm9v YmFy\r\n", &mut out, Whitespace::All)
            .unwrap();
        assert_eq!(&out[..n], b"foobar");
    }

    #[test]
    fn fused_ws_decode_all_whitespace_input() {
        let e = Engine::get();
        let mut out = [0u8; 4];
        assert_eq!(e.decode_slice_ws(b"\r\n\r\n", &mut out, Whitespace::CrLf), Ok(0));
        assert_eq!(e.decode_slice_ws(b"", &mut out, Whitespace::CrLf), Ok(0));
    }

    #[test]
    fn store_policies_produce_identical_bytes_and_errors() {
        // Cross the staging peel edges (cache line, stage, 4 KiB) on the
        // detected tier; the full tier × policy matrix lives in
        // rust/tests/stores.rs.
        let e = Engine::get();
        assert_eq!(e.policy(), super::stores::default_policy());
        for len in [0usize, 1, 63, 64, 65, 3071, 3072, 3073, 4095, 4096, 4097, 20_000] {
            let data = random_bytes(len, 0x57D0 + len as u64);
            let mut a = vec![0u8; e.encoded_len(len)];
            let mut b = vec![0u8; e.encoded_len(len)];
            e.encode_slice_policy(&data, &mut a, StorePolicy::Temporal);
            e.encode_slice_policy(&data, &mut b, StorePolicy::NonTemporal);
            assert_eq!(a, b, "encode len={len}");
            let mut da = vec![0u8; e.decoded_len_of(&a)];
            let mut db = vec![0u8; e.decoded_len_of(&b)];
            let na = e.decode_slice_policy(&a, &mut da, StorePolicy::Temporal).unwrap();
            let nb = e.decode_slice_policy(&b, &mut db, StorePolicy::NonTemporal).unwrap();
            assert_eq!((na, &da[..na]), (nb, &db[..nb]), "decode len={len}");
            assert_eq!(&da[..na], &data[..], "roundtrip len={len}");
        }
        // Identical error offsets through the NT staging seams.
        let mut enc = e.encode(&random_bytes(9000, 3));
        for pos in [0usize, 3071, 3072, 4095, 4096, 11_000] {
            let orig = enc[pos];
            enc[pos] = b'!';
            let mut out = vec![0u8; e.decoded_len_of(&enc)];
            let want = e.decode_slice_policy(&enc, &mut out, StorePolicy::Temporal).unwrap_err();
            let got = e.decode_slice_policy(&enc, &mut out, StorePolicy::NonTemporal).unwrap_err();
            assert_eq!(got, want, "pos={pos}");
            assert_eq!(got, DecodeError::InvalidByte { offset: pos, byte: b'!' });
            enc[pos] = orig;
        }
    }

    #[test]
    fn auto_policy_flips_at_its_threshold() {
        let e = Engine::get();
        // A threshold small enough that both sides are cheap to test.
        let policy = StorePolicy::Auto(8192);
        for len in [1000usize, 3000, 4000, 9000] {
            let data = random_bytes(len, len as u64);
            let mut auto_out = vec![0u8; e.encoded_len(len)];
            let mut temporal = vec![0u8; e.encoded_len(len)];
            e.encode_slice_policy(&data, &mut auto_out, policy);
            e.encode_slice_policy(&data, &mut temporal, StorePolicy::Temporal);
            assert_eq!(auto_out, temporal, "len={len}");
        }
        assert!(!policy.use_nontemporal(8192));
        assert!(policy.use_nontemporal(8193));
    }

    #[test]
    fn engine_vec_wrappers() {
        let e = Engine::get();
        assert_eq!(e.encode(b"foobar"), b"Zm9vYmFy");
        assert_eq!(e.decode(b"Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(e.decode(b"Zg==").unwrap(), b"f");
        assert!(e.decode(b"Zg=!").is_err());
    }

    #[test]
    fn decoded_len_of_counts_padding() {
        let e = Engine::get();
        assert_eq!(e.decoded_len_of(b""), 0);
        assert_eq!(e.decoded_len_of(b"Zg=="), 1);
        assert_eq!(e.decoded_len_of(b"Zm8="), 2);
        assert_eq!(e.decoded_len_of(b"Zm9v"), 3);
        assert_eq!(e.decoded_len_of(b"Zm8"), 2); // forgiving unpadded
    }
}
