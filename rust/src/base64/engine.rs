//! Zero-allocation engine with tiered runtime dispatch — the facade the
//! rest of the system encodes and decodes through.
//!
//! The paper's headline claim (base64 at almost the speed of `memcpy`)
//! only survives if the surrounding code adds no memory traffic of its
//! own. This module removes the two hot-path taxes the `Vec`-returning
//! codec API carried:
//!
//! * **allocation** — [`Engine::encode_slice`] / [`Engine::decode_slice`]
//!   write into caller-provided buffers and never touch the heap
//!   (asserted by the counting-allocator test in `rust/tests/alloc.rs`);
//! * **dynamic dispatch** — CPU feature detection runs exactly once
//!   (cached in a [`OnceLock`]) and the chosen tier's kernels are held as
//!   plain function pointers, not `Box<dyn Codec>` vtables.
//!
//! ## Tier selection
//!
//! Detection order, best first (the middle tiers are the ones both Muła &
//! Lemire papers treat as essential):
//!
//! 1. [`Tier::Avx512`] — `avx512f + avx512bw + avx512vbmi`: the paper's
//!    §3 instruction sequence ([`Avx512Codec`]);
//! 2. [`Tier::Avx2`] — the 2018 AVX2 codec ([`Avx2Codec`]); only used
//!    for alphabets with the 2018 range structure (base64url falls
//!    through to SWAR — exactly the versatility gap §5 describes);
//! 3. [`Tier::Swar`] — the wide-table u32 codec ([`SwarCodec`]);
//! 4. [`Tier::Scalar`] — the scalar block codec ([`BlockCodec`]), the
//!    portable floor (forced only; SWAR beats it everywhere).
//!
//! Set `B64SIMD_TIER=avx512|avx2|swar|scalar` to force a tier (clamped
//! to what the host supports), or construct one explicitly with
//! [`Engine::with_tier`].
//!
//! ## Parallel path
//!
//! For payloads larger than a core's L2 a single stream is memory-bound;
//! base64 is embarrassingly parallel on 48/64-byte boundaries, so
//! [`Engine::encode_par`] / [`Engine::decode_par`] split the input on
//! block boundaries across scoped threads and push aggregate throughput
//! past a single core's memcpy ceiling.

use std::sync::OnceLock;

use super::avx2::Avx2Codec;
use super::avx512::Avx512Codec;
use super::block::BlockCodec;
use super::swar::SwarCodec;
use super::validate::{decode_quads_into, decode_tail_into, split_tail};
use super::{decoded_len, encoded_len, Alphabet, Codec, DecodeError, Mode, B64_BLOCK, RAW_BLOCK};

/// Inputs below this many bytes stay single-threaded in the `_par` paths
/// (roughly an L2 capacity: smaller payloads are compute- or
/// cache-resident and forking threads only adds latency).
pub const PAR_THRESHOLD: usize = 1 << 20;

/// One of the engine's dispatch tiers, best first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// The paper's §3 AVX-512 VBMI instruction sequence.
    Avx512,
    /// The 2018 AVX2 codec (standard-structure alphabets only).
    Avx2,
    /// Wide-table SWAR on plain u32/u64 registers.
    Swar,
    /// The scalar block codec — the portable floor.
    Scalar,
}

impl Tier {
    /// Benchmark/series label.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx512 => "avx512",
            Tier::Avx2 => "avx2",
            Tier::Swar => "swar",
            Tier::Scalar => "scalar",
        }
    }

    /// Parse a tier name (the `B64SIMD_TIER` env values).
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "avx512" => Some(Tier::Avx512),
            "avx2" => Some(Tier::Avx2),
            "swar" => Some(Tier::Swar),
            "scalar" | "block" => Some(Tier::Scalar),
            _ => None,
        }
    }

    /// True iff the host CPU can run this tier.
    pub fn available(self) -> bool {
        match self {
            Tier::Avx512 => Avx512Codec::available(),
            Tier::Avx2 => Avx2Codec::available(),
            Tier::Swar | Tier::Scalar => true,
        }
    }

    /// Every tier the host supports, best first.
    pub fn supported() -> Vec<Tier> {
        [Tier::Avx512, Tier::Avx2, Tier::Swar, Tier::Scalar]
            .into_iter()
            .filter(|t| t.available())
            .collect()
    }

    /// The next tier down the ladder (used to clamp forced tiers).
    fn fallback(self) -> Tier {
        match self {
            Tier::Avx512 => Tier::Avx2,
            Tier::Avx2 => Tier::Swar,
            Tier::Swar | Tier::Scalar => Tier::Scalar,
        }
    }

    /// Clamp to host capability: walk down until a tier is available.
    fn clamp(mut self) -> Tier {
        while !self.available() {
            self = self.fallback();
        }
        self
    }
}

/// One-time tier detection: CPUID probes (plus the `B64SIMD_TIER`
/// override) run on first call, the answer is cached for the process.
pub fn detected_tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(|| {
        if let Ok(forced) = std::env::var("B64SIMD_TIER") {
            if let Some(t) = Tier::parse(&forced) {
                return t.clamp();
            }
            eprintln!("b64simd: ignoring unknown B64SIMD_TIER value '{forced}'");
        }
        if Avx512Codec::available() {
            Tier::Avx512
        } else if Avx2Codec::available() {
            Tier::Avx2
        } else {
            Tier::Swar
        }
    })
}

/// The tier kernels as plain function pointers — the flat facade that
/// replaces `Box<dyn Codec>` dispatch on the hot path. Both pointers
/// follow the bulk contract: consume a whole-granule prefix of the
/// input, write its exact output at `out[0..]`, return bytes consumed.
#[derive(Clone, Copy)]
struct Kernels {
    encode_bulk: fn(&Engine, &[u8], &mut [u8]) -> usize,
    decode_bulk: fn(&Engine, &[u8], &mut [u8]) -> Result<usize, DecodeError>,
}

fn enc_avx512(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.avx512.as_ref().expect("avx512 tier state").encode_bulk(input, out)
}

fn dec_avx512(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.avx512.as_ref().expect("avx512 tier state").decode_bulk(input, out)
}

fn enc_avx2(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.avx2.as_ref().expect("avx2 tier state").encode_bulk(input, out)
}

fn dec_avx2(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.avx2.as_ref().expect("avx2 tier state").decode_bulk(input, out)
}

fn enc_swar(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.swar.as_ref().expect("swar tier state").encode_bulk(input, out)
}

fn dec_swar(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.swar.as_ref().expect("swar tier state").decode_bulk(input, out)
}

fn enc_scalar(e: &Engine, input: &[u8], out: &mut [u8]) -> usize {
    e.block.encode_bulk(input, out)
}

fn dec_scalar(e: &Engine, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
    e.block.decode_bulk(input, out)
}

fn kernels_for(tier: Tier) -> Kernels {
    match tier {
        Tier::Avx512 => Kernels { encode_bulk: enc_avx512, decode_bulk: dec_avx512 },
        Tier::Avx2 => Kernels { encode_bulk: enc_avx2, decode_bulk: dec_avx2 },
        Tier::Swar => Kernels { encode_bulk: enc_swar, decode_bulk: dec_swar },
        Tier::Scalar => Kernels { encode_bulk: enc_scalar, decode_bulk: dec_scalar },
    }
}

/// The allocation-free, tier-dispatched codec facade.
pub struct Engine {
    alphabet: Alphabet,
    mode: Mode,
    tier: Tier,
    kernels: Kernels,
    /// Scalar block codec: the epilogue/tail path of every tier and the
    /// bulk path of [`Tier::Scalar`].
    block: BlockCodec,
    swar: Option<SwarCodec>,
    avx2: Option<Avx2Codec>,
    avx512: Option<Avx512Codec>,
}

impl Engine {
    /// The process-wide engine: standard alphabet, strict mode, best
    /// tier. Detection and table construction run exactly once.
    pub fn get() -> &'static Engine {
        static ENGINE: OnceLock<Engine> = OnceLock::new();
        ENGINE.get_or_init(|| Engine::new(Alphabet::standard()))
    }

    /// Engine for an alphabet at the host's best tier, strict mode.
    pub fn new(alphabet: Alphabet) -> Engine {
        Self::with_tier_mode(alphabet, Mode::Strict, detected_tier())
    }

    /// Engine with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Engine {
        Self::with_tier_mode(alphabet, mode, detected_tier())
    }

    /// Engine pinned to a tier (clamped to host capability — forcing
    /// `avx512` on a host without VBMI falls down the ladder).
    pub fn with_tier(alphabet: Alphabet, tier: Tier) -> Engine {
        Self::with_tier_mode(alphabet, Mode::Strict, tier)
    }

    /// Full constructor: alphabet + mode + tier.
    pub fn with_tier_mode(alphabet: Alphabet, mode: Mode, tier: Tier) -> Engine {
        let mut tier = tier.clamp();
        // The 2018 AVX2 range arithmetic only fits range-structured
        // alphabets; fall through to SWAR otherwise (paper §5).
        if tier == Tier::Avx2 && !Avx2Codec::supports(&alphabet) {
            tier = Tier::Swar;
        }
        let block = BlockCodec::with_mode(alphabet.clone(), mode);
        let swar = matches!(tier, Tier::Swar)
            .then(|| SwarCodec::with_mode(alphabet.clone(), mode));
        let avx2 = matches!(tier, Tier::Avx2)
            .then(|| Avx2Codec::with_mode(alphabet.clone(), mode));
        let avx512 = matches!(tier, Tier::Avx512)
            .then(|| Avx512Codec::with_mode(alphabet.clone(), mode));
        Engine {
            kernels: kernels_for(tier),
            alphabet,
            mode,
            tier,
            block,
            swar,
            avx2,
            avx512,
        }
    }

    /// The tier this engine dispatches to.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Exact output size of [`Self::encode_slice`] for `n` input bytes.
    pub fn encoded_len(&self, n: usize) -> usize {
        encoded_len(n)
    }

    /// Exact output size of [`Self::decode_slice`] for this input
    /// (counts trailing padding; does not validate).
    pub fn decoded_len_of(&self, input: &[u8]) -> usize {
        let pad = self.alphabet.pad();
        let pads = input.iter().rev().take(2).take_while(|&&c| c == pad).count();
        decoded_len(input.len(), pads)
    }

    /// Encode `input` into `out[0..]`, returning the bytes written
    /// (always `encoded_len(input.len())`). Never allocates; panics if
    /// `out` is too small.
    #[inline]
    pub fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let out = &mut out[..total];
        let consumed = (self.kernels.encode_bulk)(self, input, out);
        let w = consumed / 3 * 4;
        // Epilogue: the paper's conventional path for the sub-granule
        // remainder and the padded final quantum.
        self.block.encode_slice(&input[consumed..], &mut out[w..]);
        total
    }

    /// Decode `input` into `out[0..]`, returning the bytes written.
    /// `out` must hold `decoded_len_of(input)` bytes (or the
    /// `decoded_len_upper` bound). Never allocates; on error the
    /// contents of `out` are unspecified.
    #[inline]
    pub fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let body_out = body.len() / 4 * 3;
        assert!(out.len() >= body_out, "output buffer too small");
        let consumed = (self.kernels.decode_bulk)(self, body, &mut out[..body_out])?;
        let mut w = consumed / 4 * 3;
        w += decode_quads_into(
            &body[consumed..],
            self.alphabet.decode_table().as_bytes(),
            consumed,
            &mut out[w..body_out],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }

    /// Chunked multi-threaded encode for large payloads: splits the
    /// input on 48-byte block boundaries across `threads` scoped threads
    /// (0 = one per available core, capped at 8). Falls back to the
    /// single-threaded path below [`PAR_THRESHOLD`]. Output is
    /// byte-identical to [`Self::encode_slice`].
    pub fn encode_par(&self, input: &[u8], out: &mut [u8], threads: usize) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let threads = effective_threads(threads);
        if threads < 2 || input.len() < PAR_THRESHOLD {
            return self.encode_slice(input, out);
        }
        let blocks = input.len() / RAW_BLOCK;
        let span = blocks.div_ceil(threads) * RAW_BLOCK; // raw bytes per thread
        let bulk = blocks * RAW_BLOCK;
        let (bulk_in, tail_in) = input.split_at(bulk);
        let (bulk_out, tail_out) = out[..total].split_at_mut(bulk / 3 * 4);
        std::thread::scope(|s| {
            let mut rest_in = bulk_in;
            let mut rest_out = &mut bulk_out[..];
            while !rest_in.is_empty() {
                let n = span.min(rest_in.len());
                let (chunk_in, next_in) = rest_in.split_at(n);
                let (chunk_out, next_out) = std::mem::take(&mut rest_out).split_at_mut(n / 3 * 4);
                rest_in = next_in;
                rest_out = next_out;
                // Whole-block spans encode with no padding, so the
                // per-span outputs concatenate exactly.
                s.spawn(move || self.encode_slice(chunk_in, chunk_out));
            }
        });
        // The sub-block remainder (with padding) runs on this thread.
        self.block.encode_slice(tail_in, tail_out);
        total
    }

    /// Chunked multi-threaded decode: splits the whole-quantum body on
    /// 64-char block boundaries across scoped threads; the sub-block
    /// remainder and padded tail decode on the calling thread. Output
    /// and error reporting are byte-identical to [`Self::decode_slice`]
    /// except that when *multiple* spans contain invalid bytes the
    /// reported offset is the smallest among the failing spans' first
    /// errors (still always a genuinely invalid byte).
    pub fn decode_par(
        &self,
        input: &[u8],
        out: &mut [u8],
        threads: usize,
    ) -> Result<usize, DecodeError> {
        let threads = effective_threads(threads);
        if threads < 2 || input.len() < PAR_THRESHOLD {
            return self.decode_slice(input, out);
        }
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let body_out = body.len() / 4 * 3;
        assert!(out.len() >= body_out, "output buffer too small");
        let blocks = body.len() / B64_BLOCK;
        let span = blocks.div_ceil(threads) * B64_BLOCK; // chars per thread
        let bulk = blocks * B64_BLOCK;
        let first_err = std::sync::Mutex::new(None::<DecodeError>);
        std::thread::scope(|s| {
            let mut rest_in = &body[..bulk];
            let mut rest_out = &mut out[..bulk / 4 * 3];
            let mut base = 0usize;
            while !rest_in.is_empty() {
                let n = span.min(rest_in.len());
                let (chunk_in, next_in) = rest_in.split_at(n);
                let (chunk_out, next_out) = std::mem::take(&mut rest_out).split_at_mut(n / 4 * 3);
                rest_in = next_in;
                rest_out = next_out;
                let first_err = &first_err;
                let chunk_base = base;
                base += n;
                s.spawn(move || {
                    if let Err(e) = self.decode_span(chunk_in, chunk_out, chunk_base) {
                        let mut slot = first_err.lock().unwrap();
                        let replace = match (&*slot, &e) {
                            (None, _) => true,
                            (
                                Some(DecodeError::InvalidByte { offset: prev, .. }),
                                DecodeError::InvalidByte { offset: new, .. },
                            ) => new < prev,
                            _ => false,
                        };
                        if replace {
                            *slot = Some(e);
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        // Sub-block remainder + tail on the calling thread.
        let mut w = bulk / 4 * 3;
        w += decode_quads_into(
            &body[bulk..],
            self.alphabet.decode_table().as_bytes(),
            bulk,
            &mut out[w..body_out],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }

    /// Decode one whole-quantum span (no padding) with offsets rebased
    /// to the original input.
    fn decode_span(&self, span: &[u8], out: &mut [u8], base: usize) -> Result<(), DecodeError> {
        let consumed = (self.kernels.decode_bulk)(self, span, out).map_err(|e| rebase(e, base))?;
        let w = consumed / 4 * 3;
        decode_quads_into(
            &span[consumed..],
            self.alphabet.decode_table().as_bytes(),
            base + consumed,
            &mut out[w..],
        )?;
        Ok(())
    }
}

fn rebase(e: DecodeError, base: usize) -> DecodeError {
    match e {
        DecodeError::InvalidByte { offset, byte } => {
            DecodeError::InvalidByte { offset: base + offset, byte }
        }
        other => other,
    }
}

fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    }
}

impl Codec for Engine {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        Engine::encode_slice(self, input, out)
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        Engine::decode_slice(self, input, out)
    }

    fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        // Exact-size allocation via the padding-aware length helper. The
        // helper over-counts for degenerate forgiving-mode inputs (3+
        // trailing pads), so trim to what was actually written.
        let mut out = vec![0u8; self.decoded_len_of(input)];
        let n = Engine::decode_slice(self, input, &mut out)?;
        out.truncate(n);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;
    use crate::workload::random_bytes;

    #[test]
    fn tier_ladder_clamps_to_host() {
        for t in [Tier::Avx512, Tier::Avx2, Tier::Swar, Tier::Scalar] {
            assert!(t.clamp().available());
        }
        assert_eq!(Tier::Scalar.clamp(), Tier::Scalar);
        assert!(Tier::supported().contains(&Tier::Swar));
        assert!(Tier::supported().contains(&Tier::Scalar));
    }

    #[test]
    fn tier_parse_names() {
        assert_eq!(Tier::parse("avx512"), Some(Tier::Avx512));
        assert_eq!(Tier::parse("swar"), Some(Tier::Swar));
        assert_eq!(Tier::parse("block"), Some(Tier::Scalar));
        assert_eq!(Tier::parse("mmx"), None);
    }

    #[test]
    fn get_is_cached_and_usable() {
        let e1 = Engine::get();
        let e2 = Engine::get();
        assert!(std::ptr::eq(e1, e2), "Engine::get must cache");
        assert_eq!(e1.tier(), detected_tier());
        let mut out = [0u8; 8];
        assert_eq!(e1.encode_slice(b"foobar", &mut out), 8);
        assert_eq!(&out, b"Zm9vYmFy");
    }

    #[test]
    fn slice_roundtrip_every_supported_tier() {
        let oracle = ScalarCodec::new(Alphabet::standard());
        for tier in Tier::supported() {
            let e = Engine::with_tier(Alphabet::standard(), tier);
            assert_eq!(e.tier(), tier);
            for len in [0usize, 1, 2, 3, 23, 24, 47, 48, 49, 200, 1000] {
                let data = random_bytes(len, len as u64);
                let mut enc = vec![0u8; e.encoded_len(len)];
                let n = e.encode_slice(&data, &mut enc);
                assert_eq!(&enc[..n], &oracle.encode(&data)[..], "{tier:?} len={len}");
                let mut dec = vec![0u8; e.decoded_len_of(&enc[..n])];
                let m = e.decode_slice(&enc[..n], &mut dec).unwrap();
                assert_eq!(&dec[..m], &data[..], "{tier:?} len={len}");
            }
        }
    }

    #[test]
    fn url_alphabet_on_avx2_tier_falls_back() {
        if !Tier::Avx2.available() {
            eprintln!("skipping: no AVX2");
            return;
        }
        let e = Engine::with_tier(Alphabet::url(), Tier::Avx2);
        assert_eq!(e.tier(), Tier::Swar, "url lacks the 2018 range structure");
        let data = random_bytes(100, 9);
        assert_eq!(e.decode(&e.encode(&data)).unwrap(), data);
    }

    #[test]
    fn decode_errors_match_scalar_offsets() {
        let oracle = ScalarCodec::new(Alphabet::standard());
        for tier in Tier::supported() {
            let e = Engine::with_tier(Alphabet::standard(), tier);
            let mut enc = e.encode(&random_bytes(300, 3));
            for pos in [0usize, 63, 64, 250] {
                let orig = enc[pos];
                enc[pos] = b'!';
                let want = oracle.decode(&enc).unwrap_err();
                let mut out = vec![0u8; e.decoded_len_of(&enc)];
                let got = e.decode_slice(&enc, &mut out).unwrap_err();
                assert_eq!(got, want, "{tier:?} pos={pos}");
                enc[pos] = orig;
            }
        }
    }

    #[test]
    fn par_paths_match_serial() {
        let e = Engine::get();
        // Cross the PAR_THRESHOLD so the scoped-thread path actually runs.
        let data = random_bytes(PAR_THRESHOLD + 12345, 7);
        let mut serial = vec![0u8; e.encoded_len(data.len())];
        let mut par = vec![0u8; e.encoded_len(data.len())];
        e.encode_slice(&data, &mut serial);
        let n = e.encode_par(&data, &mut par, 4);
        assert_eq!(n, serial.len());
        assert_eq!(par, serial);
        let mut dec = vec![0u8; e.decoded_len_of(&par)];
        let m = e.decode_par(&par, &mut dec, 4).unwrap();
        assert_eq!(&dec[..m], &data[..]);
    }

    #[test]
    fn par_decode_reports_errors() {
        let e = Engine::get();
        let data = random_bytes(PAR_THRESHOLD + 999, 11);
        let mut enc = e.encode(&data);
        let n = enc.len();
        enc[n / 2] = 0x07;
        let mut out = vec![0u8; e.decoded_len_of(&enc)];
        match e.decode_par(&enc, &mut out, 4) {
            Err(DecodeError::InvalidByte { offset, byte: 0x07 }) => assert_eq!(offset, n / 2),
            other => panic!("expected invalid byte, got {other:?}"),
        }
    }

    #[test]
    fn engine_vec_wrappers() {
        let e = Engine::get();
        assert_eq!(e.encode(b"foobar"), b"Zm9vYmFy");
        assert_eq!(e.decode(b"Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(e.decode(b"Zg==").unwrap(), b"f");
        assert!(e.decode(b"Zg=!").is_err());
    }

    #[test]
    fn decoded_len_of_counts_padding() {
        let e = Engine::get();
        assert_eq!(e.decoded_len_of(b""), 0);
        assert_eq!(e.decoded_len_of(b"Zg=="), 1);
        assert_eq!(e.decoded_len_of(b"Zm8="), 2);
        assert_eq!(e.decoded_len_of(b"Zm9v"), 3);
        assert_eq!(e.decoded_len_of(b"Zm8"), 2); // forgiving unpadded
    }
}
