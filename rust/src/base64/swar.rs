//! Wide-word (SWAR) codec — the AVX2-class baseline on plain u64/u32.
//!
//! Where [`super::scalar`] touches one byte at a time, this codec uses the
//! classic wide-table formulation that the 2018 AVX2 paper benchmarked
//! against and that production scalar codecs (modp_b64, aklomp/base64
//! "plain") use:
//!
//! * **encode**: three 256-entry byte tables indexed by *pre-shifted*
//!   bytes, emitting one `u32` (4 chars) per 3 input bytes with a single
//!   unaligned store;
//! * **decode**: four 256-entry `u32` tables with the 6-bit values
//!   pre-positioned, so a quantum decodes as `d0[c0]|d1[c1]|d2[c2]|d3[c3]`
//!   — one OR-tree plus a single sentinel test (invalid chars carry
//!   `0xFF00_0000`), then a 4-byte store advanced by 3.
//!
//! Tables are built per [`Alphabet`] at construction time (4.75 kB), the
//! register-file analog of AVX2's in-register LUTs.

use super::validate::{decode_tail_into, split_tail, DecodeError, Mode, Whitespace};
use super::{encoded_len, Alphabet, Codec};

/// Sentinel OR-mask marking an invalid character in the decode tables.
const BAD: u32 = 0xFF00_0000;

const LANE_LSB: u64 = 0x0101_0101_0101_0101;
const LANE_MSB: u64 = 0x8080_8080_8080_8080;

/// Per-lane equality detector (the classic SWAR zero-byte test on
/// `w ^ broadcast(t)`). A lane's high bit is set when its byte equals
/// `t`; borrow propagation can set *higher* lanes spuriously, so only
/// "mask is zero" and "index of lowest set bit" are meaningful — which
/// is exactly how [`ws_mask`]'s callers use it.
#[inline(always)]
fn eq_mask(w: u64, t: u8) -> u64 {
    let x = w ^ (LANE_LSB * t as u64);
    x.wrapping_sub(LANE_LSB) & !x & LANE_MSB
}

/// Whitespace detector for one little-endian 8-byte word: lowest set bit
/// marks the first byte the policy skips (see [`eq_mask`] for why only
/// the first match is trustworthy).
#[inline(always)]
fn ws_mask(w: u64, ws: Whitespace) -> u64 {
    match ws {
        Whitespace::None => 0,
        Whitespace::CrLf => eq_mask(w, b'\r') | eq_mask(w, b'\n'),
        Whitespace::All => {
            eq_mask(w, b'\r') | eq_mask(w, b'\n') | eq_mask(w, b' ') | eq_mask(w, b'\t')
        }
    }
}

/// Offset of the first byte the policy skips, or `None`. Word-at-a-time
/// scan; the streaming decoder uses it to split chunks into significant
/// runs without copying them.
pub(crate) fn find_ws(src: &[u8], ws: Whitespace) -> Option<usize> {
    if ws == Whitespace::None {
        return None;
    }
    let mut r = 0usize;
    while r + 8 <= src.len() {
        let word = u64::from_le_bytes(src[r..r + 8].try_into().unwrap());
        let m = ws_mask(word, ws);
        if m != 0 {
            return Some(r + (m.trailing_zeros() >> 3) as usize);
        }
        r += 8;
    }
    src[r..].iter().position(|&c| ws.skips(c)).map(|p| r + p)
}

/// Word-at-a-time whitespace compaction: the portable analog of the
/// AVX2 movemask / AVX-512 `vpcompressb` staging step. Whole words with
/// no skipped byte are copied with one 8-byte store; words containing
/// whitespace fall back to a run copy up to the first skipped byte.
/// Returns `(src_consumed, dst_written)`.
pub(crate) fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
    let (mut r, mut w) = (0usize, 0usize);
    while r + 8 <= src.len() && w + 8 <= dst.len() {
        let word = u64::from_le_bytes(src[r..r + 8].try_into().unwrap());
        let m = ws_mask(word, ws);
        if m == 0 {
            dst[w..w + 8].copy_from_slice(&src[r..r + 8]);
            r += 8;
            w += 8;
        } else {
            // Copy the significant run, then skip the one whitespace byte.
            let k = (m.trailing_zeros() >> 3) as usize;
            dst[w..w + k].copy_from_slice(&src[r..r + k]);
            w += k;
            r += k + 1;
        }
    }
    let (rt, wt) = super::scalar::compact_ws(&src[r..], &mut dst[w..], ws);
    (r + rt, w + wt)
}

/// Wide-word table-driven codec (AVX2-class baseline).
pub struct SwarCodec {
    alphabet: Alphabet,
    mode: Mode,
    /// e0[x] = char(x >> 2) ; e1[x] = char(x & 0x3F) — pre-shifted encode tables.
    e0: [u8; 256],
    e1: [u8; 256],
    /// d{i}[c] = value(c) << bit-position within the little-endian u32
    /// holding the 3 output bytes; BAD when c is not in the alphabet.
    d0: Box<[u32; 256]>,
    d1: Box<[u32; 256]>,
    d2: Box<[u32; 256]>,
    d3: Box<[u32; 256]>,
}

impl SwarCodec {
    /// Strict-mode codec for an alphabet.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_mode(alphabet, Mode::Strict)
    }

    /// [`Self::new`] with an explicit strictness mode (tables built
    /// once per codec).
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        let chars = alphabet.chars();
        let mut e0 = [0u8; 256];
        let mut e1 = [0u8; 256];
        for x in 0..256 {
            e0[x] = chars[x >> 2];
            e1[x] = chars[x & 0x3F];
        }
        let mut d0 = Box::new([BAD; 256]);
        let mut d1 = Box::new([BAD; 256]);
        let mut d2 = Box::new([BAD; 256]);
        let mut d3 = Box::new([BAD; 256]);
        for (v, &c) in chars.iter().enumerate() {
            let v = v as u32;
            let c = c as usize;
            // Output u32 (LE): byte0 = a<<2|b>>4, byte1 = b<<4|c>>2, byte2 = c<<6|d.
            d0[c] = v << 2;
            d1[c] = (v >> 4) | ((v & 0x0F) << 12);
            d2[c] = ((v >> 2) << 8) | ((v & 0x03) << 22);
            d3[c] = v << 16;
        }
        Self { alphabet, mode, e0, e1, d0, d1, d2, d3 }
    }

    /// The alphabet this codec was built for.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }

    /// Bulk slice core: encode all whole 3-byte groups of `input` into
    /// `out[0..]` (4 chars per group, no padding), returning the bytes
    /// consumed. `out` must hold `input.len() / 3 * 4` chars.
    pub(crate) fn encode_bulk(&self, input: &[u8], out: &mut [u8]) -> usize {
        let mut w = 0;
        for chunk in input.chunks_exact(3) {
            let (s1, s2, s3) = (chunk[0] as usize, chunk[1] as usize, chunk[2] as usize);
            let quad = [
                self.e0[s1],
                self.e1[((s1 & 0x03) << 4) | (s2 >> 4)],
                self.e1[((s2 & 0x0F) << 2) | (s3 >> 6)],
                self.e1[s3 & 0x3F],
            ];
            out[w..w + 4].copy_from_slice(&quad);
            w += 4;
        }
        input.len() / 3 * 3
    }

    /// Bulk slice core: decode all whole 4-char quanta of `body` (no
    /// padding) into `out[0..]`, 3 bytes per quantum. Returns the chars
    /// consumed; errors report offsets relative to `body`.
    pub(crate) fn decode_bulk(&self, body: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let mut w = 0;
        for (q, quad) in body.chunks_exact(4).enumerate() {
            let v = self.d0[quad[0] as usize]
                | self.d1[quad[1] as usize]
                | self.d2[quad[2] as usize]
                | self.d3[quad[3] as usize];
            if v & BAD != 0 {
                // Narrow to the exact byte for the error report (cold path).
                for (i, &c) in quad.iter().enumerate() {
                    if self.alphabet.value_of(c).is_none() {
                        return Err(DecodeError::InvalidByte { offset: q * 4 + i, byte: c });
                    }
                }
                unreachable!("sentinel set but all bytes valid");
            }
            out[w..w + 3].copy_from_slice(&v.to_le_bytes()[..3]);
            w += 3;
        }
        Ok(body.len() / 4 * 4)
    }
}

impl Codec for SwarCodec {
    fn name(&self) -> &'static str {
        "swar"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let consumed = self.encode_bulk(input, out);
        let mut w = consumed / 3 * 4;
        let pad = self.alphabet.pad();
        match &input[consumed..] {
            [] => {}
            [s1] => {
                let s1 = *s1 as usize;
                out[w..w + 4].copy_from_slice(&[self.e0[s1], self.e1[(s1 & 0x03) << 4], pad, pad]);
                w += 4;
            }
            [s1, s2] => {
                let (s1, s2) = (*s1 as usize, *s2 as usize);
                out[w..w + 4].copy_from_slice(&[
                    self.e0[s1],
                    self.e1[((s1 & 0x03) << 4) | (s2 >> 4)],
                    self.e1[(s2 & 0x0F) << 2],
                    pad,
                ]);
                w += 4;
            }
            _ => unreachable!("bulk consumes all whole groups"),
        }
        debug_assert_eq!(w, total);
        w
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        self.decode_bulk(body, out)?;
        let w = body.len() / 4 * 3;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;

    fn codec() -> SwarCodec {
        SwarCodec::new(Alphabet::standard())
    }

    #[test]
    fn rfc4648_test_vectors() {
        let c = codec();
        for (raw, enc) in [
            (&b""[..], &b""[..]),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foob", b"Zm9vYg=="),
            (b"fooba", b"Zm9vYmE="),
            (b"foobar", b"Zm9vYmFy"),
        ] {
            assert_eq!(c.encode(raw), enc);
            assert_eq!(c.decode(enc).unwrap(), raw);
        }
    }

    #[test]
    fn agrees_with_scalar_on_random_data() {
        let s = ScalarCodec::new(Alphabet::standard());
        let c = codec();
        let mut x: u32 = 0x1234_5678;
        for len in 0..200usize {
            let data: Vec<u8> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                    (x >> 24) as u8
                })
                .collect();
            let enc = c.encode(&data);
            assert_eq!(enc, s.encode(&data), "len={len}");
            assert_eq!(c.decode(&enc).unwrap(), data, "len={len}");
        }
    }

    #[test]
    fn decode_table_positions() {
        // 'Q' = value 16: verify each table places the bits correctly by
        // decoding "QQQQ" -> 0b010000_010000_010000_010000 packed.
        let c = codec();
        let out = c.decode(b"QQQQ").unwrap();
        assert_eq!(out, vec![0b0100_0001, 0b0000_0100, 0b0001_0000]);
    }

    #[test]
    fn invalid_byte_detected_in_each_position() {
        let c = codec();
        for pos in 0..4 {
            let mut quad = *b"AAAA";
            quad[pos] = b'!';
            let err = c.decode(&quad).unwrap_err();
            assert_eq!(err, DecodeError::InvalidByte { offset: pos, byte: b'!' });
        }
    }

    #[test]
    fn non_ascii_detected() {
        let c = codec();
        for pos in 0..4 {
            let mut quad = *b"AAAA";
            quad[pos] = 0x80 + pos as u8;
            assert!(c.decode(&quad).is_err());
        }
    }

    #[test]
    fn swar_compaction_matches_scalar_reference() {
        // Pseudo-random text with whitespace sprinkled at varying density,
        // across lengths straddling the 8-byte word loop.
        let mut x: u32 = 0xBEEF;
        for len in 0..120usize {
            let src: Vec<u8> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                    match x >> 29 {
                        0 => b'\r',
                        1 => b'\n',
                        2 => b' ',
                        3 => b'\t',
                        _ => b'A' + (x >> 24 & 0x0F) as u8,
                    }
                })
                .collect();
            for ws in [Whitespace::None, Whitespace::CrLf, Whitespace::All] {
                let mut a = vec![0u8; len];
                let mut b = vec![0u8; len];
                let got = compact_ws(&src, &mut a, ws);
                let want = super::super::scalar::compact_ws(&src, &mut b, ws);
                assert_eq!(got, want, "len={len} ws={ws:?}");
                assert_eq!(a[..got.1], b[..want.1], "len={len} ws={ws:?}");
                // Constrained destination: same consumed/written split.
                let cap = len / 2;
                let mut a = vec![0u8; cap];
                let mut b = vec![0u8; cap];
                let got = compact_ws(&src, &mut a, ws);
                let want = super::super::scalar::compact_ws(&src, &mut b, ws);
                assert_eq!(got, want, "cap len={len} ws={ws:?}");
                assert_eq!(a[..got.1], b[..want.1], "cap len={len} ws={ws:?}");
            }
        }
    }

    #[test]
    fn find_ws_first_match() {
        assert_eq!(find_ws(b"AAAAAAAAAAAA\rB", Whitespace::CrLf), Some(12));
        assert_eq!(find_ws(b"\nAAAA", Whitespace::CrLf), Some(0));
        assert_eq!(find_ws(b"AAAA AAAA", Whitespace::CrLf), None);
        assert_eq!(find_ws(b"AAAA AAAA", Whitespace::All), Some(4));
        assert_eq!(find_ws(b"anything", Whitespace::None), None);
        assert_eq!(find_ws(b"", Whitespace::All), None);
    }

    #[test]
    fn url_variant_tables() {
        let c = SwarCodec::new(Alphabet::url());
        assert_eq!(c.encode(&[0xFB, 0xFF]), b"-_8=");
        assert_eq!(c.decode(b"-_8=").unwrap(), vec![0xFB, 0xFF]);
    }
}
