//! Pure-Rust base64 substrate: every codec the paper benchmarks.
//!
//! | module | paper role |
//! |---|---|
//! | [`scalar`] | the conventional per-byte LUT codec (Chrome baseline) |
//! | [`swar`] | 64-bit SWAR codec — the AVX2-class register baseline |
//! | [`block`] | the paper's AVX-512 dataflow in scalar Rust: reference twin of the Pallas kernel and the coordinator's tail path |
//! | [`avx2`] | the 2018 AVX2 codec with real intrinsics — the paper's comparison baseline |
//! | [`avx512`] | the paper's actual §3 algorithm with real AVX-512 VBMI intrinsics (runtime-detected) |
//! | [`alphabet`]/[`tables`] | runtime-swappable variants (paper §5) |
//! | [`validate`] | RFC 4648 padding/strictness semantics |
//! | [`streaming`] | incremental encode/decode with carry state |
//! | [`mime`] | RFC 2045 line-wrapped base64 |
//! | [`datauri`] | `data:` URI encode/parse |

pub mod alphabet;
pub mod avx2;
pub mod avx512;
pub mod block;
pub mod datauri;
pub mod mime;
pub mod scalar;
pub mod streaming;
pub mod swar;
pub mod tables;
pub mod validate;

pub use alphabet::Alphabet;
pub use validate::{DecodeError, Mode};

/// Number of raw bytes consumed per block-codec iteration (paper §3).
pub const RAW_BLOCK: usize = 48;
/// Number of base64 characters produced per block-codec iteration.
pub const B64_BLOCK: usize = 64;

/// Common interface implemented by every codec in this crate, so the
/// benchmarks and the coordinator can swap them freely.
pub trait Codec {
    /// Name used in benchmark output (matches the paper's series labels).
    fn name(&self) -> &'static str;

    /// Encode `input` to base64 with padding, appending to a fresh buffer.
    fn encode(&self, input: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(encoded_len(input.len()));
        self.encode_into(input, &mut out);
        out
    }

    /// Encode into a caller-provided buffer (appends; no allocation if
    /// `out` has capacity). Returns bytes written.
    fn encode_into(&self, input: &[u8], out: &mut Vec<u8>) -> usize;

    /// Decode base64 (strict RFC 4648: canonical padding, no whitespace).
    fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        let mut out = Vec::with_capacity(decoded_len_upper(input.len()));
        self.decode_into(input, &mut out)?;
        Ok(out)
    }

    /// Decode into a caller-provided buffer (appends). Returns bytes written.
    fn decode_into(&self, input: &[u8], out: &mut Vec<u8>) -> Result<usize, DecodeError>;
}

/// Exact encoded length (with '=' padding) for `n` raw bytes.
pub const fn encoded_len(n: usize) -> usize {
    n.div_ceil(3) * 4
}

/// Upper bound on decoded length for `n` base64 chars (before padding trim).
pub const fn decoded_len_upper(n: usize) -> usize {
    (n / 4 + 1) * 3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoded_len_matches_rfc() {
        assert_eq!(encoded_len(0), 0);
        assert_eq!(encoded_len(1), 4);
        assert_eq!(encoded_len(2), 4);
        assert_eq!(encoded_len(3), 4);
        assert_eq!(encoded_len(4), 8);
        assert_eq!(encoded_len(48), 64);
        assert_eq!(encoded_len(49), 68);
    }

    #[test]
    fn decoded_upper_bound_is_sufficient() {
        for n in 0..200 {
            let enc = encoded_len(n);
            assert!(decoded_len_upper(enc) >= n, "n={n}");
        }
    }
}
