//! Pure-Rust base64 substrate: every codec the paper benchmarks.
//!
//! | module | paper role |
//! |---|---|
//! | [`scalar`] | the conventional per-byte LUT codec (Chrome baseline) |
//! | [`swar`] | 64-bit SWAR codec — the AVX2-class register baseline |
//! | [`block`] | the paper's AVX-512 dataflow in scalar Rust: reference twin of the Pallas kernel and the coordinator's tail path |
//! | [`avx2`] | the 2018 AVX2 codec with real intrinsics — the paper's comparison baseline |
//! | [`avx512`] | the paper's actual §3 algorithm with real AVX-512 VBMI intrinsics (runtime-detected) |
//! | [`engine`] | zero-allocation facade: one-time tier detection (AVX-512 → AVX2 → SWAR → scalar block), cached function pointers, slice + parallel APIs |
//! | [`stores`] | store-policy subsystem: non-temporal cache-line stores + software prefetch for >LLC payloads (`Temporal \| NonTemporal \| Auto`) |
//! | [`alphabet`]/[`tables`] | runtime-swappable variants (paper §5) |
//! | [`validate`] | RFC 4648 padding/strictness semantics + the shared deferred-error re-scan helpers |
//! | [`streaming`] | incremental encode/decode with carry state |
//! | [`mime`] | RFC 2045 line-wrapped base64 |
//! | [`datauri`] | `data:` URI encode/parse |
//!
//! The hot path everywhere is the *slice* API: [`Codec::encode_slice`] /
//! [`Codec::decode_slice`] write into caller-provided buffers and never
//! allocate. The `Vec`-returning methods are thin wrappers over those
//! cores. [`engine::Engine`] picks the fastest core the host supports
//! exactly once and exposes it behind plain function pointers.
//!
//! ## Whitespace-tolerant (MIME) decoding
//!
//! Line-wrapped base64 is the paper's motivating workload, so the engine
//! fuses whitespace handling into the wide loop instead of stripping in
//! a separate pass:
//!
//! * [`validate::Whitespace`] (`None | CrLf | All`) names the skip set —
//!   `CrLf` for RFC 2045 line wrapping, `All` to also skip space/tab;
//! * [`engine::Engine::decode_slice_ws`] decodes while compacting
//!   skipped bytes through a tier-matched kernel (AVX-512 VBMI2
//!   `vpcompressb` mask-compress, AVX2 `vpcmpeqb`+`vpmovmskb` run
//!   copies, or a SWAR word scan) into an on-stack staging block that
//!   feeds the same bulk decode kernels as the flat path — single pass,
//!   zero allocations, error offsets in *original input* coordinates;
//! * [`engine::Engine::encode_wrapped_slice`] writes CRLF line breaks
//!   inline during the store loop (no encode-then-recopy);
//! * [`mime::MimeCodec`] and [`datauri`] are thin wrappers over these
//!   entry points, and [`streaming`] drives the same tiered kernels with
//!   a block-aligned carry buffer so chunked sessions decode at engine
//!   speed too.
//!
//! ## Store policy (>L2 payloads)
//!
//! The memcpy-speed claim stops at the last-level cache: beyond it,
//! temporal stores pay read-for-ownership traffic and evict the input
//! stream. [`stores::StorePolicy`] (`Temporal | NonTemporal |
//! Auto(threshold)`) threads through every engine entry point
//! (`*_policy` twins); non-temporal mode stages kernel output in L1 and
//! streams whole aligned cache lines to the destination
//! (`_mm512_stream_si512` / `_mm256_stream_si256`, plain stores as the
//! SWAR/scalar fallback) with tier-scaled input prefetch. `Auto` — the
//! default — flips to streaming stores when a call's working set
//! exceeds the detected LLC, and drives [`engine::Engine::encode_par`] /
//! [`engine::Engine::decode_par`], the streaming codecs' bulk path and
//! the coordinator's block backends. Output bytes and error offsets are
//! identical under every policy (pinned by `rust/tests/stores.rs`).
//!
//! ## Tier override
//!
//! Set `B64SIMD_TIER=avx512|avx2|swar|scalar` to clamp the runtime
//! dispatch (see [`engine::detected_tier`]); the choice applies to the
//! bulk codecs *and* the whitespace compaction kernels, so
//! `B64SIMD_TIER=scalar` exercises a fully scalar pipeline end to end.
//! Set `B64SIMD_STORES=temporal|nontemporal|auto|auto:<bytes>` to clamp
//! the store policy the same way (see [`stores::default_policy`]).

pub mod alphabet;
pub mod avx2;
pub mod avx512;
pub mod block;
pub mod datauri;
pub mod engine;
pub mod mime;
pub mod scalar;
pub mod stores;
pub mod streaming;
pub mod swar;
pub mod tables;
pub mod validate;

pub use alphabet::Alphabet;
pub use engine::{Engine, Tier};
pub use stores::StorePolicy;
pub use validate::{DecodeError, Mode, Whitespace};

/// Number of raw bytes consumed per block-codec iteration (paper §3).
pub const RAW_BLOCK: usize = 48;
/// Number of base64 characters produced per block-codec iteration.
pub const B64_BLOCK: usize = 64;

/// Common interface implemented by every codec in this crate, so the
/// benchmarks and the coordinator can swap them freely.
///
/// The *required* methods are the allocation-free slice cores; the
/// `Vec`-based conveniences are provided wrappers over them, so every
/// codec has exactly one hot-path implementation.
pub trait Codec {
    /// Name used in benchmark output (matches the paper's series labels).
    fn name(&self) -> &'static str;

    /// Encode `input` to padded base64 into `out[0..]`, returning the
    /// bytes written (always `encoded_len(input.len())`). Panics if `out`
    /// is shorter than that. Never allocates.
    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize;

    /// Decode base64 into `out[0..]`, returning the bytes written.
    /// `out` must hold at least `decoded_len_upper(input.len())` bytes
    /// (use [`decoded_len`] for the exact count when the padding is
    /// known). On error the contents of `out` are unspecified. Never
    /// allocates.
    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError>;

    /// Encode `input` to base64 with padding, returning a fresh buffer.
    fn encode(&self, input: &[u8]) -> Vec<u8> {
        let mut out = vec![0u8; encoded_len(input.len())];
        let n = self.encode_slice(input, &mut out);
        debug_assert_eq!(n, out.len());
        out
    }

    /// Encode into a caller-provided buffer (appends; no allocation if
    /// `out` has capacity). Returns bytes written.
    fn encode_into(&self, input: &[u8], out: &mut Vec<u8>) -> usize {
        let start = out.len();
        out.resize(start + encoded_len(input.len()), 0);
        self.encode_slice(input, &mut out[start..])
    }

    /// Decode base64 (strict RFC 4648: canonical padding, no whitespace).
    fn decode(&self, input: &[u8]) -> Result<Vec<u8>, DecodeError> {
        let mut out = vec![0u8; decoded_len_upper(input.len())];
        let n = self.decode_slice(input, &mut out)?;
        out.truncate(n);
        Ok(out)
    }

    /// Decode into a caller-provided buffer (appends). Returns bytes
    /// written; on error `out` is restored to its original length.
    fn decode_into(&self, input: &[u8], out: &mut Vec<u8>) -> Result<usize, DecodeError> {
        let start = out.len();
        out.resize(start + decoded_len_upper(input.len()), 0);
        match self.decode_slice(input, &mut out[start..]) {
            Ok(n) => {
                out.truncate(start + n);
                Ok(n)
            }
            Err(e) => {
                out.truncate(start);
                Err(e)
            }
        }
    }
}

/// Exact encoded length (with '=' padding) for `n` raw bytes.
pub const fn encoded_len(n: usize) -> usize {
    n.div_ceil(3) * 4
}

/// Tight upper bound on decoded length for `n` base64 chars (before the
/// padding trim): ceil(n/4)*3. Exact for padded whole-quantum input whose
/// final quantum carries no '='; at most 2 bytes over otherwise. The old
/// `(n/4 + 1)*3` formula over-reserved a full 3-byte group for every
/// whole-block input.
pub const fn decoded_len_upper(n: usize) -> usize {
    n.div_ceil(4) * 3
}

/// Exact decoded length for `n` base64 chars of which the trailing
/// `padding` are pad characters. Handles unpadded (forgiving-mode)
/// lengths too: a 2-char final fragment decodes to 1 byte, a 3-char one
/// to 2. (A 1-char fragment is invalid and contributes 0.)
pub const fn decoded_len(n: usize, padding: usize) -> usize {
    let data = n - padding;
    data / 4 * 3
        + match data % 4 {
            2 => 1,
            3 => 2,
            _ => 0,
        }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoded_len_matches_rfc() {
        assert_eq!(encoded_len(0), 0);
        assert_eq!(encoded_len(1), 4);
        assert_eq!(encoded_len(2), 4);
        assert_eq!(encoded_len(3), 4);
        assert_eq!(encoded_len(4), 8);
        assert_eq!(encoded_len(48), 64);
        assert_eq!(encoded_len(49), 68);
    }

    #[test]
    fn decoded_upper_bound_is_sufficient_and_tight_on_blocks() {
        for n in 0..200 {
            let enc = encoded_len(n);
            assert!(decoded_len_upper(enc) >= n, "n={n}");
        }
        // Whole-block inputs must not over-reserve (the old formula added
        // a spurious 3 bytes for every n % 4 == 0 input).
        assert_eq!(decoded_len_upper(64), 48);
        assert_eq!(decoded_len_upper(0), 0);
        assert_eq!(decoded_len_upper(4), 3);
    }

    #[test]
    fn decoded_len_exact_against_roundtrip() {
        use super::scalar::ScalarCodec;
        let c = ScalarCodec::new(Alphabet::standard());
        for n in 0..100usize {
            let data = vec![0xA7u8; n];
            let enc = c.encode(&data);
            let pads = enc.iter().rev().take_while(|&&b| b == b'=').count();
            assert_eq!(decoded_len(enc.len(), pads), n, "n={n}");
        }
        // Unpadded forgiving-mode lengths.
        assert_eq!(decoded_len(3, 0), 2);
        assert_eq!(decoded_len(2, 0), 1);
        assert_eq!(decoded_len(6, 0), 4);
    }
}
