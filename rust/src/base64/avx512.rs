//! The paper's actual AVX-512 VBMI codec, with real intrinsics.
//!
//! When the host CPU supports AVX-512 VBMI (the paper's Cannon Lake ISA —
//! also present on Ice Lake and newer), this module runs §3 of the paper
//! *verbatim*:
//!
//! * encode, 3 instructions / 64 output bytes: `vpermb`
//!   (`_mm512_permutexvar_epi8`) → `vpmultishiftqb`
//!   (`_mm512_multishift_epi64_epi8`) → `vpermb`;
//! * decode, 5 instructions / 64 input bytes: `vpermi2b`
//!   (`_mm512_permutex2var_epi8`) → `vpternlogd` (imm 0xFE: A|B|C) →
//!   `vpmaddubsw` → `vpmaddwd` → `vpermb`, with a single `vpmovb2m`
//!   error check per stream.
//!
//! Tables are runtime values (the alphabet/decode registers), so every
//! variant works without recompilation — the paper's §5 claim, measured
//! here with the real instructions. Use [`Avx512Codec::available`] to
//! detect support; construction panics without it.

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::block::BlockCodec;
use super::validate::{
    decode_quads_into, decode_tail_into, first_invalid, split_tail, DecodeError, Mode,
};
#[cfg(target_arch = "x86_64")]
use super::validate::Whitespace;
use super::{encoded_len, Alphabet, Codec, B64_BLOCK, RAW_BLOCK};

/// The paper's §3 algorithm on real 512-bit registers.
pub struct Avx512Codec {
    alphabet: Alphabet,
    mode: Mode,
    /// Scalar twin for tails and non-x86 fallback paths.
    scalar_twin: BlockCodec,
}

impl Avx512Codec {
    /// True iff the host can run this codec.
    pub fn available() -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512bw")
                && is_x86_feature_detected!("avx512vbmi")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    /// Panics if [`Self::available`] is false.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_mode(alphabet, Mode::Strict)
    }

    /// [`Self::new`] with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        assert!(Self::available(), "AVX-512 VBMI not available on this CPU");
        Self {
            scalar_twin: BlockCodec::with_mode(alphabet.clone(), mode),
            alphabet,
            mode,
        }
    }

    /// The alphabet this codec was built for.
    pub fn alphabet(&self) -> &Alphabet {
        &self.alphabet
    }

    /// True iff the host additionally supports AVX-512 VBMI2 — the ISA
    /// level of `vpcompressb`, which the engine's fused whitespace decode
    /// uses for in-register compaction (Clausecker & Lemire's AVX-512
    /// transcoding trick applied to byte removal).
    pub fn vbmi2_available() -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            Self::available() && is_x86_feature_detected!("avx512vbmi2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub use kernels as raw;

/// The raw AVX-512 intrinsic kernels (shared with the engine's
/// dispatch tables and the NT-store line copies).
#[cfg(target_arch = "x86_64")]
pub mod kernels {
    use super::*;

    /// Byte shuffle for `vpermb` #1 (paper §3.1): group g of the output
    /// takes input bytes (3g+1, 3g, 3g+2, 3g+1) = (s2, s1, s3, s2).
    const fn enc_shuffle() -> [u8; 64] {
        let mut idx = [0u8; 64];
        let mut g = 0;
        while g < 16 {
            idx[4 * g] = (3 * g + 1) as u8;
            idx[4 * g + 1] = (3 * g) as u8;
            idx[4 * g + 2] = (3 * g + 2) as u8;
            idx[4 * g + 3] = (3 * g + 1) as u8;
            g += 1;
        }
        idx
    }

    /// The paper's multishift list per 64-bit lane: 10, 4, 22, 16 for the
    /// low dword's four output bytes, +32 for the high dword.
    const fn multishifts() -> [u8; 8] {
        [10, 4, 22, 16, 10 + 32, 4 + 32, 22 + 32, 16 + 32]
    }

    /// `vpermb` compaction for decode (paper §3.2): output byte 3g+j
    /// takes packed byte (4g + 2-j) — the madd result holds the three
    /// useful bytes in little-endian order below a zero byte.
    const fn dec_pack() -> [u8; 64] {
        let mut idx = [0u8; 64];
        let mut g = 0;
        while g < 16 {
            idx[3 * g] = (4 * g + 2) as u8;
            idx[3 * g + 1] = (4 * g + 1) as u8;
            idx[3 * g + 2] = (4 * g) as u8;
            g += 1;
        }
        // Bytes 48..63 are don't-care (masked out of the store).
        idx
    }

    #[inline]
    unsafe fn load64(table: &[u8; 64]) -> __m512i {
        _mm512_loadu_si512(table.as_ptr() as *const _)
    }

    /// Encode full 48-byte blocks. `input.len() % 48 == 0`,
    /// `out.len() == input.len() / 48 * 64`.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub unsafe fn encode_blocks(input: &[u8], out: &mut [u8], table: &[u8; 64]) {
        let shuffle = load64(&enc_shuffle());
        let shifts = _mm512_set1_epi64(i64::from_le_bytes(multishifts()));
        let alphabet = load64(table);
        let blocks = input.len() / RAW_BLOCK;
        let in48: __mmask64 = 0x0000_FFFF_FFFF_FFFF;
        for b in 0..blocks {
            let src = input.as_ptr().add(b * RAW_BLOCK);
            let dst = out.as_mut_ptr().add(b * B64_BLOCK);
            // Load 48 bytes (masked: never reads past the buffer).
            let v = _mm512_maskz_loadu_epi8(in48, src as *const i8);
            // -- vpermb #1: (s1,s2,s3) -> (s2,s1,s3,s2).
            let v = _mm512_permutexvar_epi8(shuffle, v);
            // -- vpmultishiftqb: the four 6-bit fields per 32-bit lane.
            let idx = _mm512_multishift_epi64_epi8(shifts, v);
            // -- vpermb #2: alphabet lookup (6 LSBs of each index byte).
            let chars = _mm512_permutexvar_epi8(idx, alphabet);
            _mm512_storeu_si512(dst as *mut _, chars);
        }
    }

    /// Decode full 64-char blocks with the deferred error accumulator.
    /// Returns the `vpmovb2m` mask of the ERROR register (0 = clean).
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub unsafe fn decode_blocks(input: &[u8], out: &mut [u8], dtable: &[u8; 128]) -> u64 {
        let lut_lo = _mm512_loadu_si512(dtable.as_ptr() as *const _);
        let lut_hi = _mm512_loadu_si512(dtable.as_ptr().add(64) as *const _);
        let madd1 = _mm512_set1_epi32(0x0140_0140); // bytes (0x40, 0x01) pairs
        let madd2 = _mm512_set1_epi32(0x0001_1000); // words (0x1000, 0x0001)
        let pack = load64(&dec_pack());
        let out48: __mmask64 = 0x0000_FFFF_FFFF_FFFF;
        let mut error = _mm512_setzero_si512();
        let blocks = input.len() / B64_BLOCK;
        for b in 0..blocks {
            let src = input.as_ptr().add(b * B64_BLOCK);
            let dst = out.as_mut_ptr().add(b * RAW_BLOCK);
            let chars = _mm512_loadu_si512(src as *const _);
            // -- vpermi2b: 128-entry lookup, index MSB ignored
            //    (operand order: table_lo, index, table_hi).
            let values = _mm512_permutex2var_epi8(lut_lo, chars, lut_hi);
            // -- vpternlogd 0xFE: ERROR |= chars | values.
            error = _mm512_ternarylogic_epi32(error, chars, values, 0xFE);
            // -- vpmaddubsw: b + a*2^6 per byte pair.
            let merged = _mm512_maddubs_epi16(values, madd1);
            // -- vpmaddwd: cd + ab*2^12 per word pair.
            let packed = _mm512_madd_epi16(merged, madd2);
            // -- vpermb: compact 3-of-4 with byte-order fixup.
            let shuffled = _mm512_permutexvar_epi8(pack, packed);
            _mm512_mask_storeu_epi8(dst as *mut i8, out48, shuffled);
        }
        // -- vpmovb2m, once per stream.
        _mm512_movepi8_mask(error) as u64
    }

    /// Stream `lines` whole cache lines from `src` to the 64-byte-aligned
    /// `dst` with `_mm512_stream_si512` (one non-temporal store per
    /// line; unaligned loads are fine). No fence is issued — see the
    /// `sfence` contract in [`crate::base64::stores`]: the caller fences
    /// once at kernel exit on the issuing thread.
    ///
    /// # Safety
    /// `dst` must be 64-byte aligned when `lines > 0`, both pointers
    /// must cover `lines * 64` bytes, and the host must support
    /// AVX-512F (the engine's tier clamp guarantees it on the AVX-512
    /// tier). A `lines == 0` call is a no-op and carries no alignment
    /// requirement (the peel of a copy shorter than one line never
    /// reaches an aligned address).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn nt_store_lines(dst: *mut u8, src: *const u8, lines: usize) {
        debug_assert!(lines == 0 || dst as usize % 64 == 0, "NT stores require aligned lines");
        for i in 0..lines {
            let v = _mm512_loadu_si512(src.add(i * 64) as *const _);
            _mm512_stream_si512(dst.add(i * 64) as *mut _, v);
        }
    }

    /// Mask-and-compress whitespace compaction: classify the skipped
    /// bytes with `vpcmpeqb` k-mask compares, then compact the kept
    /// bytes in-register with `vpcompressb` (`_mm512_maskz_compress_epi8`)
    /// and advance the destination by the mask popcount — irregular byte
    /// *removal* fused into the wide loop with no per-byte branches.
    /// Requires 64 writable bytes of headroom in `dst` per iteration
    /// (the full register is stored; the slack is overwritten by the
    /// next store or ignored by the returned count).
    /// Returns `(src_consumed, dst_written)`.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi2")]
    pub unsafe fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
        let cr = _mm512_set1_epi8(b'\r' as i8);
        let lf = _mm512_set1_epi8(b'\n' as i8);
        let sp = _mm512_set1_epi8(b' ' as i8);
        let ht = _mm512_set1_epi8(b'\t' as i8);
        let all = ws == Whitespace::All;
        let (mut r, mut w) = (0usize, 0usize);
        while r + 64 <= src.len() && w + 64 <= dst.len() {
            let v = _mm512_loadu_si512(src.as_ptr().add(r) as *const _);
            let mut skip: __mmask64 =
                _mm512_cmpeq_epi8_mask(v, cr) | _mm512_cmpeq_epi8_mask(v, lf);
            if all {
                skip |= _mm512_cmpeq_epi8_mask(v, sp) | _mm512_cmpeq_epi8_mask(v, ht);
            }
            let keep = !skip;
            let packed = _mm512_maskz_compress_epi8(keep, v);
            _mm512_storeu_si512(dst.as_mut_ptr().add(w) as *mut _, packed);
            w += keep.count_ones() as usize;
            r += 64;
        }
        let (rt, wt) = crate::base64::swar::compact_ws(&src[r..], &mut dst[w..], ws);
        (r + rt, w + wt)
    }
}

/// Safe wrapper over [`kernels::compact_ws`]; the engine stores this as
/// its compaction function on AVX-512 VBMI2 hosts.
#[cfg(target_arch = "x86_64")]
pub(crate) fn compact_ws(src: &[u8], dst: &mut [u8], ws: Whitespace) -> (usize, usize) {
    debug_assert!(Avx512Codec::vbmi2_available());
    // SAFETY: the engine only selects this function after
    // `Avx512Codec::vbmi2_available()` returned true.
    unsafe { kernels::compact_ws(src, dst, ws) }
}

impl Avx512Codec {
    /// Bulk slice core: encode whole 48-byte blocks into `out[0..]` with
    /// the §3.1 instruction sequence, returning the bytes consumed.
    pub(crate) fn encode_bulk(&self, input: &[u8], out: &mut [u8]) -> usize {
        let blocks_len = input.len() / RAW_BLOCK * RAW_BLOCK;
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: availability asserted at construction; slices sized
            // to whole blocks.
            unsafe {
                kernels::encode_blocks(
                    &input[..blocks_len],
                    &mut out[..blocks_len / RAW_BLOCK * B64_BLOCK],
                    self.alphabet.encode_table().as_bytes(),
                );
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            self.scalar_twin.encode_bulk(&input[..blocks_len], out);
        }
        blocks_len
    }

    /// Bulk slice core: decode whole 64-char blocks into `out[0..]` with
    /// the deferred error accumulator (one `vpmovb2m` per stream),
    /// returning the chars consumed. On failure the input is re-scanned
    /// for the exact offending byte (cold path).
    pub(crate) fn decode_bulk(&self, body: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let blocks_len = body.len() / B64_BLOCK * B64_BLOCK;
        #[cfg(target_arch = "x86_64")]
        let err_mask = {
            // SAFETY: see encode_bulk.
            unsafe {
                kernels::decode_blocks(
                    &body[..blocks_len],
                    &mut out[..blocks_len / B64_BLOCK * RAW_BLOCK],
                    self.alphabet.decode_table().as_bytes(),
                )
            }
        };
        #[cfg(not(target_arch = "x86_64"))]
        let err_mask: u64 = {
            self.scalar_twin.decode_bulk(&body[..blocks_len], out)?;
            0
        };
        if err_mask != 0 {
            // Deferred check fired: re-scan for the exact byte (cold).
            let bad = first_invalid(&body[..blocks_len], self.alphabet.decode_table().as_bytes())
                .expect("vpmovb2m mask set implies an invalid byte");
            return Err(DecodeError::InvalidByte { offset: bad, byte: body[bad] });
        }
        Ok(blocks_len)
    }
}

impl Codec for Avx512Codec {
    fn name(&self) -> &'static str {
        "avx512"
    }

    fn encode_slice(&self, input: &[u8], out: &mut [u8]) -> usize {
        let total = encoded_len(input.len());
        assert!(out.len() >= total, "output buffer too small");
        let consumed = self.encode_bulk(input, out);
        let w = consumed / 3 * 4;
        // Scalar epilogue for the remainder (paper §3.1).
        self.scalar_twin.encode_slice(&input[consumed..], &mut out[w..]);
        total
    }

    fn decode_slice(&self, input: &[u8], out: &mut [u8]) -> Result<usize, DecodeError> {
        let (body, tail) = split_tail(input, self.alphabet.pad(), self.mode)?;
        let consumed = self.decode_bulk(body, out)?;
        let mut w = consumed / 4 * 3;
        // Sub-block remainder + padded tail: scalar path.
        w += decode_quads_into(
            &body[consumed..],
            self.alphabet.decode_table().as_bytes(),
            consumed,
            &mut out[w..],
        )?;
        let t = decode_tail_into(
            tail,
            self.alphabet.pad(),
            self.mode,
            body.len(),
            |c| self.alphabet.value_of(c),
            &mut out[w..],
        )?;
        Ok(w + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::scalar::ScalarCodec;
    use crate::workload::random_bytes;

    fn skip() -> bool {
        if !Avx512Codec::available() {
            eprintln!("skipping: no AVX-512 VBMI on this host");
            return true;
        }
        false
    }

    #[test]
    fn rfc4648_vectors() {
        if skip() {
            return;
        }
        let c = Avx512Codec::new(Alphabet::standard());
        for (raw, enc) in [
            (&b""[..], &b""[..]),
            (b"f", b"Zg=="),
            (b"fo", b"Zm8="),
            (b"foo", b"Zm9v"),
            (b"foobar", b"Zm9vYmFy"),
        ] {
            assert_eq!(c.encode(raw), enc);
            assert_eq!(c.decode(enc).unwrap(), raw);
        }
    }

    #[test]
    fn agrees_with_scalar_across_lengths() {
        if skip() {
            return;
        }
        let s = ScalarCodec::new(Alphabet::standard());
        let c = Avx512Codec::new(Alphabet::standard());
        for len in 0..400usize {
            let data = random_bytes(len, len as u64);
            assert_eq!(c.encode(&data), s.encode(&data), "len={len}");
            let enc = s.encode(&data);
            assert_eq!(c.decode(&enc).unwrap(), data, "len={len}");
        }
    }

    #[test]
    fn large_roundtrip() {
        if skip() {
            return;
        }
        let c = Avx512Codec::new(Alphabet::standard());
        let data = random_bytes(1 << 20, 99);
        let enc = c.encode(&data);
        assert_eq!(c.decode(&enc).unwrap(), data);
    }

    #[test]
    fn error_detection_every_position() {
        if skip() {
            return;
        }
        let c = Avx512Codec::new(Alphabet::standard());
        let enc = c.encode(&random_bytes(48 * 4, 7));
        for pos in 0..enc.len() {
            let mut bad = enc.clone();
            bad[pos] = b'!';
            match c.decode(&bad) {
                Err(DecodeError::InvalidByte { offset, byte: b'!' }) => {
                    assert_eq!(offset, pos)
                }
                other => panic!("pos {pos}: {other:?}"),
            }
        }
    }

    #[test]
    fn non_ascii_detected() {
        if skip() {
            return;
        }
        let c = Avx512Codec::new(Alphabet::standard());
        let mut enc = c.encode(&random_bytes(480, 3));
        enc[100] = 0xC3;
        assert!(matches!(
            c.decode(&enc),
            Err(DecodeError::InvalidByte { offset: 100, byte: 0xC3 })
        ));
    }

    #[test]
    fn runtime_variants() {
        if skip() {
            return;
        }
        // The paper's §5 claim with real vpermb registers: change only
        // the tables, same code path.
        let data = random_bytes(1000, 5);
        for alphabet in [Alphabet::standard(), Alphabet::url(), Alphabet::imap()] {
            let c = Avx512Codec::new(alphabet.clone());
            let s = ScalarCodec::new(alphabet);
            let enc = c.encode(&data);
            assert_eq!(enc, s.encode(&data));
            assert_eq!(c.decode(&enc).unwrap(), data);
        }
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn vpcompressb_compaction_matches_scalar_reference() {
        if !Avx512Codec::vbmi2_available() {
            eprintln!("skipping: no AVX-512 VBMI2 on this host");
            return;
        }
        use crate::base64::validate::Whitespace;
        let mut x: u32 = 0xACE1;
        for len in [0usize, 1, 63, 64, 65, 128, 200, 1024] {
            let src: Vec<u8> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                    match x >> 29 {
                        0 => b'\r',
                        1 => b'\n',
                        2 => b' ',
                        _ => b'A' + (x >> 24 & 0x0F) as u8,
                    }
                })
                .collect();
            for ws in [Whitespace::CrLf, Whitespace::All] {
                for cap in [len, len / 2, 100] {
                    let mut a = vec![0u8; cap];
                    let mut b = vec![0u8; cap];
                    let got = compact_ws(&src, &mut a, ws);
                    let want = crate::base64::scalar::compact_ws(&src, &mut b, ws);
                    assert_eq!(got, want, "len={len} cap={cap} ws={ws:?}");
                    assert_eq!(a[..got.1], b[..want.1], "len={len} cap={cap} ws={ws:?}");
                }
            }
        }
    }

    #[test]
    fn padding_char_rejected_in_block_body() {
        if skip() {
            return;
        }
        let c = Avx512Codec::new(Alphabet::standard());
        let mut enc = c.encode(&random_bytes(96, 1));
        enc[10] = b'=';
        assert!(c.decode(&enc).is_err());
    }
}
