//! Incremental (chunked) encoding/decoding on the tiered [`Engine`].
//!
//! The paper's codecs are one-shot over a contiguous buffer; a serving
//! system receives payloads in chunks. These adapters keep a
//! **block-aligned carry buffer** — up to one raw block (48 bytes) on the
//! encoder, up to one encoded block (64 chars) plus a held-back padded
//! quantum on the decoder — and hand every whole block to the same
//! tier-dispatched SIMD kernels the one-shot calls use, so chunked
//! sessions (coordinator [`crate::coordinator::state`], server
//! [`crate::server`]) run at engine speed regardless of how the input is
//! framed. The decoder also applies a [`Whitespace`] policy, skipping
//! CR/LF (or all whitespace) without a strip pass, and reports error
//! offsets in *raw stream* coordinates (whitespace included). The
//! encoder can CRLF-wrap its output directly
//! ([`StreamingEncoder::new_wrapped`]) via a line-position carry, so
//! chunked MIME encodes no longer need a wrapping pass at the framing
//! layer — the chunked output is byte-identical to the one-shot
//! [`Engine::encode_wrapped_slice`].
//!
//! Validation follows the paper's deferred-error model: bulk bytes are
//! checked when their block is decoded (which may be a later `update`
//! call or `finish`, once the carry fills), not on arrival; padding
//! ordering is enforced eagerly. The hot paths perform no heap
//! allocation beyond growing the caller's output `Vec` — with reserved
//! capacity they allocate nothing (asserted in `rust/tests/alloc.rs`).
//!
//! The bulk paths (whole blocks taken straight from a chunk) run the
//! engine's slice cores, so the engine's `Auto` store policy applies
//! per chunk: a session fed multi-megabyte chunks streams its output
//! with non-temporal stores exactly like the one-shot calls, while
//! small chunks stay on the temporal path
//! (see [`crate::base64::stores`]).

use super::engine::Engine;
use super::swar::find_ws;
use super::validate::{decode_tail, DecodeError, Mode, Whitespace};
use super::{encoded_len, Alphabet, Codec, B64_BLOCK, RAW_BLOCK};

/// Wrapped-encode staging: raw bytes encoded per batch (a multiple of
/// [`RAW_BLOCK`], so every batch but the last is padding-free) and the
/// chars they produce.
const ENC_STAGE_RAW: usize = 3072;
const ENC_STAGE_B64: usize = 4096;

/// Line-position carry for CRLF-wrapped streaming encode: where on the
/// current output line the stream stands, preserved across chunks.
struct Wrap {
    line_len: usize,
    line_pos: usize,
}

/// Incremental encoder: feed arbitrary chunks, finish once.
///
/// With [`StreamingEncoder::new_wrapped`] the output is CRLF-wrapped at
/// a fixed line length (RFC 2045 style) as it is emitted — the
/// line-position carry spans chunk boundaries, so chunked MIME encodes
/// produce ready-to-frame text byte-identical to a one-shot
/// [`Engine::encode_wrapped_slice`] over the concatenated input,
/// regardless of how the input was chunked. The final line carries no
/// trailing CRLF (separators are emitted lazily, before the chars that
/// start the next line).
pub struct StreamingEncoder {
    engine: Engine,
    /// 0..48 raw bytes carried until a full block is available.
    carry: [u8; RAW_BLOCK],
    carry_len: usize,
    /// Total raw bytes consumed (for observability).
    consumed: u64,
    /// CRLF wrapping state; `None` emits flat base64.
    wrap: Option<Wrap>,
}

impl StreamingEncoder {
    /// Flat (unwrapped) encoder at the host's best tier.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::from_engine(Engine::new(alphabet))
    }

    /// Build on an explicitly configured engine (tier pinning, mode).
    pub fn from_engine(engine: Engine) -> Self {
        Self { engine, carry: [0; RAW_BLOCK], carry_len: 0, consumed: 0, wrap: None }
    }

    /// Encoder whose output is CRLF-wrapped at `line_len` chars per
    /// line. `line_len` must be a positive multiple of 4 (the same
    /// domain [`Engine::encode_wrapped_slice`] accepts, so the two are
    /// parity-comparable).
    pub fn new_wrapped(alphabet: Alphabet, line_len: usize) -> Self {
        Self::from_engine_wrapped(Engine::new(alphabet), line_len)
    }

    /// [`Self::new_wrapped`] on an explicitly configured engine.
    pub fn from_engine_wrapped(engine: Engine, line_len: usize) -> Self {
        assert!(
            line_len >= 4 && line_len % 4 == 0,
            "line length must be a positive multiple of 4"
        );
        let mut s = Self::from_engine(engine);
        s.wrap = Some(Wrap { line_len, line_pos: 0 });
        s
    }

    /// The engine this stream dispatches to.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Encode `chunk`, appending complete blocks to `out`. Bytes that do
    /// not fill a 48-byte block are carried to the next call, so all bulk
    /// work stays on the tier's SIMD kernel.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) {
        self.consumed += chunk.len() as u64;
        let mut chunk = chunk;
        // Top the carry up to a whole block first.
        if self.carry_len > 0 {
            let take = (RAW_BLOCK - self.carry_len).min(chunk.len());
            self.carry[self.carry_len..self.carry_len + take].copy_from_slice(&chunk[..take]);
            self.carry_len += take;
            chunk = &chunk[take..];
            if self.carry_len < RAW_BLOCK {
                return;
            }
            let block = self.carry;
            self.carry_len = 0;
            // A whole block encodes without padding.
            self.encode_emit(&block, out);
        }
        // Bulk: whole blocks straight from the chunk.
        let whole = chunk.len() / RAW_BLOCK * RAW_BLOCK;
        if self.wrap.is_none() {
            self.engine.encode_into(&chunk[..whole], out);
        } else {
            // Wrapped: stage a batch of chars, then distribute across
            // lines. Batches are RAW_BLOCK multiples → padding-free, so
            // staged outputs concatenate exactly.
            let mut r = 0;
            while r < whole {
                let take = ENC_STAGE_RAW.min(whole - r);
                self.encode_emit(&chunk[r..r + take], out);
                r += take;
            }
        }
        // Stash the sub-block remainder.
        let rest = &chunk[whole..];
        self.carry[..rest.len()].copy_from_slice(rest);
        self.carry_len = rest.len();
    }

    /// Flush the final partial block (emits padding). Returns total raw
    /// bytes consumed over the stream's lifetime.
    pub fn finish(mut self, out: &mut Vec<u8>) -> u64 {
        if self.carry_len > 0 {
            let n = self.carry_len;
            self.carry_len = 0;
            let block = self.carry;
            self.encode_emit(&block[..n], out);
        }
        self.consumed
    }

    /// Encode one bounded batch (≤ [`ENC_STAGE_RAW`] bytes) and append
    /// it flat or line-wrapped.
    fn encode_emit(&mut self, input: &[u8], out: &mut Vec<u8>) {
        debug_assert!(input.len() <= ENC_STAGE_RAW);
        if self.wrap.is_none() {
            self.engine.encode_into(input, out);
            return;
        }
        let mut stage = [0u8; ENC_STAGE_B64];
        let n = self.engine.encode_slice(input, &mut stage[..encoded_len(input.len())]);
        self.emit_wrapped(&stage[..n], out);
    }

    /// Append `chars` to `out`, inserting a CRLF before the chars that
    /// start each new line (lazy separators: the stream never ends with
    /// a dangling CRLF).
    fn emit_wrapped(&mut self, chars: &[u8], out: &mut Vec<u8>) {
        let w = self.wrap.as_mut().expect("wrapped emission without wrap state");
        let mut i = 0;
        while i < chars.len() {
            if w.line_pos == w.line_len {
                out.extend_from_slice(b"\r\n");
                w.line_pos = 0;
            }
            let take = (w.line_len - w.line_pos).min(chars.len() - i);
            out.extend_from_slice(&chars[i..i + take]);
            w.line_pos += take;
            i += take;
        }
    }
}

/// Decoder carry capacity: one encoded block plus a held-back padded
/// quantum (the stream's final quantum may straddle a block boundary).
const DEC_CARRY: usize = B64_BLOCK + 4;

/// Incremental decoder: feed arbitrary chunks, finish once.
///
/// Validation is deferred per the paper: a byte is checked when the
/// block holding it decodes — possibly a later `update` or `finish` —
/// with error offsets still exact (raw stream coordinates). Padding
/// ordering is enforced eagerly.
pub struct StreamingDecoder {
    engine: Engine,
    ws: Whitespace,
    /// Significant chars awaiting a whole block / stream end.
    carry: [u8; DEC_CARRY],
    /// Raw-stream offset of each carried char (whitespace-aware error
    /// reporting across chunk boundaries).
    carry_off: [u64; DEC_CARRY],
    carry_len: usize,
    /// Raw bytes consumed so far (including skipped whitespace).
    raw_offset: u64,
    /// Significant (non-skipped) chars seen so far.
    stripped: u64,
    /// Set once padding has been seen — only more padding may follow.
    saw_pad: bool,
}

impl StreamingDecoder {
    /// Strict decoder at the host's best tier, no whitespace skipping.
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_policy(alphabet, Mode::Strict, Whitespace::None)
    }

    /// [`Self::new`] with an explicit strictness mode.
    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        Self::with_policy(alphabet, mode, Whitespace::None)
    }

    /// Full constructor: strictness plus whitespace policy (the chunked
    /// MIME path).
    pub fn with_policy(alphabet: Alphabet, mode: Mode, ws: Whitespace) -> Self {
        Self::from_engine(Engine::with_mode(alphabet, mode), ws)
    }

    /// Build on an explicitly configured engine (tier pinning).
    pub fn from_engine(engine: Engine, ws: Whitespace) -> Self {
        Self {
            engine,
            ws,
            carry: [0; DEC_CARRY],
            carry_off: [0; DEC_CARRY],
            carry_len: 0,
            raw_offset: 0,
            stripped: 0,
            saw_pad: false,
        }
    }

    /// The engine this stream dispatches to.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Decode `chunk`, appending raw bytes to `out`. Quanta spanning
    /// chunk boundaries are carried; whitespace is skipped per the
    /// policy; padding may only appear at stream end.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) -> Result<(), DecodeError> {
        let chunk_base = self.raw_offset;
        // Split the chunk into significant runs around skipped bytes so
        // the bulk path below never sees whitespace.
        let mut rel = 0usize;
        while rel < chunk.len() {
            if self.ws.skips(chunk[rel]) {
                rel += 1;
                continue;
            }
            let run_len = find_ws(&chunk[rel..], self.ws).unwrap_or(chunk.len() - rel);
            self.process_run(&chunk[rel..rel + run_len], chunk_base + rel as u64, out)?;
            rel += run_len;
        }
        self.raw_offset = chunk_base + chunk.len() as u64;
        Ok(())
    }

    /// Handle one whitespace-free run starting at raw offset `base`.
    fn process_run(
        &mut self,
        run: &[u8],
        base: u64,
        out: &mut Vec<u8>,
    ) -> Result<(), DecodeError> {
        self.stripped += run.len() as u64;
        if self.saw_pad {
            return self.push_padding(run, base);
        }
        let pad = self.engine.alphabet().pad();
        match run.iter().position(|&c| c == pad) {
            None => self.process_data(run, base, out),
            Some(p) => {
                self.process_data(&run[..p], base, out)?;
                self.saw_pad = true;
                self.push_padding(&run[p..], base + p as u64)
            }
        }
    }

    /// After the first pad char, only pad chars may follow, and the final
    /// quantum is bounded — anything else is an ordering error.
    fn push_padding(&mut self, bytes: &[u8], base: u64) -> Result<(), DecodeError> {
        let pad = self.engine.alphabet().pad();
        for (j, &c) in bytes.iter().enumerate() {
            if c != pad || self.carry_len == DEC_CARRY {
                return Err(DecodeError::InvalidPadding { offset: (base + j as u64) as usize });
            }
            self.carry[self.carry_len] = c;
            self.carry_off[self.carry_len] = base + j as u64;
            self.carry_len += 1;
        }
        Ok(())
    }

    /// Pad-free significant bytes: top the carry up to a whole block,
    /// bulk-decode whole blocks straight from the run, stash the rest.
    fn process_data(
        &mut self,
        data: &[u8],
        base: u64,
        out: &mut Vec<u8>,
    ) -> Result<(), DecodeError> {
        let mut data = data;
        let mut base = base;
        if self.carry_len > 0 {
            let take = (B64_BLOCK - self.carry_len).min(data.len());
            for j in 0..take {
                self.carry[self.carry_len + j] = data[j];
                self.carry_off[self.carry_len + j] = base + j as u64;
            }
            self.carry_len += take;
            data = &data[take..];
            base += take as u64;
            if self.carry_len < B64_BLOCK {
                return Ok(());
            }
            // Carry reached a whole block: decode it through the engine.
            let carried = self.carry_len;
            self.carry_len = 0;
            if let Err(e) = self.engine.decode_quanta_into(&self.carry[..carried], out) {
                return Err(self.rebase_carry_err(e));
            }
        }
        // Bulk: whole blocks directly from the run (block-aligned, so the
        // tier kernel does all the work).
        let whole = data.len() / B64_BLOCK * B64_BLOCK;
        if whole > 0 {
            self.engine
                .decode_quanta_into(&data[..whole], out)
                .map_err(|e| rebase_raw(e, base))?;
        }
        // Stash the sub-block remainder with its raw offsets.
        for (j, &c) in data[whole..].iter().enumerate() {
            self.carry[j] = c;
            self.carry_off[j] = base + (whole + j) as u64;
        }
        self.carry_len = data.len() - whole;
        Ok(())
    }

    /// Map an error whose offset indexes the carry buffer back to raw
    /// stream coordinates.
    fn rebase_carry_err(&self, e: DecodeError) -> DecodeError {
        e.map_offset(|offset| self.carry_off[offset] as usize)
    }

    /// Finish the stream: decode the carried residue (possibly padded)
    /// and enforce length/padding rules. Returns total raw bytes
    /// consumed.
    pub fn finish(mut self, out: &mut Vec<u8>) -> Result<u64, DecodeError> {
        let n = self.carry_len;
        if n == 0 {
            return Ok(self.raw_offset);
        }
        if self.engine.mode() == Mode::Strict && self.stripped % 4 != 0 {
            return Err(DecodeError::InvalidLength { len: self.stripped as usize });
        }
        let carry = self.carry;
        let (body, tail) = super::validate::split_tail(
            &carry[..n],
            self.engine.alphabet().pad(),
            self.engine.mode(),
        )
        .map_err(|e| match e {
            DecodeError::InvalidLength { .. } => {
                DecodeError::InvalidLength { len: self.stripped as usize }
            }
            other => self.rebase_carry_err(other),
        })?;
        if !body.is_empty() {
            self.engine
                .decode_quanta_into(body, out)
                .map_err(|e| self.rebase_carry_err(e))?;
        }
        let tail_start = body.len();
        decode_tail(
            tail,
            self.engine.alphabet().pad(),
            self.engine.mode(),
            0,
            |c| self.engine.alphabet().value_of(c),
            out,
        )
        .map_err(|e| match e {
            DecodeError::InvalidLength { .. } => {
                DecodeError::InvalidLength { len: self.stripped as usize }
            }
            // Offsets from the tail decode index `tail`; shift them into
            // the carry and map through the recorded raw offsets.
            other => other.map_offset(|offset| self.carry_off[tail_start + offset] as usize),
        })?;
        self.carry_len = 0;
        Ok(self.raw_offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base64::block::BlockCodec;

    fn enc_ref(data: &[u8]) -> Vec<u8> {
        BlockCodec::new(Alphabet::standard()).encode(data)
    }

    #[test]
    fn encoder_chunking_invariance() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let expect = enc_ref(&data);
        for chunk_size in [1usize, 2, 3, 7, 47, 48, 49, 64, 333] {
            let mut enc = StreamingEncoder::new(Alphabet::standard());
            let mut out = vec![];
            for chunk in data.chunks(chunk_size) {
                enc.update(chunk, &mut out);
            }
            let consumed = enc.finish(&mut out);
            assert_eq!(consumed, 1000);
            assert_eq!(out, expect, "chunk_size={chunk_size}");
        }
    }

    #[test]
    fn wrapped_encoder_parity_with_one_shot_across_chunkings() {
        // The line-position carry must make chunked wrapped output
        // byte-identical to Engine::encode_wrapped_slice, for every
        // chunking and for line lengths crossing the 48-byte block and
        // 3072-byte stage boundaries.
        let e = Engine::new(Alphabet::standard());
        for len in [0usize, 1, 3, 57, 76, 100, 997, 3072, 3073, 10_000] {
            let data: Vec<u8> = (0..len).map(|i| (i * 131 % 256) as u8).collect();
            for line_len in [4usize, 60, 76] {
                let mut expect = vec![0u8; e.encoded_wrapped_len(len, line_len)];
                let n = e.encode_wrapped_slice(&data, &mut expect, line_len);
                expect.truncate(n);
                for chunk_size in [1usize, 7, 47, 48, 49, 76, 333, 4096] {
                    let mut enc = StreamingEncoder::new_wrapped(Alphabet::standard(), line_len);
                    let mut out = vec![];
                    for chunk in data.chunks(chunk_size.max(1)) {
                        enc.update(chunk, &mut out);
                    }
                    let consumed = enc.finish(&mut out);
                    assert_eq!(consumed, len as u64);
                    assert_eq!(
                        out, expect,
                        "len={len} line_len={line_len} chunk={chunk_size}"
                    );
                }
            }
        }
    }

    #[test]
    fn wrapped_encoder_roundtrips_through_ws_decoder() {
        // Wrapped streaming output feeds straight back through the
        // whitespace-tolerant streaming decoder.
        let data: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        let mut enc = StreamingEncoder::new_wrapped(Alphabet::standard(), 76);
        let mut wrapped = vec![];
        for chunk in data.chunks(233) {
            enc.update(chunk, &mut wrapped);
        }
        enc.finish(&mut wrapped);
        let mut dec =
            StreamingDecoder::with_policy(Alphabet::standard(), Mode::Strict, Whitespace::CrLf);
        let mut back = vec![];
        for chunk in wrapped.chunks(101) {
            dec.update(chunk, &mut back).unwrap();
        }
        dec.finish(&mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn wrapped_encoder_rejects_bad_line_len() {
        let _ = StreamingEncoder::new_wrapped(Alphabet::standard(), 70);
    }

    #[test]
    fn decoder_chunking_invariance() {
        let data: Vec<u8> = (0..=255u8).cycle().take(997).collect();
        let encoded = enc_ref(&data);
        for chunk_size in [1usize, 3, 4, 5, 63, 64, 65, 256] {
            let mut dec = StreamingDecoder::new(Alphabet::standard());
            let mut out = vec![];
            for chunk in encoded.chunks(chunk_size) {
                dec.update(chunk, &mut out).unwrap();
            }
            dec.finish(&mut out).unwrap();
            assert_eq!(out, data, "chunk_size={chunk_size}");
        }
    }

    #[test]
    fn decoder_ws_policy_chunking_invariance() {
        // Wrapped MIME text straight through the streaming decoder: the
        // CRLFs are skipped inline, no pre-stripping.
        let data: Vec<u8> = (0..=255u8).cycle().take(997).collect();
        let mime = crate::base64::mime::MimeCodec::new(Alphabet::standard());
        let wrapped = mime.encode(&data);
        for chunk_size in [1usize, 3, 4, 5, 63, 64, 65, 76, 78, 256, 333] {
            let mut dec =
                StreamingDecoder::with_policy(Alphabet::standard(), Mode::Strict, Whitespace::CrLf);
            let mut out = vec![];
            for chunk in wrapped.chunks(chunk_size) {
                dec.update(chunk, &mut out).unwrap();
            }
            let consumed = dec.finish(&mut out).unwrap();
            assert_eq!(consumed, wrapped.len() as u64);
            assert_eq!(out, data, "chunk_size={chunk_size}");
        }
    }

    #[test]
    fn decoder_rejects_data_after_padding() {
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        let r = dec
            .update(b"Zm8=", &mut out)
            .and_then(|_| dec.update(b"Zm9v", &mut out));
        assert!(matches!(r, Err(DecodeError::InvalidPadding { .. })));
    }

    #[test]
    fn decoder_error_offset_across_chunks() {
        // Validation is deferred to block granularity (paper §3.2): the
        // bad byte is reported when its block decodes — here at finish,
        // since 12 chars never fill the 64-char carry — with the offset
        // still exact in stream coordinates.
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        dec.update(b"AAAABBBB", &mut out).unwrap();
        dec.update(b"CC!C", &mut out).unwrap();
        let err = dec.finish(&mut out).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 10, byte: b'!' });
    }

    #[test]
    fn decoder_error_offset_in_bulk_block() {
        // A bad byte inside a whole block is caught by the update that
        // decodes the block, offset in raw coordinates.
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        let mut chunk = vec![b'A'; 2 * B64_BLOCK];
        chunk[100] = 0x07;
        let err = dec.update(&chunk, &mut out).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 100, byte: 0x07 });
    }

    #[test]
    fn decoder_ws_error_offsets_are_raw() {
        // "Zm9v\r\n!..." — the '!' is at raw offset 6 even though it is
        // the 5th significant char.
        let mut dec =
            StreamingDecoder::with_policy(Alphabet::standard(), Mode::Strict, Whitespace::CrLf);
        let mut out = vec![];
        dec.update(b"Zm9v\r\n", &mut out).unwrap();
        dec.update(b"!mFy", &mut out).unwrap();
        let err = dec.finish(&mut out).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 6, byte: b'!' });
    }

    #[test]
    fn decoder_strict_rejects_trailing_fragment() {
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        dec.update(b"AAAABB", &mut out).unwrap();
        assert!(matches!(
            dec.finish(&mut out),
            Err(DecodeError::InvalidLength { len: 6 })
        ));
    }

    #[test]
    fn decoder_forgiving_accepts_unpadded_tail() {
        let mut dec = StreamingDecoder::with_mode(Alphabet::standard(), Mode::Forgiving);
        let mut out = vec![];
        dec.update(b"Zm9vYmE", &mut out).unwrap();
        dec.finish(&mut out).unwrap();
        assert_eq!(out, b"fooba");
    }

    #[test]
    fn decoder_large_stream_hits_bulk_path() {
        // > one block per update, plus a padded tail quantum.
        let data: Vec<u8> = (0..100_001).map(|i| (i * 131 % 256) as u8).collect();
        let encoded = enc_ref(&data);
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        for chunk in encoded.chunks(1500) {
            dec.update(chunk, &mut out).unwrap();
        }
        dec.finish(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn empty_stream() {
        let enc = StreamingEncoder::new(Alphabet::standard());
        let mut out = vec![];
        assert_eq!(enc.finish(&mut out), 0);
        assert!(out.is_empty());
        let dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        assert_eq!(dec.finish(&mut out).unwrap(), 0);
    }
}

/// Shift a raw-relative error by `base` (bulk path straight from a run).
fn rebase_raw(e: DecodeError, base: u64) -> DecodeError {
    e.map_offset(|offset| (base + offset as u64) as usize)
}
