//! Incremental (chunked) encoding/decoding with carry state.
//!
//! The paper's codecs are one-shot over a contiguous buffer; a serving
//! system receives payloads in chunks. These adapters maintain the 0–2
//! raw-byte (encoder) / 0–3 char (decoder) carry between chunks and drive
//! the block codec over every full block, so the hot path stays on the
//! paper's algorithm regardless of how the input is framed. They also
//! back the per-connection session state in
//! [`crate::coordinator::state`].

use super::block::BlockCodec;
use super::validate::{decode_tail, DecodeError, Mode};
use super::{Alphabet, Codec};

/// Incremental encoder: feed arbitrary chunks, finish once.
pub struct StreamingEncoder {
    codec: BlockCodec,
    /// 0..3 raw bytes carried until a full 3-byte group is available.
    carry: [u8; 3],
    carry_len: usize,
    /// Total raw bytes consumed (for observability).
    consumed: u64,
}

impl StreamingEncoder {
    pub fn new(alphabet: Alphabet) -> Self {
        Self {
            codec: BlockCodec::new(alphabet),
            carry: [0; 3],
            carry_len: 0,
            consumed: 0,
        }
    }

    /// Encode `chunk`, appending complete quanta to `out`. Bytes that do
    /// not fill a 3-byte group are carried to the next call.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) {
        self.consumed += chunk.len() as u64;
        let mut chunk = chunk;
        // Complete the carry group first.
        if self.carry_len > 0 {
            let need = 3 - self.carry_len;
            let take = need.min(chunk.len());
            self.carry[self.carry_len..self.carry_len + take].copy_from_slice(&chunk[..take]);
            self.carry_len += take;
            chunk = &chunk[take..];
            if self.carry_len < 3 {
                return;
            }
            let group = self.carry;
            self.carry_len = 0;
            // A full group encodes without padding.
            self.codec.encode_into(&group, out);
        }
        // Bulk: all whole 3-byte groups go through the block codec (whole
        // 48-byte blocks inside) without padding.
        let whole = chunk.len() - chunk.len() % 3;
        self.codec.encode_into(&chunk[..whole], out);
        // Stash the remainder.
        let rest = &chunk[whole..];
        self.carry[..rest.len()].copy_from_slice(rest);
        self.carry_len = rest.len();
    }

    /// Flush the final partial group (emits padding). Returns total raw
    /// bytes consumed over the stream's lifetime.
    pub fn finish(mut self, out: &mut Vec<u8>) -> u64 {
        if self.carry_len > 0 {
            let group = &self.carry[..self.carry_len];
            self.codec.encode_into(group, out);
            self.carry_len = 0;
        }
        self.consumed
    }
}

/// Incremental decoder: feed arbitrary chunks, finish once.
///
/// Validation is deferred per the paper: each bulk call only checks its
/// own blocks' accumulated error; `finish` performs the final tail and
/// padding checks.
pub struct StreamingDecoder {
    codec: BlockCodec,
    alphabet: Alphabet,
    mode: Mode,
    /// 0..4 chars carried until a full quantum is available.
    carry: [u8; 4],
    carry_len: usize,
    /// Offset of the next input byte (for error reporting).
    offset: u64,
    /// Set once padding has been seen — only more padding may follow.
    saw_pad: bool,
}

impl StreamingDecoder {
    pub fn new(alphabet: Alphabet) -> Self {
        Self::with_mode(alphabet, Mode::Strict)
    }

    pub fn with_mode(alphabet: Alphabet, mode: Mode) -> Self {
        Self {
            codec: BlockCodec::with_mode(alphabet.clone(), mode),
            alphabet,
            mode,
            carry: [0; 4],
            carry_len: 0,
            offset: 0,
            saw_pad: false,
        }
    }

    fn check_pad_ordering(&mut self, chunk: &[u8]) -> Result<(), DecodeError> {
        let pad = self.alphabet.pad();
        for (i, &c) in chunk.iter().enumerate() {
            if self.saw_pad && c != pad {
                return Err(DecodeError::InvalidPadding {
                    offset: (self.offset + i as u64) as usize,
                });
            }
            if c == pad {
                self.saw_pad = true;
            }
        }
        Ok(())
    }

    /// Decode `chunk`, appending raw bytes to `out`. Quanta spanning chunk
    /// boundaries are carried. Padding may only appear at stream end.
    pub fn update(&mut self, chunk: &[u8], out: &mut Vec<u8>) -> Result<(), DecodeError> {
        self.check_pad_ordering(chunk)?;
        let pad = self.alphabet.pad();
        let mut chunk = chunk;
        // Once padding has started, just accumulate the final quantum.
        if self.saw_pad {
            // Move everything (data before pad is still in carry/body).
            for &c in chunk {
                if self.carry_len == 4 {
                    // A padded quantum is at most 4 chars: flush it first.
                    self.flush_carry(out)?;
                }
                self.carry[self.carry_len] = c;
                self.carry_len += 1;
                self.offset += 1;
            }
            return Ok(());
        }
        // Complete the carried quantum.
        if self.carry_len > 0 {
            let need = 4 - self.carry_len;
            let take = need.min(chunk.len());
            self.carry[self.carry_len..self.carry_len + take].copy_from_slice(&chunk[..take]);
            self.carry_len += take;
            self.offset += take as u64;
            chunk = &chunk[take..];
            if self.carry_len < 4 {
                return Ok(());
            }
            if self.carry.contains(&pad) {
                // Leave padded quantum for finish().
                return self.stash_rest(chunk);
            }
            self.flush_carry(out)?;
        }
        // Bulk: decode whole quanta that cannot be the padded tail. Keep
        // the last quantum if it might contain padding (conservatively: if
        // it contains the pad char) or if the chunk end is mid-quantum.
        let whole = chunk.len() - chunk.len() % 4;
        let (body, rest) = chunk.split_at(whole);
        let (body, held) = match body.chunks_exact(4).rposition(|q| q.contains(&pad)) {
            Some(_) => {
                // Some quantum in the body holds padding: it must be the
                // last one overall; decode up to it, stash it.
                let cut = body.len() - 4;
                (&body[..cut], &body[cut..])
            }
            None => (body, &[][..]),
        };
        let base = self.offset as usize;
        let mut tmp_err = self
            .codec
            .decode_full_blocks(body, out)
            .and(Ok(()));
        if let Err(DecodeError::InvalidByte { offset, byte }) = tmp_err {
            tmp_err = Err(DecodeError::InvalidByte { offset: base + offset, byte });
        }
        tmp_err?;
        // Sub-block remainder of the body (whole quanta, no padding).
        let consumed_blocks = body.len() / 64 * 64;
        for (q, quad) in body[consumed_blocks..].chunks_exact(4).enumerate() {
            self.decode_quad(quad, base + consumed_blocks + q * 4, out)?;
        }
        self.offset += body.len() as u64;
        // Stash held padded quantum + trailing partial.
        for &c in held.iter().chain(rest) {
            self.carry[self.carry_len] = c;
            self.carry_len += 1;
            self.offset += 1;
        }
        Ok(())
    }

    fn stash_rest(&mut self, chunk: &[u8]) -> Result<(), DecodeError> {
        for &c in chunk {
            if self.carry_len == 4 {
                return Err(DecodeError::InvalidPadding { offset: self.offset as usize });
            }
            self.carry[self.carry_len] = c;
            self.carry_len += 1;
            self.offset += 1;
        }
        Ok(())
    }

    fn flush_carry(&mut self, out: &mut Vec<u8>) -> Result<(), DecodeError> {
        let quad = self.carry;
        let base = self.offset as usize - self.carry_len;
        self.carry_len = 0;
        self.decode_quad(&quad, base, out)
    }

    fn decode_quad(&self, quad: &[u8], base: usize, out: &mut Vec<u8>) -> Result<(), DecodeError> {
        let table = self.alphabet.decode_table();
        let mut vals = [0u8; 4];
        for i in 0..4 {
            let c = quad[i];
            let v = table.lookup(c);
            if (c | v) & 0x80 != 0 {
                return Err(DecodeError::InvalidByte { offset: base + i, byte: c });
            }
            vals[i] = v;
        }
        out.push((vals[0] << 2) | (vals[1] >> 4));
        out.push((vals[1] << 4) | (vals[2] >> 2));
        out.push((vals[2] << 6) | vals[3]);
        Ok(())
    }

    /// Finish the stream: decode the final (possibly padded) quantum and
    /// enforce length/padding rules.
    pub fn finish(mut self, out: &mut Vec<u8>) -> Result<u64, DecodeError> {
        let tail = &self.carry[..self.carry_len];
        let base = self.offset as usize - self.carry_len;
        if tail.is_empty() {
            return Ok(self.offset);
        }
        if self.mode == Mode::Strict && self.carry_len != 4 {
            return Err(DecodeError::InvalidLength { len: self.offset as usize });
        }
        let tail = tail.to_vec();
        decode_tail(
            &tail,
            self.alphabet.pad(),
            self.mode,
            base,
            |c| self.alphabet.value_of(c),
            out,
        )?;
        self.carry_len = 0;
        Ok(self.offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc_ref(data: &[u8]) -> Vec<u8> {
        BlockCodec::new(Alphabet::standard()).encode(data)
    }

    #[test]
    fn encoder_chunking_invariance() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let expect = enc_ref(&data);
        for chunk_size in [1usize, 2, 3, 7, 47, 48, 49, 64, 333] {
            let mut enc = StreamingEncoder::new(Alphabet::standard());
            let mut out = vec![];
            for chunk in data.chunks(chunk_size) {
                enc.update(chunk, &mut out);
            }
            let consumed = enc.finish(&mut out);
            assert_eq!(consumed, 1000);
            assert_eq!(out, expect, "chunk_size={chunk_size}");
        }
    }

    #[test]
    fn decoder_chunking_invariance() {
        let data: Vec<u8> = (0..=255u8).cycle().take(997).collect();
        let encoded = enc_ref(&data);
        for chunk_size in [1usize, 3, 4, 5, 63, 64, 65, 256] {
            let mut dec = StreamingDecoder::new(Alphabet::standard());
            let mut out = vec![];
            for chunk in encoded.chunks(chunk_size) {
                dec.update(chunk, &mut out).unwrap();
            }
            dec.finish(&mut out).unwrap();
            assert_eq!(out, data, "chunk_size={chunk_size}");
        }
    }

    #[test]
    fn decoder_rejects_data_after_padding() {
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        let r = dec
            .update(b"Zm8=", &mut out)
            .and_then(|_| dec.update(b"Zm9v", &mut out));
        assert!(matches!(r, Err(DecodeError::InvalidPadding { .. })));
    }

    #[test]
    fn decoder_error_offset_across_chunks() {
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        dec.update(b"AAAABBBB", &mut out).unwrap();
        let err = dec.update(b"CC!C", &mut out).unwrap_err();
        assert_eq!(err, DecodeError::InvalidByte { offset: 10, byte: b'!' });
    }

    #[test]
    fn decoder_strict_rejects_trailing_fragment() {
        let mut dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        dec.update(b"AAAABB", &mut out).unwrap();
        assert!(matches!(
            dec.finish(&mut out),
            Err(DecodeError::InvalidLength { .. })
        ));
    }

    #[test]
    fn decoder_forgiving_accepts_unpadded_tail() {
        let mut dec = StreamingDecoder::with_mode(Alphabet::standard(), Mode::Forgiving);
        let mut out = vec![];
        dec.update(b"Zm9vYmE", &mut out).unwrap();
        dec.finish(&mut out).unwrap();
        assert_eq!(out, b"fooba");
    }

    #[test]
    fn empty_stream() {
        let enc = StreamingEncoder::new(Alphabet::standard());
        let mut out = vec![];
        assert_eq!(enc.finish(&mut out), 0);
        assert!(out.is_empty());
        let dec = StreamingDecoder::new(Alphabet::standard());
        let mut out = vec![];
        assert_eq!(dec.finish(&mut out).unwrap(), 0);
    }
}
