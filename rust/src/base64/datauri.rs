//! `data:` URI handling — the web workload behind Table 3's Google-logo
//! row (a base64 data URI embedded in the Google search page).
//!
//! Both [`build`] and [`parse`] are thin wrappers over the tiered
//! [`Engine`]: the standard alphabet reuses the process-wide cached
//! engine, encode writes straight into the URI's single output buffer
//! (no intermediate payload `Vec`), and decode allocates exactly the
//! payload's decoded size.

use super::engine::Engine;
use super::validate::DecodeError;
use super::{Alphabet, Codec};

/// Run `f` against an engine for `alphabet`, reusing the process-wide
/// cached engine when the standard variant is requested.
fn with_engine<R>(alphabet: &Alphabet, f: impl FnOnce(&Engine) -> R) -> R {
    if *alphabet == Alphabet::standard() {
        f(Engine::get())
    } else {
        f(&Engine::new(alphabet.clone()))
    }
}

/// A parsed `data:` URI with a base64 payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DataUri {
    /// MIME type, e.g. `image/png` (defaults to `text/plain` per RFC 2397).
    pub mime_type: String,
    /// Decoded payload bytes.
    pub data: Vec<u8>,
}

/// Errors from [`parse`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataUriError {
    /// Missing `data:` scheme prefix.
    NotADataUri,
    /// Missing the `,` separating the header from the payload.
    MissingComma,
    /// Header lacks the `;base64` marker (we only handle base64 payloads).
    NotBase64,
    /// The payload failed base64 decoding.
    Decode(DecodeError),
}

impl std::fmt::Display for DataUriError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NotADataUri => write!(f, "not a data: URI"),
            Self::MissingComma => write!(f, "data: URI missing ',' separator"),
            Self::NotBase64 => write!(f, "data: URI payload is not base64"),
            Self::Decode(e) => write!(f, "data: URI payload: {e}"),
        }
    }
}

impl std::error::Error for DataUriError {}

/// Build a `data:` URI: `data:<mime>;base64,<payload>`. The payload is
/// encoded directly into the URI's buffer — one allocation total.
pub fn build(mime_type: &str, data: &[u8], alphabet: &Alphabet) -> String {
    with_engine(alphabet, |engine| {
        let mut out =
            Vec::with_capacity(5 + mime_type.len() + 8 + engine.encoded_len(data.len()));
        out.extend_from_slice(b"data:");
        out.extend_from_slice(mime_type.as_bytes());
        out.extend_from_slice(b";base64,");
        engine.encode_into(data, &mut out);
        String::from_utf8(out).expect("mime type is str and base64 is ASCII")
    })
}

/// Parse a base64 `data:` URI and decode its payload.
pub fn parse(uri: &str, alphabet: &Alphabet) -> Result<DataUri, DataUriError> {
    let rest = uri.strip_prefix("data:").ok_or(DataUriError::NotADataUri)?;
    let comma = rest.find(',').ok_or(DataUriError::MissingComma)?;
    let (header, payload) = rest.split_at(comma);
    let payload = &payload[1..];
    let mime_type = match header.split(';').next() {
        Some("") | None => "text/plain".to_string(),
        Some(m) => m.to_string(),
    };
    if !header.split(';').any(|p| p == "base64") {
        return Err(DataUriError::NotBase64);
    }
    let data = with_engine(alphabet, |engine| engine.decode(payload.as_bytes()))
        .map_err(DataUriError::Decode)?;
    Ok(DataUri { mime_type, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_png_like() {
        let a = Alphabet::standard();
        let payload: Vec<u8> = (0..2357u32).map(|i| (i % 256) as u8).collect();
        let uri = build("image/png", &payload, &a);
        assert!(uri.starts_with("data:image/png;base64,"));
        let parsed = parse(&uri, &a).unwrap();
        assert_eq!(parsed.mime_type, "image/png");
        assert_eq!(parsed.data, payload);
    }

    #[test]
    fn default_mime_type() {
        let a = Alphabet::standard();
        let parsed = parse("data:;base64,aGk=", &a).unwrap();
        assert_eq!(parsed.mime_type, "text/plain");
        assert_eq!(parsed.data, b"hi");
    }

    #[test]
    fn rejects_non_base64_uri() {
        let a = Alphabet::standard();
        assert_eq!(parse("data:text/plain,hello", &a), Err(DataUriError::NotBase64));
    }

    #[test]
    fn rejects_missing_scheme_and_comma() {
        let a = Alphabet::standard();
        assert_eq!(parse("http://x", &a), Err(DataUriError::NotADataUri));
        assert_eq!(parse("data:image/png;base64", &a), Err(DataUriError::MissingComma));
    }

    #[test]
    fn corrupt_payload_reports_decode_error() {
        let a = Alphabet::standard();
        let r = parse("data:image/png;base64,aG!k", &a);
        assert!(matches!(r, Err(DataUriError::Decode(_))));
    }
}
