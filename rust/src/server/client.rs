//! Blocking client for the codec service (used by examples and benches).

use std::net::{SocketAddr, TcpStream};

use super::proto::{read_frame, write_frame, Message, ProtoError};
use crate::base64::{Mode, Whitespace};

/// Client-side failures.
#[derive(Debug)]
pub enum ClientError {
    /// Wire-level failure (I/O error or malformed frame).
    Proto(ProtoError),
    /// The server closed the connection at a frame boundary.
    Closed,
    /// The server answered with an error frame (its message inside).
    Server(String),
    /// The server refused the connection at its admission cap (a
    /// `RespBusy` frame) — retry later, possibly against another
    /// replica. Distinct from [`ClientError::Server`] so callers can
    /// back off instead of failing the request.
    Busy(String),
    /// The server answered with a response type the request never
    /// solicits.
    Unexpected,
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Proto(e) => write!(f, "proto: {e}"),
            Self::Closed => write!(f, "connection closed"),
            Self::Server(m) => write!(f, "server error: {m}"),
            Self::Busy(m) => write!(f, "server busy: {m}"),
            Self::Unexpected => write!(f, "unexpected response"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<ProtoError> for ClientError {
    fn from(e: ProtoError) -> Self {
        Self::Proto(e)
    }
}

/// One connection to the service.
pub struct Client {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    /// Connect to a service address (`TCP_NODELAY` set).
    pub fn connect(addr: SocketAddr) -> Result<Self, ClientError> {
        let stream = TcpStream::connect(addr).map_err(ProtoError::Io)?;
        stream.set_nodelay(true).ok();
        let reader = std::io::BufReader::new(stream.try_clone().map_err(ProtoError::Io)?);
        let writer = std::io::BufWriter::new(stream);
        Ok(Self { reader, writer, next_id: 1 })
    }

    fn call(&mut self, msg: &Message) -> Result<Message, ClientError> {
        write_frame(&mut self.writer, msg)?;
        match read_frame(&mut self.reader)?.ok_or(ClientError::Closed)? {
            // Admission refusal: surface as the typed busy error no
            // matter what request was in flight.
            Message::RespBusy { message } => Err(ClientError::Busy(message)),
            other => Ok(other),
        }
    }

    fn expect_data(&mut self, msg: &Message) -> Result<Vec<u8>, ClientError> {
        match self.call(msg)? {
            Message::RespData { data, .. } => Ok(data),
            Message::RespError { message, .. } => Err(ClientError::Server(message)),
            _ => Err(ClientError::Unexpected),
        }
    }

    fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Encode `data` with the named alphabet (e.g. "standard").
    pub fn encode(&mut self, data: &[u8], alphabet: &str) -> Result<Vec<u8>, ClientError> {
        let id = self.id();
        self.expect_data(&Message::Encode {
            id,
            alphabet: alphabet.to_string(),
            mode: Mode::Strict,
            data: data.to_vec(),
        })
    }

    /// Decode base64 with the named alphabet.
    pub fn decode(&mut self, data: &[u8], alphabet: &str, mode: Mode) -> Result<Vec<u8>, ClientError> {
        self.decode_ws(data, alphabet, mode, Whitespace::None)
    }

    /// Decode with a whitespace policy: the server skips the named bytes
    /// inline (one-shot MIME bodies — no client-side strip pass). A
    /// `None` policy emits the legacy 0x02 frame; anything else rides
    /// the 0x04 tag, so old servers only ever see frames they know.
    pub fn decode_ws(
        &mut self,
        data: &[u8],
        alphabet: &str,
        mode: Mode,
        ws: Whitespace,
    ) -> Result<Vec<u8>, ClientError> {
        let id = self.id();
        self.expect_data(&Message::Decode {
            id,
            alphabet: alphabet.to_string(),
            mode,
            ws,
            data: data.to_vec(),
        })
    }

    /// Validate base64 without materializing output.
    pub fn validate(&mut self, data: &[u8], alphabet: &str) -> Result<(), ClientError> {
        let id = self.id();
        self.expect_data(&Message::Validate {
            id,
            alphabet: alphabet.to_string(),
            mode: Mode::Strict,
            data: data.to_vec(),
        })
        .map(|_| ())
    }

    /// Open a chunked stream; returns the stream id.
    pub fn stream_begin(&mut self, decode: bool, alphabet: &str) -> Result<u64, ClientError> {
        self.stream_begin_ws(decode, alphabet, Whitespace::None)
    }

    /// Open a chunked decode stream with a whitespace policy (MIME
    /// bodies: the server skips CR/LF inline on its SIMD path, so the
    /// client does not need to strip line breaks first).
    pub fn stream_begin_ws(
        &mut self,
        decode: bool,
        alphabet: &str,
        ws: Whitespace,
    ) -> Result<u64, ClientError> {
        let id = self.id();
        self.expect_data(&Message::StreamBegin {
            id,
            decode,
            alphabet: alphabet.to_string(),
            mode: Mode::Strict,
            ws,
            wrap: 0,
        })?;
        Ok(id)
    }

    /// Open a chunked *encode* stream whose output is CRLF-wrapped at
    /// `line_len` chars per line (chunked MIME encode: the server's
    /// line-position carry spans chunk boundaries, so the client
    /// receives ready-to-frame RFC 2045 text). `line_len` must be a
    /// positive multiple of 4.
    pub fn stream_begin_wrapped(
        &mut self,
        alphabet: &str,
        line_len: u16,
    ) -> Result<u64, ClientError> {
        let id = self.id();
        self.expect_data(&Message::StreamBegin {
            id,
            decode: false,
            alphabet: alphabet.to_string(),
            mode: Mode::Strict,
            ws: Whitespace::None,
            wrap: line_len,
        })?;
        Ok(id)
    }

    /// Send a chunk; returns bytes produced so far.
    pub fn stream_chunk(&mut self, stream: u64, data: &[u8]) -> Result<Vec<u8>, ClientError> {
        self.expect_data(&Message::StreamChunk { id: stream, data: data.to_vec() })
    }

    /// Close a stream; returns the final bytes.
    pub fn stream_end(&mut self, stream: u64) -> Result<Vec<u8>, ClientError> {
        self.expect_data(&Message::StreamEnd { id: stream })
    }

    /// List the codecs this connection can name in requests: the
    /// built-in table plus any alphabets registered via
    /// [`Client::register_codec`], as `(id, name)` rows (a `CodecHello`
    /// frame). Servers predating codec negotiation treat the frame as
    /// malformed and close the connection, which surfaces here as
    /// [`ClientError::Closed`] — callers can use that to feature-detect.
    pub fn codecs(&mut self) -> Result<Vec<(u16, String)>, ClientError> {
        let id = self.id();
        match self.call(&Message::CodecHello { id })? {
            Message::RespCodecs { codecs, .. } => Ok(codecs),
            Message::RespError { message, .. } => Err(ClientError::Server(message)),
            _ => Err(ClientError::Unexpected),
        }
    }

    /// Register a custom base64 alphabet under `name` for this
    /// connection (a `CodecRegister` frame); returns the assigned codec
    /// id. The name is then accepted anywhere an alphabet name is —
    /// [`Client::encode`], [`Client::decode`], streams — until the
    /// connection closes.
    pub fn register_codec(
        &mut self,
        name: &str,
        chars: &[u8; 64],
        pad: u8,
    ) -> Result<u16, ClientError> {
        let id = self.id();
        let data = self.expect_data(&Message::CodecRegister {
            id,
            name: name.to_string(),
            pad,
            chars: *chars,
        })?;
        let raw: [u8; 2] = data[..].try_into().map_err(|_| ClientError::Unexpected)?;
        Ok(u16::from_le_bytes(raw))
    }

    /// Fetch the server's metrics report line.
    pub fn stats(&mut self) -> Result<String, ClientError> {
        match self.call(&Message::Stats)? {
            Message::RespStats { report } => Ok(report),
            _ => Err(ClientError::Unexpected),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        match self.call(&Message::Ping)? {
            Message::Pong => Ok(()),
            _ => Err(ClientError::Unexpected),
        }
    }
}
