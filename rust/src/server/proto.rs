//! Wire protocol: length-prefixed binary frames over TCP.
//!
//! ```text
//! frame     := u32le payload_len, payload
//! payload   := tag(u8), body
//! requests:
//!   0x01 Encode    { id:u64le, alphabet:str8, mode:u8, data }
//!   0x02 Decode    { id:u64le, alphabet:str8, mode:u8, data }
//!   0x03 Validate  { id:u64le, alphabet:str8, mode:u8, data }
//!   0x04 DecodeWs  { id:u64le, alphabet:str8, mode:u8, ws:u8, data }
//!   0x10 StreamBegin { id:u64le, dir:u8(0=enc,1=dec), alphabet:str8, mode:u8, ws:u8, wrap:u16le }
//!   0x11 StreamChunk { id:u64le, data }
//!   0x12 StreamEnd   { id:u64le }
//!   0x20 Stats     {}
//!   0x21 Ping      {}
//!   0x22 CodecHello    { id:u64le } — list the connection's codecs
//!   0x23 CodecRegister { id:u64le, name:str8, pad:u8, chars:[u8;64] }
//! responses:
//!   0x81 Data      { id:u64le, data }
//!   0x82 Error     { id:u64le, message }
//!   0x83 Pong      {}
//!   0x84 Stats     { report }
//!   0x85 Busy      { message } — connection refused at admission; the
//!                  server closes the socket right after writing it
//!   0x86 Codecs    { id:u64le, count:u16le, (id:u16le, name:str8)* } —
//!                  reply to CodecHello; one row per registered codec
//!                  (built-ins first, this connection's dynamics after)
//! str8      := len(u8), utf-8 bytes
//! mode      := 0 strict, 1 forgiving
//! ws        := 0 none, 1 crlf, 2 all — whitespace the decoder skips
//!              (trailing byte on StreamBegin; absent means none, for
//!              old clients)
//! wrap      := encode streams only: CRLF-wrap output at this many
//!              chars per line (0 = flat). A second trailing extension
//!              on StreamBegin: serialized only when non-zero (with the
//!              ws byte then always present), so old servers never see
//!              it and old clients' shorter frames still parse.
//! ```
//!
//! One-shot decodes carry the whitespace knob too: [`Message::Decode`]
//! has a `ws` field mirroring `StreamBegin`'s byte (same slot, right
//! after the mode). Because the `Decode` body ends in variable-length
//! data, the byte cannot be appended to the 0x02 layout without
//! ambiguity, so a *non-default* policy upgrades the tag to 0x04 — both
//! directions stay backward compatible: old clients' 0x02 frames parse
//! as `ws = None`, and new clients talking to old servers emit 0x04
//! only when asking for behaviour those servers never had.

use std::io::{Read, Write};

use crate::base64::{Alphabet, Mode, Whitespace};

/// Frames larger than this are rejected (sanity bound, 256 MiB).
pub const MAX_FRAME: usize = 256 << 20;

/// Wire tag of [`Message::RespData`] — referenced by the zero-copy
/// reply path, which writes the tag byte itself before letting the
/// codec kernels fill the payload in place.
pub const TAG_RESP_DATA: u8 = 0x81;

/// Wire tag of [`Message::RespError`] (see [`TAG_RESP_DATA`]).
pub const TAG_RESP_ERROR: u8 = 0x82;

/// A parsed protocol message. The full wire layout (tags, field order,
/// trailing extensions and compatibility rules) is specified in
/// `docs/PROTOCOL.md`.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Tag `0x01`: one-shot encode request.
    Encode {
        /// Request id, echoed in the reply.
        id: u64,
        /// Alphabet name (`"standard"`, `"url"`, …).
        alphabet: String,
        /// Strictness mode (encode requests ignore it on execution).
        mode: Mode,
        /// Raw bytes to encode.
        data: Vec<u8>,
    },
    /// Tag `0x02` (legacy, `ws = None`) or `0x04` (whitespace-tolerant):
    /// one-shot decode request.
    Decode {
        /// Request id, echoed in the reply.
        id: u64,
        /// Alphabet name.
        alphabet: String,
        /// Strictness mode (padding rules).
        mode: Mode,
        /// Whitespace the decoder skips; error offsets still index the
        /// original payload. `None` keeps the legacy `0x02` layout.
        ws: Whitespace,
        /// Base64 characters to decode.
        data: Vec<u8>,
    },
    /// Tag `0x03`: decode-side validation without materializing output.
    Validate {
        /// Request id, echoed in the reply.
        id: u64,
        /// Alphabet name.
        alphabet: String,
        /// Strictness mode (padding rules).
        mode: Mode,
        /// Base64 characters to validate.
        data: Vec<u8>,
    },
    /// Tag `0x10`: open a chunked stream session.
    StreamBegin {
        /// Stream id (scoped to the connection).
        id: u64,
        /// Direction: `true` = decode, `false` = encode.
        decode: bool,
        /// Alphabet name.
        alphabet: String,
        /// Strictness mode (decode streams).
        mode: Mode,
        /// Whitespace skipped by decode streams (trailing extension
        /// byte; absent on the wire means `None`, for old clients).
        ws: Whitespace,
        /// Encode streams only: CRLF-wrap output at this many chars per
        /// line; 0 means flat output (the only value decode streams
        /// accept). A second trailing extension, serialized only when
        /// non-zero.
        wrap: u16,
    },
    /// Tag `0x11`: feed a chunk into an open stream.
    StreamChunk {
        /// Stream id from [`Message::StreamBegin`].
        id: u64,
        /// Raw (encode) or base64 (decode) bytes for this chunk.
        data: Vec<u8>,
    },
    /// Tag `0x12`: close a stream, flushing its carry state.
    StreamEnd {
        /// Stream id to finish.
        id: u64,
    },
    /// Tag `0x20`: request the server's metrics report.
    Stats,
    /// Tag `0x21`: liveness probe.
    Ping,
    /// Tag `0x22`: list the codecs this connection can name in its
    /// requests (built-ins plus dynamically registered alphabets).
    /// Answered with [`Message::RespCodecs`]. Old servers treat the
    /// unknown tag as a malformed frame and close the connection, so a
    /// client probing for negotiation support should send this on a
    /// fresh connection.
    CodecHello {
        /// Request id, echoed in the reply.
        id: u64,
    },
    /// Tag `0x23`: register a custom base64 alphabet under a new codec
    /// name, scoped to this connection. Success is acknowledged with a
    /// [`Message::RespData`] whose 2-byte payload is the assigned codec
    /// id (u16le); rejection (bad name, duplicate, invalid table, full
    /// registry) is an ordinary [`Message::RespError`].
    CodecRegister {
        /// Request id, echoed in the reply.
        id: u64,
        /// Codec name for subsequent requests' `alphabet` field
        /// (1–255 bytes of graphic ASCII).
        name: String,
        /// Padding character (usually `=`); must not collide with the
        /// table.
        pad: u8,
        /// The 64-character encode table.
        chars: [u8; 64],
    },
    /// Tag `0x81`: successful reply carrying output bytes.
    RespData {
        /// Id of the request this answers.
        id: u64,
        /// Output payload (empty for validate/stream-begin acks).
        data: Vec<u8>,
    },
    /// Tag `0x82`: error reply.
    RespError {
        /// Id of the request this answers (0 when unattributable).
        id: u64,
        /// Human-readable error, stable across transports and reply
        /// paths (the parity tests compare it byte-for-byte).
        message: String,
    },
    /// Tag `0x83`: reply to [`Message::Ping`].
    Pong,
    /// Tag `0x84`: reply to [`Message::Stats`].
    RespStats {
        /// One-line metrics snapshot (`Metrics::report`).
        report: String,
    },
    /// Tag `0x85` — admission refusal: the server is at its connection
    /// cap. Written once on the fresh socket, which is then closed —
    /// the typed alternative to the silent drop clients used to see.
    RespBusy {
        /// Why the connection was refused (includes the cap).
        message: String,
    },
    /// Tag `0x86`: reply to [`Message::CodecHello`] — every codec this
    /// connection can name, as `(id, name)` rows ordered by id.
    RespCodecs {
        /// Id of the `CodecHello` this answers.
        id: u64,
        /// `(codec id, canonical name)` rows (aliases are not listed).
        codecs: Vec<(u16, String)>,
    },
}

/// Protocol-level failures.
#[derive(Debug)]
pub enum ProtoError {
    /// Socket-level failure while reading or writing a frame.
    Io(std::io::Error),
    /// A length prefix (or a reply body) exceeded [`MAX_FRAME`].
    FrameTooLarge(usize),
    /// A frame body that does not parse (unknown tag, truncated field,
    /// invalid mode/whitespace byte…). Fatal for the connection.
    Malformed(&'static str),
    /// A request named an alphabet the server does not know.
    UnknownAlphabet(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::FrameTooLarge(n) => write!(f, "frame too large: {n} bytes"),
            Self::Malformed(m) => write!(f, "malformed frame: {m}"),
            Self::UnknownAlphabet(a) => write!(f, "unknown alphabet: {a}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

fn mode_byte(m: Mode) -> u8 {
    match m {
        Mode::Strict => 0,
        Mode::Forgiving => 1,
    }
}

fn byte_mode(b: u8) -> Result<Mode, ProtoError> {
    match b {
        0 => Ok(Mode::Strict),
        1 => Ok(Mode::Forgiving),
        _ => Err(ProtoError::Malformed("bad mode byte")),
    }
}

fn ws_byte(ws: Whitespace) -> u8 {
    match ws {
        Whitespace::None => 0,
        Whitespace::CrLf => 1,
        Whitespace::All => 2,
    }
}

fn byte_ws(b: u8) -> Result<Whitespace, ProtoError> {
    match b {
        0 => Ok(Whitespace::None),
        1 => Ok(Whitespace::CrLf),
        2 => Ok(Whitespace::All),
        _ => Err(ProtoError::Malformed("bad whitespace byte")),
    }
}

/// Resolve an alphabet name from the wire.
pub fn resolve_alphabet(name: &str) -> Result<Alphabet, ProtoError> {
    Alphabet::by_name(name).ok_or_else(|| ProtoError::UnknownAlphabet(name.to_string()))
}

impl Message {
    /// The request/stream id this message carries, or `0` for messages
    /// without one (`Stats`, `Ping`, …). Lets a transport attribute an
    /// error reply — a timeout notice, a panic report — to the request
    /// it answers even when the request itself can no longer be asked.
    pub fn request_id(&self) -> u64 {
        match self {
            Message::Encode { id, .. }
            | Message::Decode { id, .. }
            | Message::Validate { id, .. }
            | Message::StreamBegin { id, .. }
            | Message::StreamChunk { id, .. }
            | Message::StreamEnd { id }
            | Message::CodecHello { id }
            | Message::CodecRegister { id, .. }
            | Message::RespData { id, .. }
            | Message::RespError { id, .. }
            | Message::RespCodecs { id, .. } => *id,
            Message::Stats | Message::Ping | Message::Pong => 0,
            Message::RespStats { .. } | Message::RespBusy { .. } => 0,
        }
    }

    /// Serialize to a frame body (without the length prefix).
    pub fn to_bytes(&self) -> Vec<u8> {
        fn str8(out: &mut Vec<u8>, s: &str) {
            debug_assert!(s.len() < 256);
            out.push(s.len() as u8);
            out.extend_from_slice(s.as_bytes());
        }
        let mut out = Vec::new();
        match self {
            Message::Encode { id, alphabet, mode, data }
            | Message::Validate { id, alphabet, mode, data } => {
                out.push(if matches!(self, Message::Encode { .. }) { 0x01 } else { 0x03 });
                out.extend_from_slice(&id.to_le_bytes());
                str8(&mut out, alphabet);
                out.push(mode_byte(*mode));
                out.extend_from_slice(data);
            }
            Message::Decode { id, alphabet, mode, ws, data } => {
                // ws = None keeps the legacy 0x02 layout (old servers
                // parse it); a real policy upgrades the tag to 0x04 and
                // adds the ws byte in StreamBegin's slot, after the mode.
                out.push(if *ws == Whitespace::None { 0x02 } else { 0x04 });
                out.extend_from_slice(&id.to_le_bytes());
                str8(&mut out, alphabet);
                out.push(mode_byte(*mode));
                if *ws != Whitespace::None {
                    out.push(ws_byte(*ws));
                }
                out.extend_from_slice(data);
            }
            Message::StreamBegin { id, decode, alphabet, mode, ws, wrap } => {
                out.push(0x10);
                out.extend_from_slice(&id.to_le_bytes());
                out.push(*decode as u8);
                str8(&mut out, alphabet);
                out.push(mode_byte(*mode));
                out.push(ws_byte(*ws));
                // Trailing extension: only serialized when requested, so
                // wrap-less frames stay byte-identical to the old layout.
                if *wrap != 0 {
                    out.extend_from_slice(&wrap.to_le_bytes());
                }
            }
            Message::StreamChunk { id, data } => {
                out.push(0x11);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(data);
            }
            Message::StreamEnd { id } => {
                out.push(0x12);
                out.extend_from_slice(&id.to_le_bytes());
            }
            Message::Stats => out.push(0x20),
            Message::Ping => out.push(0x21),
            Message::CodecHello { id } => {
                out.push(0x22);
                out.extend_from_slice(&id.to_le_bytes());
            }
            Message::CodecRegister { id, name, pad, chars } => {
                out.push(0x23);
                out.extend_from_slice(&id.to_le_bytes());
                str8(&mut out, name);
                out.push(*pad);
                out.extend_from_slice(chars);
            }
            Message::RespData { id, data } => {
                out.push(TAG_RESP_DATA);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(data);
            }
            Message::RespError { id, message } => {
                out.push(TAG_RESP_ERROR);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(message.as_bytes());
            }
            Message::Pong => out.push(0x83),
            Message::RespStats { report } => {
                out.push(0x84);
                out.extend_from_slice(report.as_bytes());
            }
            Message::RespBusy { message } => {
                out.push(0x85);
                out.extend_from_slice(message.as_bytes());
            }
            Message::RespCodecs { id, codecs } => {
                out.push(0x86);
                out.extend_from_slice(&id.to_le_bytes());
                debug_assert!(codecs.len() < (1 << 16));
                out.extend_from_slice(&(codecs.len() as u16).to_le_bytes());
                for (cid, name) in codecs {
                    out.extend_from_slice(&cid.to_le_bytes());
                    str8(&mut out, name);
                }
            }
        }
        out
    }

    /// Serialize as one complete wire frame (length prefix + body), the
    /// form the nonblocking transport queues. Rejects oversized bodies
    /// like [`write_frame`] does.
    pub fn to_frame_bytes(&self) -> Result<Vec<u8>, ProtoError> {
        let body = self.to_bytes();
        if body.len() > MAX_FRAME {
            return Err(ProtoError::FrameTooLarge(body.len()));
        }
        let mut frame = Vec::with_capacity(4 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        Ok(frame)
    }

    /// Parse a frame body.
    pub fn from_bytes(buf: &[u8]) -> Result<Message, ProtoError> {
        fn take_u64(buf: &[u8]) -> Result<(u64, &[u8]), ProtoError> {
            if buf.len() < 8 {
                return Err(ProtoError::Malformed("truncated id"));
            }
            Ok((u64::from_le_bytes(buf[..8].try_into().unwrap()), &buf[8..]))
        }
        fn take_str8(buf: &[u8]) -> Result<(String, &[u8]), ProtoError> {
            let n = *buf.first().ok_or(ProtoError::Malformed("truncated str8"))? as usize;
            if buf.len() < 1 + n {
                return Err(ProtoError::Malformed("truncated str8"));
            }
            let s = std::str::from_utf8(&buf[1..1 + n])
                .map_err(|_| ProtoError::Malformed("non-utf8 str8"))?;
            Ok((s.to_string(), &buf[1 + n..]))
        }
        let (&tag, rest) = buf.split_first().ok_or(ProtoError::Malformed("empty frame"))?;
        match tag {
            0x01 | 0x02 | 0x03 | 0x04 => {
                let (id, rest) = take_u64(rest)?;
                let (alphabet, rest) = take_str8(rest)?;
                let (&mb, rest) = rest.split_first().ok_or(ProtoError::Malformed("no mode"))?;
                let mode = byte_mode(mb)?;
                // 0x04 carries the whitespace byte between mode and data
                // (the slot StreamBegin uses); 0x02 is the legacy layout.
                let (ws, data) = if tag == 0x04 {
                    let (&wb, rest) =
                        rest.split_first().ok_or(ProtoError::Malformed("no whitespace byte"))?;
                    (byte_ws(wb)?, rest.to_vec())
                } else {
                    (Whitespace::None, rest.to_vec())
                };
                Ok(match tag {
                    0x01 => Message::Encode { id, alphabet, mode, data },
                    0x02 | 0x04 => Message::Decode { id, alphabet, mode, ws, data },
                    _ => Message::Validate { id, alphabet, mode, data },
                })
            }
            0x10 => {
                let (id, rest) = take_u64(rest)?;
                let (&d, rest) = rest.split_first().ok_or(ProtoError::Malformed("no dir"))?;
                let (alphabet, rest) = take_str8(rest)?;
                let (&mb, rest) = rest.split_first().ok_or(ProtoError::Malformed("no mode"))?;
                // Trailing extensions, oldest client first: frames may end
                // after the mode byte (ws = none), after the ws byte
                // (wrap = 0), or after the wrap u16.
                let (ws, wrap) = match rest.len() {
                    0 => (Whitespace::None, 0u16),
                    1 => (byte_ws(rest[0])?, 0u16),
                    3 => (byte_ws(rest[0])?, u16::from_le_bytes([rest[1], rest[2]])),
                    _ => return Err(ProtoError::Malformed("bad stream-begin tail")),
                };
                Ok(Message::StreamBegin {
                    id,
                    decode: d != 0,
                    alphabet,
                    mode: byte_mode(mb)?,
                    ws,
                    wrap,
                })
            }
            0x11 => {
                let (id, rest) = take_u64(rest)?;
                Ok(Message::StreamChunk { id, data: rest.to_vec() })
            }
            0x12 => {
                let (id, _) = take_u64(rest)?;
                Ok(Message::StreamEnd { id })
            }
            0x20 => Ok(Message::Stats),
            0x21 => Ok(Message::Ping),
            0x22 => {
                let (id, _) = take_u64(rest)?;
                Ok(Message::CodecHello { id })
            }
            0x23 => {
                let (id, rest) = take_u64(rest)?;
                let (name, rest) = take_str8(rest)?;
                let (&pad, rest) =
                    rest.split_first().ok_or(ProtoError::Malformed("no pad byte"))?;
                let chars: [u8; 64] = rest
                    .try_into()
                    .map_err(|_| ProtoError::Malformed("codec table must be 64 bytes"))?;
                Ok(Message::CodecRegister { id, name, pad, chars })
            }
            0x81 => {
                let (id, rest) = take_u64(rest)?;
                Ok(Message::RespData { id, data: rest.to_vec() })
            }
            0x82 => {
                let (id, rest) = take_u64(rest)?;
                let message = String::from_utf8_lossy(rest).into_owned();
                Ok(Message::RespError { id, message })
            }
            0x83 => Ok(Message::Pong),
            0x84 => Ok(Message::RespStats {
                report: String::from_utf8_lossy(rest).into_owned(),
            }),
            0x85 => Ok(Message::RespBusy {
                message: String::from_utf8_lossy(rest).into_owned(),
            }),
            0x86 => {
                let (id, rest) = take_u64(rest)?;
                if rest.len() < 2 {
                    return Err(ProtoError::Malformed("truncated codec count"));
                }
                let count = u16::from_le_bytes([rest[0], rest[1]]) as usize;
                let mut rest = &rest[2..];
                let mut codecs = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    if rest.len() < 2 {
                        return Err(ProtoError::Malformed("truncated codec row"));
                    }
                    let cid = u16::from_le_bytes([rest[0], rest[1]]);
                    let (name, r) = take_str8(&rest[2..])?;
                    codecs.push((cid, name));
                    rest = r;
                }
                if !rest.is_empty() {
                    return Err(ProtoError::Malformed("trailing bytes after codec rows"));
                }
                Ok(Message::RespCodecs { id, codecs })
            }
            _ => Err(ProtoError::Malformed("unknown tag")),
        }
    }
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, msg: &Message) -> Result<(), ProtoError> {
    let body = msg.to_bytes();
    if body.len() > MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(body.len()));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Message>, ProtoError> {
    Ok(read_frame_raw(r)?.map(|(msg, _)| msg))
}

/// [`read_frame`] that also reports the frame's wire size (length
/// prefix included) — the blocking transport's hook for byte-level
/// metrics without re-serializing.
pub fn read_frame_raw(r: &mut impl Read) -> Result<Option<(Message, usize)>, ProtoError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some((Message::from_bytes(&body)?, 4 + len)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let got = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn all_message_types_roundtrip() {
        roundtrip(Message::Encode { id: 7, alphabet: "standard".into(), mode: Mode::Strict, data: b"hello".to_vec() });
        roundtrip(Message::Decode { id: 8, alphabet: "url".into(), mode: Mode::Forgiving, ws: Whitespace::None, data: b"aGk".to_vec() });
        roundtrip(Message::Decode { id: 8, alphabet: "standard".into(), mode: Mode::Strict, ws: Whitespace::CrLf, data: b"Zm9v\r\nYg==".to_vec() });
        roundtrip(Message::Decode { id: 8, alphabet: "standard".into(), mode: Mode::Forgiving, ws: Whitespace::All, data: b"Zm 9v".to_vec() });
        roundtrip(Message::Validate { id: 9, alphabet: "imap".into(), mode: Mode::Strict, data: b"AAAA".to_vec() });
        roundtrip(Message::StreamBegin { id: 1, decode: true, alphabet: "standard".into(), mode: Mode::Strict, ws: Whitespace::None, wrap: 0 });
        roundtrip(Message::StreamBegin { id: 2, decode: true, alphabet: "standard".into(), mode: Mode::Strict, ws: Whitespace::CrLf, wrap: 0 });
        roundtrip(Message::StreamBegin { id: 3, decode: false, alphabet: "url".into(), mode: Mode::Forgiving, ws: Whitespace::All, wrap: 0 });
        roundtrip(Message::StreamBegin { id: 4, decode: false, alphabet: "standard".into(), mode: Mode::Strict, ws: Whitespace::None, wrap: 76 });
        roundtrip(Message::StreamChunk { id: 1, data: vec![0, 1, 255] });
        roundtrip(Message::StreamEnd { id: 1 });
        roundtrip(Message::Stats);
        roundtrip(Message::Ping);
        roundtrip(Message::RespData { id: 7, data: vec![9; 100] });
        roundtrip(Message::RespError { id: 7, message: "bad byte".into() });
        roundtrip(Message::Pong);
        roundtrip(Message::RespStats { report: "req=1".into() });
        roundtrip(Message::RespBusy { message: "server busy".into() });
        roundtrip(Message::CodecHello { id: 11 });
        roundtrip(Message::CodecRegister {
            id: 12,
            name: "custom1".into(),
            pad: b'=',
            chars: *crate::base64::Alphabet::standard().chars(),
        });
        roundtrip(Message::RespCodecs { id: 11, codecs: vec![] });
        roundtrip(Message::RespCodecs {
            id: 11,
            codecs: vec![(0, "standard".into()), (3, "hex".into()), (64, "custom1".into())],
        });
    }

    #[test]
    fn codec_register_layout_is_pinned() {
        let msg = Message::CodecRegister {
            id: 0x0102_0304_0506_0708,
            name: "ab".into(),
            pad: b'=',
            chars: *crate::base64::Alphabet::standard().chars(),
        };
        let body = msg.to_bytes();
        // tag(1) + id(8) + str8(1+2) + pad(1) + table(64) = 77.
        assert_eq!(body.len(), 77);
        assert_eq!(body[0], 0x23);
        assert_eq!(&body[9..12], &[2, b'a', b'b']);
        assert_eq!(body[12], b'=');
        assert_eq!(&body[13..], &crate::base64::Alphabet::standard().chars()[..]);
        // A short or long table is malformed, not silently truncated.
        assert!(Message::from_bytes(&body[..76]).is_err());
        let mut long = body.clone();
        long.push(b'x');
        assert!(Message::from_bytes(&long).is_err());
    }

    #[test]
    fn resp_codecs_layout_is_pinned() {
        let msg = Message::RespCodecs { id: 9, codecs: vec![(3, "hex".into())] };
        let body = msg.to_bytes();
        // tag(1) + id(8) + count(2) + row(2 + 1+3) = 17.
        assert_eq!(body.len(), 17);
        assert_eq!(body[0], 0x86);
        assert_eq!(&body[9..11], &1u16.to_le_bytes());
        assert_eq!(&body[11..13], &3u16.to_le_bytes());
        assert_eq!(&body[13..], &[3, b'h', b'e', b'x']);
        // Count must match the rows exactly.
        let mut short = body.clone();
        short[9] = 2;
        assert!(Message::from_bytes(&short).is_err());
        let mut trailing = body;
        trailing.push(0);
        assert!(Message::from_bytes(&trailing).is_err());
    }

    #[test]
    fn eof_between_frames_is_clean() {
        let buf: Vec<u8> = Vec::new();
        assert!(read_frame(&mut buf.as_slice()).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::Ping).unwrap();
        buf.pop();
        buf[0] = 2; // claim 2 bytes, provide 0
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(read_frame(&mut buf.as_slice()), Err(ProtoError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_bodies_rejected() {
        assert!(Message::from_bytes(&[]).is_err());
        assert!(Message::from_bytes(&[0xFF]).is_err());
        assert!(Message::from_bytes(&[0x01, 1, 2]).is_err()); // truncated id
        // Bad mode byte.
        let mut b = vec![0x01];
        b.extend_from_slice(&0u64.to_le_bytes());
        b.push(0); // empty alphabet
        b.push(9); // invalid mode
        assert!(Message::from_bytes(&b).is_err());
    }

    #[test]
    fn stream_begin_without_ws_byte_defaults_to_none() {
        // Frames from clients that predate the ws extension end after the
        // mode byte.
        let mut b = vec![0x10];
        b.extend_from_slice(&7u64.to_le_bytes());
        b.push(1); // decode
        b.push(8);
        b.extend_from_slice(b"standard");
        b.push(0); // strict
        let msg = Message::from_bytes(&b).unwrap();
        assert_eq!(
            msg,
            Message::StreamBegin {
                id: 7,
                decode: true,
                alphabet: "standard".into(),
                mode: Mode::Strict,
                ws: Whitespace::None,
                wrap: 0,
            }
        );
        // An invalid ws byte is rejected.
        b.push(9);
        assert!(Message::from_bytes(&b).is_err());
    }

    #[test]
    fn stream_begin_wrap_extension_layout() {
        // Wrap-less frames keep the PR-2/3 era layout (nothing after the
        // ws byte), so old servers parse new clients.
        let flat = Message::StreamBegin {
            id: 5,
            decode: false,
            alphabet: "standard".into(),
            mode: Mode::Strict,
            ws: Whitespace::None,
            wrap: 0,
        };
        let body = flat.to_bytes();
        // tag(1) + id(8) + dir(1) + str8(1+8) + mode(1) + ws(1) = 21.
        assert_eq!(body.len(), 21);
        assert_eq!(Message::from_bytes(&body).unwrap(), flat);
        // A wrapped stream appends the u16le line length.
        let wrapped = Message::StreamBegin {
            id: 5,
            decode: false,
            alphabet: "standard".into(),
            mode: Mode::Strict,
            ws: Whitespace::None,
            wrap: 76,
        };
        let body = wrapped.to_bytes();
        assert_eq!(body.len(), 23);
        assert_eq!(&body[21..], &76u16.to_le_bytes());
        assert_eq!(Message::from_bytes(&body).unwrap(), wrapped);
        // A dangling half-u16 tail is malformed.
        assert!(Message::from_bytes(&body[..22]).is_err());
    }

    #[test]
    fn busy_frame_roundtrips_with_message() {
        let msg = Message::RespBusy { message: "server busy: 256 connections".into() };
        let body = msg.to_bytes();
        assert_eq!(body[0], 0x85);
        assert_eq!(Message::from_bytes(&body).unwrap(), msg);
    }

    #[test]
    fn frame_bytes_matches_write_frame() {
        for msg in [
            Message::Ping,
            Message::RespData { id: 3, data: vec![1, 2, 3] },
            Message::RespBusy { message: "busy".into() },
        ] {
            let mut via_writer = Vec::new();
            write_frame(&mut via_writer, &msg).unwrap();
            assert_eq!(msg.to_frame_bytes().unwrap(), via_writer);
        }
    }

    #[test]
    fn decode_ws_none_keeps_the_legacy_tag() {
        // A ws-less decode must serialize byte-identically to the PR-2
        // era 0x02 frame so old servers keep parsing new clients.
        let msg = Message::Decode {
            id: 3,
            alphabet: "standard".into(),
            mode: Mode::Strict,
            ws: Whitespace::None,
            data: b"Zm9v".to_vec(),
        };
        let body = msg.to_bytes();
        assert_eq!(body[0], 0x02);
        // And the legacy layout (no ws byte anywhere) parses as ws=None.
        assert_eq!(Message::from_bytes(&body).unwrap(), msg);
        // The upgraded tag carries the ws byte right after the mode.
        let msg_ws = Message::Decode {
            id: 3,
            alphabet: "standard".into(),
            mode: Mode::Strict,
            ws: Whitespace::CrLf,
            data: b"Zm9v".to_vec(),
        };
        let body = msg_ws.to_bytes();
        assert_eq!(body[0], 0x04);
        // id(8) + str8(1+8) + mode(1) = 18 bytes after the tag.
        assert_eq!(body[19], 1, "ws byte sits in StreamBegin's slot");
        assert_eq!(Message::from_bytes(&body).unwrap(), msg_ws);
        // Truncation before the ws byte is malformed, and a bad ws byte
        // is rejected.
        assert!(Message::from_bytes(&body[..19]).is_err());
        let mut bad = body.clone();
        bad[19] = 9;
        assert!(Message::from_bytes(&bad).is_err());
    }

    #[test]
    fn alphabet_resolution() {
        assert!(resolve_alphabet("standard").is_ok());
        assert!(resolve_alphabet("url").is_ok());
        assert!(matches!(resolve_alphabet("nope"), Err(ProtoError::UnknownAlphabet(_))));
    }
}
