//! Threaded TCP service speaking a length-prefixed codec protocol.
//!
//! One OS thread per connection (bounded by `max_connections`), a shared
//! [`crate::coordinator::Router`] underneath — so batching happens
//! *across* connections, which is where the fixed-shape executables win.

pub mod client;
pub mod proto;
pub mod service;

pub use client::Client;
pub use service::{serve, ServerConfig, ServerHandle};
