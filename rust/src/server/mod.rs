//! TCP service speaking a length-prefixed codec protocol (the wire
//! format is specified in `docs/PROTOCOL.md`).
//!
//! Two transports behind one [`serve`] entry point (picked by
//! [`ServerConfig::transport`] / `B64SIMD_TRANSPORT`):
//!
//! * **epoll** (Linux default) — the event-driven [`crate::net`]
//!   subsystem: [`ServerConfig::reactors`] readiness loops sharing one
//!   port via `SO_REUSEPORT`, thousands of connections multiplexed
//!   onto a fixed worker set, replies built in place on the zero-copy
//!   path;
//! * **threaded** — one OS thread per connection (bounded by
//!   `max_connections`), the portable fallback.
//!
//! Both share the [`crate::coordinator::Router`] underneath — so
//! batching happens *across* connections, which is where the
//! fixed-shape executables win — and both shed over-cap connections
//! with a typed busy frame.

pub mod client;
pub mod proto;
pub mod service;

pub use client::Client;
pub use service::{serve, ConfigParseError, ServerConfig, ServerHandle, Transport};
