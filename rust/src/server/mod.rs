//! TCP service speaking a length-prefixed codec protocol.
//!
//! Two transports behind one [`serve`] entry point (picked by
//! [`ServerConfig::transport`] / `B64SIMD_TRANSPORT`):
//!
//! * **epoll** (Linux default) — the event-driven [`crate::net`]
//!   readiness loop: thousands of connections multiplexed onto a fixed
//!   worker set;
//! * **threaded** — one OS thread per connection (bounded by
//!   `max_connections`), the portable fallback.
//!
//! Both share the [`crate::coordinator::Router`] underneath — so
//! batching happens *across* connections, which is where the
//! fixed-shape executables win — and both shed over-cap connections
//! with a typed busy frame.

pub mod client;
pub mod proto;
pub mod service;

pub use client::Client;
pub use service::{serve, ServerConfig, ServerHandle, Transport};
