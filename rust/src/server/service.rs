//! The codec service: TCP listener, connection threads, shared router.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::proto::{read_frame, resolve_alphabet, write_frame, Message, ProtoError};
use crate::base64::{Mode, Whitespace};
use crate::coordinator::state::{SessionState, StreamError};
use crate::coordinator::{Outcome, Request, RequestKind, Router};

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: SocketAddr,
    /// Maximum concurrent connections; excess connections are refused.
    pub max_connections: usize,
    /// Maximum open streams per connection.
    pub max_streams_per_connection: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:4648".parse().unwrap(), // port = RFC number
            max_connections: 256,
            max_streams_per_connection: 16,
        }
    }
}

/// Running server handle. Dropping stops accepting (existing connections
/// run to completion; use [`ServerHandle::shutdown`] for a joined stop).
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Stop accepting and join the acceptor.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the acceptor out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start the service; returns once the listener is bound.
pub fn serve(router: Arc<Router>, config: ServerConfig) -> anyhow::Result<ServerHandle> {
    let listener = TcpListener::bind(config.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns = Arc::new(AtomicUsize::new(0));
    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            if conns.load(Ordering::SeqCst) >= config.max_connections {
                drop(stream); // shed
                continue;
            }
            conns.fetch_add(1, Ordering::SeqCst);
            let router = router.clone();
            let conns = conns.clone();
            let max_streams = config.max_streams_per_connection;
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &router, max_streams);
                conns.fetch_sub(1, Ordering::SeqCst);
            });
        }
    });
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

fn handle_connection(
    stream: TcpStream,
    router: &Router,
    max_streams: usize,
) -> Result<(), ProtoError> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut session = SessionState::new(max_streams);
    while let Some(msg) = read_frame(&mut reader)? {
        let reply = dispatch(msg, router, &mut session);
        write_frame(&mut writer, &reply)?;
    }
    Ok(())
}

fn outcome_to_message(id: u64, outcome: Outcome) -> Message {
    match outcome {
        Outcome::Data(data) => Message::RespData { id, data },
        Outcome::Valid => Message::RespData { id, data: Vec::new() },
        Outcome::Invalid(e) => Message::RespError { id, message: e.to_string() },
        Outcome::Rejected(r) => Message::RespError { id, message: r.to_string() },
        Outcome::Internal(m) => Message::RespError { id, message: m },
    }
}

fn stream_err(id: u64, e: StreamError) -> Message {
    Message::RespError { id, message: e.to_string() }
}

/// Resolve the alphabet and run a one-shot request through the router.
fn one_shot(
    router: &Router,
    id: u64,
    kind: RequestKind,
    alphabet: String,
    mode: Mode,
    ws: Whitespace,
    data: Vec<u8>,
) -> Message {
    let alphabet = match resolve_alphabet(&alphabet) {
        Ok(a) => a,
        Err(e) => return Message::RespError { id, message: e.to_string() },
    };
    let resp = router.process(Request { id, kind, payload: data, alphabet, mode, ws });
    outcome_to_message(id, resp.outcome)
}

fn dispatch(msg: Message, router: &Router, session: &mut SessionState) -> Message {
    match msg {
        Message::Encode { id, alphabet, mode, data } => {
            one_shot(router, id, RequestKind::Encode, alphabet, mode, Whitespace::None, data)
        }
        Message::Decode { id, alphabet, mode, ws, data } => {
            // The one-shot whitespace knob (wire tag 0x04) rides through
            // to the router, which strips and rebases error offsets.
            one_shot(router, id, RequestKind::Decode, alphabet, mode, ws, data)
        }
        Message::Validate { id, alphabet, mode, data } => {
            one_shot(router, id, RequestKind::Validate, alphabet, mode, Whitespace::None, data)
        }
        Message::StreamBegin { id, decode, alphabet, mode, ws } => {
            let alphabet = match resolve_alphabet(&alphabet) {
                Ok(a) => a,
                Err(e) => return Message::RespError { id, message: e.to_string() },
            };
            let r = if decode {
                session.open_decode_ws(id, alphabet, mode, ws)
            } else {
                session.open_encode(id, alphabet)
            };
            match r {
                Ok(()) => Message::RespData { id, data: Vec::new() },
                Err(e) => stream_err(id, e),
            }
        }
        Message::StreamChunk { id, data } => match session.chunk(id, &data) {
            Ok(out) => Message::RespData { id, data: out },
            Err(e) => stream_err(id, e),
        },
        Message::StreamEnd { id } => match session.finish(id) {
            Ok(out) => Message::RespData { id, data: out },
            Err(e) => stream_err(id, e),
        },
        Message::Stats => Message::RespStats { report: router.metrics().report() },
        Message::Ping => Message::Pong,
        // A server never receives responses; answer with an error frame.
        other => Message::RespError { id: 0, message: format!("unexpected message {other:?}") },
    }
}
