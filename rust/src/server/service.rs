//! The codec service: TCP listeners, pluggable transport, shared router.
//!
//! Two transports speak the same wire protocol over the same
//! [`Router`]:
//!
//! * [`Transport::Epoll`] (Linux, the default) — the event-driven
//!   [`crate::net`] subsystem, sharded across
//!   [`ServerConfig::reactors`] edge-triggered readiness loops (one
//!   `SO_REUSEPORT` listener each) feeding a fixed worker pool, so
//!   thousands of mostly-idle clients cost no threads and the event
//!   loop scales with cores;
//! * [`Transport::Threaded`] — the original thread-per-connection
//!   fallback (non-Linux hosts, differential testing).
//!
//! Either way, connections beyond `max_connections` receive a typed
//! [`Message::RespBusy`] frame before the socket closes — load shedding
//! the client can distinguish from a network failure — and both
//! transports feed the shared connection/frame/byte counters in
//! [`crate::coordinator::Metrics`].

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::proto::{read_frame_raw, resolve_alphabet, Message, ProtoError};
use crate::base64::{Mode, Whitespace};
use crate::coordinator::backpressure::ConnLimiter;
use crate::coordinator::state::{SessionState, StreamError};
use crate::coordinator::{Metrics, Outcome, Request, RequestKind, Router};
use crate::net::frame::ReplySink;

/// Which connection subsystem `serve` runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// Event-driven readiness loop (`crate::net`); Linux only — other
    /// hosts silently fall back to [`Transport::Threaded`].
    Epoll,
    /// One blocking OS thread per connection.
    Threaded,
}

impl Transport {
    /// Short name, as used on the wire of the `B64SIMD_TRANSPORT` knob
    /// and in benchmark tables.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Epoll => "epoll",
            Transport::Threaded => "threaded",
        }
    }

    /// Parse a transport name (the `B64SIMD_TRANSPORT` env values).
    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "epoll" => Some(Transport::Epoll),
            "threaded" | "threads" => Some(Transport::Threaded),
            _ => None,
        }
    }

    /// `B64SIMD_TRANSPORT` override, else the host default (epoll on
    /// Linux). The env knob is how CI runs the whole suite against both
    /// transports.
    pub fn from_env() -> Transport {
        if let Ok(v) = std::env::var("B64SIMD_TRANSPORT") {
            if let Some(t) = Transport::parse(&v) {
                return t;
            }
            eprintln!("b64simd: ignoring unknown B64SIMD_TRANSPORT value '{v}'");
        }
        if cfg!(target_os = "linux") {
            Transport::Epoll
        } else {
            Transport::Threaded
        }
    }
}

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Address to bind (every reactor shard binds it via
    /// `SO_REUSEPORT` when `reactors > 1`).
    pub addr: SocketAddr,
    /// Maximum concurrent connections across all shards; excess
    /// connections get a busy frame and are closed.
    pub max_connections: usize,
    /// Maximum open streams per connection.
    pub max_streams_per_connection: usize,
    /// Connection subsystem (see [`Transport::from_env`]).
    pub transport: Transport,
    /// Worker threads executing requests for the epoll transport (the
    /// threaded transport uses one thread per connection instead). The
    /// pool is shared by every reactor shard, so cross-connection
    /// batching spans shards.
    pub net_workers: usize,
    /// Epoll reactor shards: each runs its own `SO_REUSEPORT` listener,
    /// readiness loop, slab, buffer pool and completion queue, and the
    /// kernel spreads incoming connections across them. `1` preserves
    /// the single-loop behaviour; the default follows
    /// `B64SIMD_REACTORS`, else the host's available cores. Ignored by
    /// the threaded transport.
    pub reactors: usize,
    /// Reply path for the epoll transport: `true` (default) builds
    /// reply frames in place and hands the buffer to the write queue
    /// (zero-copy); `false` serializes replies through `Vec`s — the
    /// differential reference path. `B64SIMD_ZEROCOPY=0` flips the
    /// default off.
    pub zero_copy: bool,
}

impl ServerConfig {
    /// Parse an on/off switch value (`1`/`true`/`on` vs `0`/`false`/
    /// `off`) — the accepted spellings of `B64SIMD_ZEROCOPY` and the
    /// CLI/loadgen `--zerocopy` flags, kept in one place so they cannot
    /// drift.
    pub fn parse_switch(v: &str) -> Option<bool> {
        match v {
            "1" | "true" | "on" => Some(true),
            "0" | "false" | "off" => Some(false),
            _ => None,
        }
    }

    /// `B64SIMD_REACTORS` override, else the host's available cores.
    fn reactors_from_env() -> usize {
        if let Ok(v) = std::env::var("B64SIMD_REACTORS") {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => return n,
                _ => eprintln!("b64simd: ignoring invalid B64SIMD_REACTORS value '{v}'"),
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// `B64SIMD_ZEROCOPY` override (`0`/`false`/`off` select the `Vec`
    /// reference path), else the zero-copy default.
    fn zero_copy_from_env() -> bool {
        match std::env::var("B64SIMD_ZEROCOPY") {
            Err(_) => true,
            Ok(v) => Self::parse_switch(&v).unwrap_or_else(|| {
                eprintln!("b64simd: ignoring unknown B64SIMD_ZEROCOPY value '{v}'");
                true
            }),
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:4648".parse().unwrap(), // port = RFC number
            // The epoll loops hold connections, not threads, so the
            // default cap is an admission bound, not a thread budget.
            max_connections: 1024,
            max_streams_per_connection: 16,
            transport: Transport::from_env(),
            net_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8),
            reactors: Self::reactors_from_env(),
            zero_copy: Self::zero_copy_from_env(),
        }
    }
}

/// Running server handle. Dropping stops the transport (joined); use
/// [`ServerHandle::shutdown`] for an explicit stop.
pub struct ServerHandle {
    /// The bound address (useful with a port-0 request).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    waker: Waker,
}

/// How to nudge a blocked transport out of its wait.
enum Waker {
    /// Connect once to unblock a blocking `accept()`.
    Connect(SocketAddr),
    /// Signal every reactor shard's eventfd.
    #[cfg(target_os = "linux")]
    Events(Vec<Arc<crate::net::sys::EventFd>>),
}

impl Waker {
    fn wake(&self) {
        match self {
            Waker::Connect(addr) => {
                let _ = TcpStream::connect(addr);
            }
            #[cfg(target_os = "linux")]
            Waker::Events(efds) => {
                for efd in efds {
                    efd.signal();
                }
            }
        }
    }
}

impl ServerHandle {
    /// Stop the transport and join its threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Start the service; returns once the listener(s) are bound. The
/// epoll transport binds [`ServerConfig::reactors`] `SO_REUSEPORT`
/// listeners and runs one readiness loop per shard; a single-reactor
/// configuration keeps the plain listener.
pub fn serve(router: Arc<Router>, config: ServerConfig) -> anyhow::Result<ServerHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    match config.transport {
        #[cfg(target_os = "linux")]
        Transport::Epoll => {
            let shards = config.reactors.max(1);
            let listeners = if shards > 1 {
                crate::net::sys::reuseport_group(config.addr, shards)?
            } else {
                vec![TcpListener::bind(config.addr)?]
            };
            let addr = listeners[0].local_addr()?;
            let srv = crate::net::driver::spawn(router, &config, listeners, stop.clone())?;
            Ok(ServerHandle { addr, stop, threads: srv.threads, waker: Waker::Events(srv.wakes) })
        }
        #[cfg(not(target_os = "linux"))]
        Transport::Epoll => {
            let listener = TcpListener::bind(config.addr)?;
            let addr = listener.local_addr()?;
            serve_threaded(router, config, listener, addr, stop)
        }
        Transport::Threaded => {
            let listener = TcpListener::bind(config.addr)?;
            let addr = listener.local_addr()?;
            serve_threaded(router, config, listener, addr, stop)
        }
    }
}

/// The thread-per-connection transport.
fn serve_threaded(
    router: Arc<Router>,
    config: ServerConfig,
    listener: TcpListener,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
) -> anyhow::Result<ServerHandle> {
    let stop2 = stop.clone();
    let limiter = ConnLimiter::new(config.max_connections);
    let metrics = router.metrics().clone();
    let accept_thread = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let Some(permit) = limiter.try_acquire() else {
                Metrics::inc(&metrics.conns_refused, 1);
                refuse_busy(stream, &limiter);
                continue;
            };
            Metrics::inc(&metrics.conns_accepted, 1);
            Metrics::inc(&metrics.conns_open, 1);
            let router = router.clone();
            let metrics = metrics.clone();
            let max_streams = config.max_streams_per_connection;
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &router, max_streams, &metrics);
                Metrics::dec(&metrics.conns_open, 1);
                drop(permit);
            });
        }
    });
    Ok(ServerHandle { addr, stop, threads: vec![accept_thread], waker: Waker::Connect(addr) })
}

/// Load-shed an over-cap connection: tell the client why before
/// closing, instead of the silent drop that used to look identical to a
/// network failure. Best-effort single nonblocking write — a refusal
/// path must never be able to stall the acceptor.
pub(crate) fn refuse_busy(stream: TcpStream, limiter: &ConnLimiter) {
    let msg = Message::RespBusy {
        message: format!(
            "server busy: {} connections open (limit {})",
            limiter.open(),
            limiter.max()
        ),
    };
    if let Ok(frame) = msg.to_frame_bytes() {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).ok();
        // `write_all` on a nonblocking socket errors out (rather than
        // spinning) if the fresh socket buffer somehow cannot take the
        // tiny frame — exactly the best-effort semantics wanted here.
        if (&stream).write_all(&frame).is_err() {
            return;
        }
        // FIN after the frame, then drain whatever request bytes the
        // client already sent: closing with unread data in the receive
        // queue makes the kernel send RST, which on some stacks would
        // discard the busy frame before the client reads it.
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let mut sink = [0u8; 4096];
        for _ in 0..16 {
            match (&stream).read(&mut sink) {
                Ok(0) | Err(_) => break, // EOF, nothing buffered, or reset
                Ok(_) => {}
            }
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    router: &Router,
    max_streams: usize,
    metrics: &Metrics,
) -> Result<(), ProtoError> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut session = SessionState::new(max_streams);
    while let Some((msg, wire_len)) = read_frame_raw(&mut reader)? {
        Metrics::inc(&metrics.frames_in, 1);
        Metrics::inc(&metrics.net_bytes_in, wire_len as u64);
        let reply = dispatch(msg, router, &mut session);
        let frame = reply.to_frame_bytes()?;
        writer.write_all(&frame)?;
        writer.flush()?;
        Metrics::inc(&metrics.frames_out, 1);
        Metrics::inc(&metrics.net_bytes_out, frame.len() as u64);
    }
    Ok(())
}

fn outcome_to_message(id: u64, outcome: Outcome) -> Message {
    match outcome {
        Outcome::Data(data) => Message::RespData { id, data },
        Outcome::Valid => Message::RespData { id, data: Vec::new() },
        Outcome::Invalid(e) => Message::RespError { id, message: e.to_string() },
        Outcome::Rejected(r) => Message::RespError { id, message: r.to_string() },
        Outcome::Internal(m) => Message::RespError { id, message: m },
    }
}

fn stream_err(id: u64, e: StreamError) -> Message {
    Message::RespError { id, message: e.to_string() }
}

/// Resolve the alphabet and run a one-shot request through the router.
fn one_shot(
    router: &Router,
    id: u64,
    kind: RequestKind,
    alphabet: String,
    mode: Mode,
    ws: Whitespace,
    data: Vec<u8>,
) -> Message {
    let alphabet = match resolve_alphabet(&alphabet) {
        Ok(a) => a,
        Err(e) => return Message::RespError { id, message: e.to_string() },
    };
    let resp = router.process(Request { id, kind, payload: data, alphabet, mode, ws });
    outcome_to_message(id, resp.outcome)
}

/// Execute one request message against the router / session. Shared by
/// both transports: the blocking path calls it inline on the connection
/// thread, the epoll path on a net worker (with the session behind the
/// connection's mutex).
pub(crate) fn dispatch(msg: Message, router: &Router, session: &mut SessionState) -> Message {
    match msg {
        Message::Encode { id, alphabet, mode, data } => {
            one_shot(router, id, RequestKind::Encode, alphabet, mode, Whitespace::None, data)
        }
        Message::Decode { id, alphabet, mode, ws, data } => {
            // The one-shot whitespace knob (wire tag 0x04) rides through
            // to the router, which strips and rebases error offsets.
            one_shot(router, id, RequestKind::Decode, alphabet, mode, ws, data)
        }
        Message::Validate { id, alphabet, mode, data } => {
            one_shot(router, id, RequestKind::Validate, alphabet, mode, Whitespace::None, data)
        }
        Message::StreamBegin { id, decode, alphabet, mode, ws, wrap } => {
            let alphabet = match resolve_alphabet(&alphabet) {
                Ok(a) => a,
                Err(e) => return Message::RespError { id, message: e.to_string() },
            };
            let r = if decode {
                if wrap != 0 {
                    return Message::RespError {
                        id,
                        message: "wrap is only valid on encode streams".into(),
                    };
                }
                session.open_decode_ws(id, alphabet, mode, ws)
            } else if wrap != 0 {
                session.open_encode_wrapped(id, alphabet, wrap as usize)
            } else {
                session.open_encode(id, alphabet)
            };
            match r {
                Ok(()) => Message::RespData { id, data: Vec::new() },
                Err(e) => stream_err(id, e),
            }
        }
        Message::StreamChunk { id, data } => match session.chunk(id, &data) {
            Ok(out) => Message::RespData { id, data: out },
            Err(e) => stream_err(id, e),
        },
        Message::StreamEnd { id } => match session.finish(id) {
            Ok(out) => Message::RespData { id, data: out },
            Err(e) => stream_err(id, e),
        },
        Message::Stats => Message::RespStats { report: router.metrics().report() },
        Message::Ping => Message::Pong,
        // A server never receives responses; answer with an error frame.
        other => Message::RespError { id: 0, message: format!("unexpected message {other:?}") },
    }
}

/// Resolve a one-shot request's alphabet, or the error reply to send.
fn make_request(
    id: u64,
    kind: RequestKind,
    alphabet: String,
    mode: Mode,
    ws: Whitespace,
    data: Vec<u8>,
) -> Result<Request, Message> {
    match resolve_alphabet(&alphabet) {
        Ok(alphabet) => Ok(Request { id, kind, payload: data, alphabet, mode, ws }),
        Err(e) => Err(Message::RespError { id, message: e.to_string() }),
    }
}

/// [`dispatch`] on the zero-copy reply path: the complete reply frame
/// is written into `sink` instead of materializing a [`Message`]. The
/// one-shot hot paths go through [`Router::process_into`], which lets
/// the codec kernels fill the payload in place; everything else (stream
/// control, stats, errors) serializes its small reply directly into the
/// sink. The produced bytes are identical to framing [`dispatch`]'s
/// reply — pinned by the router's parity tests and
/// `rust/tests/transport.rs`. `Err` marks an unframeable (oversized)
/// reply, fatal for the connection on both paths.
pub(crate) fn dispatch_into(
    msg: Message,
    router: &Router,
    session: &mut SessionState,
    sink: &mut ReplySink,
) -> Result<(), ProtoError> {
    match msg {
        Message::Encode { id, alphabet, mode, data } => {
            match make_request(id, RequestKind::Encode, alphabet, mode, Whitespace::None, data) {
                Ok(req) => router.process_into(req, sink),
                Err(reply) => sink.push_message(&reply),
            }
        }
        Message::Decode { id, alphabet, mode, ws, data } => {
            match make_request(id, RequestKind::Decode, alphabet, mode, ws, data) {
                Ok(req) => router.process_into(req, sink),
                Err(reply) => sink.push_message(&reply),
            }
        }
        Message::Validate { id, alphabet, mode, data } => {
            match make_request(id, RequestKind::Validate, alphabet, mode, Whitespace::None, data) {
                Ok(req) => router.process_into(req, sink),
                Err(reply) => sink.push_message(&reply),
            }
        }
        // Stream payload replies: the session already materialized the
        // output bytes, so frame them with one copy into the sink
        // instead of the serialize-then-copy `push_message` pair.
        Message::StreamChunk { id, data } => match session.chunk(id, &data) {
            Ok(out) => sink.push_data(id, &out),
            Err(e) => sink.push_message(&stream_err(id, e)),
        },
        Message::StreamEnd { id } => match session.finish(id) {
            Ok(out) => sink.push_data(id, &out),
            Err(e) => sink.push_message(&stream_err(id, e)),
        },
        other => {
            let reply = dispatch(other, router, session);
            sink.push_message(&reply)
        }
    }
}
