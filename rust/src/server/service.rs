//! The codec service: TCP listeners, pluggable transport, shared router.
//!
//! Three transports speak the same wire protocol over the same
//! [`Router`]:
//!
//! * [`Transport::Epoll`] (Linux, the default) — the event-driven
//!   [`crate::net`] subsystem, sharded across
//!   [`ServerConfig::reactors`] edge-triggered readiness loops (one
//!   `SO_REUSEPORT` listener each) feeding a fixed worker pool, so
//!   thousands of mostly-idle clients cost no threads and the event
//!   loop scales with cores;
//! * [`Transport::Uring`] (Linux 5.11+) — the same shard/worker
//!   architecture driven by io_uring submission/completion rings with
//!   kernel-registered read buffers, replacing the per-ready-fd
//!   `read`/`write` syscall pair with one `io_uring_enter` per loop
//!   pass. On kernels without io_uring it falls back to epoll with a
//!   logged notice — unless [`ServerConfig::transport_required`] is
//!   set, in which case `serve` returns the typed
//!   [`crate::net::sys::UringUnsupported`] error;
//! * [`Transport::Threaded`] — the original thread-per-connection
//!   fallback (non-Linux hosts, differential testing).
//!
//! Either way, connections beyond `max_connections` receive a typed
//! [`Message::RespBusy`] frame before the socket closes — load shedding
//! the client can distinguish from a network failure — and both
//! transports feed the shared connection/frame/byte counters in
//! [`crate::coordinator::Metrics`].

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::proto::{Message, ProtoError};
use crate::base64::{Mode, Whitespace};
use crate::codec::CodecSel;
use crate::coordinator::backpressure::{ConnLimiter, RateLimiter};
use crate::coordinator::state::{SessionState, StreamError};
use crate::coordinator::{Metrics, Outcome, Request, RequestKind, Router};
use crate::net::frame::{FrameMachine, ReplySink};
use crate::net::http::{
    busy_response, panic_response, respond_clocked, timeout_response, HttpMachine, HttpWork,
};
use crate::obs::clock::{Proto, ReqClock};

/// Which connection subsystem `serve` runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// Event-driven readiness loop (`crate::net`); Linux only — other
    /// hosts silently fall back to [`Transport::Threaded`].
    Epoll,
    /// io_uring submission/completion rings with registered read
    /// buffers; Linux 5.11+ only. Kernels without io_uring fall back to
    /// [`Transport::Epoll`] with a logged notice — unless
    /// [`ServerConfig::transport_required`] is set, in which case
    /// `serve` fails with [`crate::net::sys::UringUnsupported`].
    /// Non-Linux hosts fall back to [`Transport::Threaded`].
    Uring,
    /// One blocking OS thread per connection.
    Threaded,
}

/// The accepted spellings of `B64SIMD_TRANSPORT`, for warnings and
/// typed errors — kept next to [`Transport::parse`] so they cannot
/// drift.
pub const TRANSPORT_ACCEPTED: &str = "epoll | uring | threaded";

/// The accepted spellings of the on/off switch knobs
/// (`B64SIMD_ZEROCOPY`, `B64SIMD_TRANSPORT_REQUIRED`), next to
/// [`ServerConfig::parse_switch`].
pub const SWITCH_ACCEPTED: &str = "1 | true | on | 0 | false | off";

impl Transport {
    /// Short name, as used on the wire of the `B64SIMD_TRANSPORT` knob
    /// and in benchmark tables.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Epoll => "epoll",
            Transport::Uring => "uring",
            Transport::Threaded => "threaded",
        }
    }

    /// Parse a transport name (the `B64SIMD_TRANSPORT` env values).
    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "epoll" => Some(Transport::Epoll),
            "uring" | "io_uring" | "io-uring" => Some(Transport::Uring),
            "threaded" | "threads" => Some(Transport::Threaded),
            _ => None,
        }
    }

    /// Strict variant of [`Transport::parse`]: a typed
    /// [`ConfigParseError`] naming the accepted set instead of `None`.
    pub fn parse_strict(s: &str) -> Result<Transport, ConfigParseError> {
        Transport::parse(s).ok_or_else(|| ConfigParseError {
            key: "B64SIMD_TRANSPORT",
            value: s.to_string(),
            accepted: TRANSPORT_ACCEPTED,
        })
    }

    /// `B64SIMD_TRANSPORT` override, else the host default (epoll on
    /// Linux). The env knob is how CI runs the whole suite against the
    /// transports. Unknown values warn (naming the accepted set) and
    /// keep the default rather than panicking at `Default` time.
    pub fn from_env() -> Transport {
        let default = if cfg!(target_os = "linux") {
            Transport::Epoll
        } else {
            Transport::Threaded
        };
        match std::env::var("B64SIMD_TRANSPORT") {
            Err(_) => default,
            Ok(v) => match Transport::parse_strict(&v) {
                Ok(t) => t,
                Err(e) => {
                    crate::log_warn!("config", "{e}; using '{}'", default.name());
                    default
                }
            },
        }
    }
}

/// A configuration knob held a value outside its accepted set.
///
/// Environment-driven defaults ([`ServerConfig::default`],
/// [`Transport::from_env`]) deliberately *warn and fall back* rather
/// than return this — a typo in an env var should not panic a library
/// `Default` impl — but callers that take config values from flags
/// (the CLI, loadgen) parse through the strict entry points and get
/// this typed error to surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigParseError {
    /// The knob (env var name) whose value failed to parse.
    pub key: &'static str,
    /// The offending value.
    pub value: String,
    /// Human-readable accepted set, e.g. `"epoll | uring | threaded"`.
    pub accepted: &'static str,
}

impl std::fmt::Display for ConfigParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown {} value '{}' (accepted: {})",
            self.key, self.value, self.accepted
        )
    }
}

impl std::error::Error for ConfigParseError {}

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Address to bind (every reactor shard binds it via
    /// `SO_REUSEPORT` when `reactors > 1`).
    pub addr: SocketAddr,
    /// Maximum concurrent connections across all shards; excess
    /// connections get a busy frame and are closed.
    pub max_connections: usize,
    /// Maximum open streams per connection.
    pub max_streams_per_connection: usize,
    /// Connection subsystem (see [`Transport::from_env`]).
    pub transport: Transport,
    /// Fail startup instead of falling back when the configured
    /// transport is unavailable on this host (today: `uring` on a
    /// kernel without io_uring). `B64SIMD_TRANSPORT_REQUIRED=1`;
    /// default off, i.e. fall back with a logged notice.
    pub transport_required: bool,
    /// Worker threads executing requests for the epoll transport (the
    /// threaded transport uses one thread per connection instead). The
    /// pool is shared by every reactor shard, so cross-connection
    /// batching spans shards.
    pub net_workers: usize,
    /// Epoll reactor shards: each runs its own `SO_REUSEPORT` listener,
    /// readiness loop, slab, buffer pool and completion queue, and the
    /// kernel spreads incoming connections across them. `1` preserves
    /// the single-loop behaviour; the default follows
    /// `B64SIMD_REACTORS`, else the host's available cores. Ignored by
    /// the threaded transport.
    pub reactors: usize,
    /// Reply path for the epoll transport: `true` (default) builds
    /// reply frames in place and hands the buffer to the write queue
    /// (zero-copy); `false` serializes replies through `Vec`s — the
    /// differential reference path. `B64SIMD_ZEROCOPY=0` flips the
    /// default off.
    pub zero_copy: bool,
    /// Close a connection that has been fully quiescent (no request in
    /// flight, nothing buffered) this long. `B64SIMD_TIMEOUT_IDLE`
    /// (milliseconds; `0` disables), default 60s.
    pub idle_timeout: Duration,
    /// Close a connection whose *partial* request frame has not
    /// completed within this window — the slow-loris shed. Progress is
    /// counted per complete frame, not per byte, so dripping one header
    /// byte at a time cannot refresh the deadline.
    /// `B64SIMD_TIMEOUT_READ` (milliseconds; `0` disables), default 10s.
    pub read_timeout: Duration,
    /// Close a connection whose pending replies have made no progress
    /// onto the socket this long (the peer stopped reading).
    /// `B64SIMD_TIMEOUT_WRITE` (milliseconds; `0` disables), default
    /// 10s.
    pub write_timeout: Duration,
    /// Graceful-drain grace period: how long `ServerHandle::shutdown`
    /// waits for in-flight requests to be answered and flushed before
    /// force-closing what remains. `B64SIMD_DRAIN_MS`, default 5s.
    pub drain_grace: Duration,
    /// HTTP/1.1 gateway bind address ([`crate::net::http`]). On the
    /// sharded transports every reactor also binds this address via
    /// `SO_REUSEPORT` and routes its connections through the gateway's
    /// request machine; the threaded transport runs a second accept
    /// loop. `B64SIMD_HTTP` (e.g. `127.0.0.1:8040`); `None` (the
    /// default — unset or invalid, with a warning) disables the
    /// gateway.
    pub http_addr: Option<SocketAddr>,
    /// Per-client-IP rate limit for HTTP `POST` requests, in requests
    /// per second (fractional rates allowed; burst = one second's
    /// worth). Refusals are `429` responses that count into the
    /// `rate_limited` metric. `B64SIMD_RATELIMIT`; `0` (the default)
    /// disables. Native-protocol listeners are never rate limited.
    pub rate_limit: f64,
}

impl ServerConfig {
    /// Parse an on/off switch value (`1`/`true`/`on` vs `0`/`false`/
    /// `off`) — the accepted spellings of `B64SIMD_ZEROCOPY` and the
    /// CLI/loadgen `--zerocopy` flags, kept in one place so they cannot
    /// drift.
    pub fn parse_switch(v: &str) -> Option<bool> {
        match v {
            "1" | "true" | "on" => Some(true),
            "0" | "false" | "off" => Some(false),
            _ => None,
        }
    }

    /// `B64SIMD_REACTORS` override, else the host's available cores.
    fn reactors_from_env() -> usize {
        if let Ok(v) = std::env::var("B64SIMD_REACTORS") {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => return n,
                _ => crate::log_warn!("config", "ignoring invalid B64SIMD_REACTORS value '{v}'"),
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// `B64SIMD_ZEROCOPY` override (`0`/`false`/`off` select the `Vec`
    /// reference path), else the zero-copy default.
    fn zero_copy_from_env() -> bool {
        Self::switch_from_env("B64SIMD_ZEROCOPY", true)
    }

    /// On/off env knob through [`ServerConfig::parse_switch`]; unknown
    /// values warn — naming the accepted spellings — and keep the
    /// default.
    fn switch_from_env(key: &'static str, default: bool) -> bool {
        match std::env::var(key) {
            Err(_) => default,
            Ok(v) => Self::parse_switch(&v).unwrap_or_else(|| {
                let e = ConfigParseError {
                    key,
                    value: v,
                    accepted: SWITCH_ACCEPTED,
                };
                crate::log_warn!("config", "{e}; using '{default}'");
                default
            }),
        }
    }

    /// `B64SIMD_HTTP` gateway address; unset disables, invalid warns
    /// and disables (same warn-don't-panic contract as the other env
    /// defaults).
    fn http_addr_from_env() -> Option<SocketAddr> {
        match std::env::var("B64SIMD_HTTP") {
            Err(_) => None,
            Ok(v) => match v.parse::<SocketAddr>() {
                Ok(a) => Some(a),
                Err(_) => {
                    crate::log_warn!(
                        "config",
                        "ignoring invalid B64SIMD_HTTP value '{v}' \
                         (want an address like 127.0.0.1:8040)"
                    );
                    None
                }
            },
        }
    }

    /// `B64SIMD_RATELIMIT` requests/second; `0` (and unset) disables,
    /// invalid or negative warns and disables.
    fn rate_limit_from_env() -> f64 {
        match std::env::var("B64SIMD_RATELIMIT") {
            Err(_) => 0.0,
            Ok(v) => match v.parse::<f64>() {
                Ok(r) if r.is_finite() && r >= 0.0 => r,
                _ => {
                    crate::log_warn!("config", "ignoring invalid B64SIMD_RATELIMIT value '{v}'");
                    0.0
                }
            },
        }
    }

    /// Millisecond env knob for the lifecycle deadlines; `0` disables
    /// the deadline it configures.
    fn timeout_from_env(key: &str, default: Duration) -> Duration {
        match std::env::var(key) {
            Err(_) => default,
            Ok(v) => match v.parse::<u64>() {
                Ok(ms) => Duration::from_millis(ms),
                Err(_) => {
                    crate::log_warn!("config", "ignoring invalid {key} value '{v}'");
                    default
                }
            },
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:4648".parse().unwrap(), // port = RFC number
            // The epoll loops hold connections, not threads, so the
            // default cap is an admission bound, not a thread budget.
            max_connections: 1024,
            max_streams_per_connection: 16,
            transport: Transport::from_env(),
            transport_required: Self::switch_from_env("B64SIMD_TRANSPORT_REQUIRED", false),
            net_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8),
            reactors: Self::reactors_from_env(),
            zero_copy: Self::zero_copy_from_env(),
            idle_timeout: Self::timeout_from_env("B64SIMD_TIMEOUT_IDLE", Duration::from_secs(60)),
            read_timeout: Self::timeout_from_env("B64SIMD_TIMEOUT_READ", Duration::from_secs(10)),
            write_timeout: Self::timeout_from_env("B64SIMD_TIMEOUT_WRITE", Duration::from_secs(10)),
            drain_grace: Self::timeout_from_env("B64SIMD_DRAIN_MS", Duration::from_secs(5)),
            http_addr: Self::http_addr_from_env(),
            rate_limit: Self::rate_limit_from_env(),
        }
    }
}

/// Running server handle. Dropping drains and stops the transport
/// (joined); use [`ServerHandle::shutdown`] for an explicit graceful
/// stop or [`ServerHandle::abort`] to skip the drain.
pub struct ServerHandle {
    /// The bound address (useful with a port-0 request).
    pub addr: SocketAddr,
    /// The HTTP gateway's bound address, when
    /// [`ServerConfig::http_addr`] enabled it (useful with a port-0
    /// request).
    pub http_addr: Option<SocketAddr>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    waker: Waker,
    metrics: Arc<Metrics>,
}

/// How to nudge a blocked transport out of its wait.
enum Waker {
    /// Connect once to each listed address to unblock its blocking
    /// `accept()` loop (the native listener, plus the HTTP gateway's
    /// when enabled).
    Connect(Vec<SocketAddr>),
    /// Signal every reactor shard's eventfd.
    #[cfg(target_os = "linux")]
    Events(Vec<Arc<crate::net::sys::EventFd>>),
}

impl Waker {
    fn wake(&self) {
        match self {
            Waker::Connect(addrs) => {
                for addr in addrs {
                    let _ = TcpStream::connect(addr);
                }
            }
            #[cfg(target_os = "linux")]
            Waker::Events(efds) => {
                for efd in efds {
                    efd.signal();
                }
            }
        }
    }
}

impl ServerHandle {
    /// Gracefully drain and stop: accepting ends at once, every request
    /// already parsed off the wire is answered and its reply flushed
    /// (bounded by [`ServerConfig::drain_grace`]), idle connections
    /// close immediately, and the transport threads join before this
    /// returns — the `conns_open` gauge is back to zero.
    pub fn shutdown(mut self) {
        self.drain_and_join();
    }

    /// Hard stop: abandon open connections without answering what is
    /// still queued. Exists for tests and for a second, impatient
    /// signal; prefer [`ServerHandle::shutdown`].
    pub fn abort(mut self) {
        self.stop_and_join();
    }

    fn drain_and_join(&mut self) {
        if self.threads.is_empty() {
            return; // already stopped
        }
        Metrics::inc(&self.metrics.drains, 1);
        self.drain.store(true, Ordering::SeqCst);
        self.waker.wake();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    fn stop_and_join(&mut self) {
        if self.threads.is_empty() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.drain_and_join();
    }
}

/// Start the service; returns once the listener(s) are bound. The
/// epoll transport binds [`ServerConfig::reactors`] `SO_REUSEPORT`
/// listeners and runs one readiness loop per shard; a single-reactor
/// configuration keeps the plain listener.
pub fn serve(router: Arc<Router>, config: ServerConfig) -> anyhow::Result<ServerHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    let drain = Arc::new(AtomicBool::new(false));
    match config.transport {
        #[cfg(target_os = "linux")]
        Transport::Epoll => serve_sharded(router, config, stop, drain, false),
        #[cfg(target_os = "linux")]
        Transport::Uring => {
            if crate::net::sys::uring_supported() {
                serve_sharded(router, config, stop, drain, true)
            } else if config.transport_required {
                Err(crate::net::sys::UringUnsupported.into())
            } else {
                crate::log_warn!(
                    "service",
                    "{}; falling back to transport 'epoll' \
                     (set B64SIMD_TRANSPORT_REQUIRED=1 to fail instead)",
                    crate::net::sys::UringUnsupported
                );
                serve_sharded(router, config, stop, drain, false)
            }
        }
        #[cfg(not(target_os = "linux"))]
        Transport::Epoll | Transport::Uring => {
            let listener = TcpListener::bind(config.addr)?;
            let addr = listener.local_addr()?;
            serve_threaded(router, config, listener, addr, stop, drain)
        }
        Transport::Threaded => {
            let listener = TcpListener::bind(config.addr)?;
            let addr = listener.local_addr()?;
            serve_threaded(router, config, listener, addr, stop, drain)
        }
    }
}

/// Shared startup for the sharded Linux transports: bind the
/// `SO_REUSEPORT` listener group (or a single plain listener), spawn
/// the reactor shards and worker pool through the chosen driver, and
/// wrap the result in a [`ServerHandle`] woken via the shards'
/// eventfds.
#[cfg(target_os = "linux")]
fn serve_sharded(
    router: Arc<Router>,
    config: ServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    uring: bool,
) -> anyhow::Result<ServerHandle> {
    use crate::net::http::Protocol;
    let shards = config.reactors.max(1);
    let bind_group = |addr: SocketAddr| -> std::io::Result<Vec<TcpListener>> {
        if shards > 1 {
            crate::net::sys::reuseport_group(addr, shards)
        } else {
            Ok(vec![TcpListener::bind(addr)?])
        }
    };
    let mut listeners: Vec<(TcpListener, Protocol)> = bind_group(config.addr)?
        .into_iter()
        .map(|l| (l, Protocol::Native))
        .collect();
    let addr = listeners[0].0.local_addr()?;
    // The gateway gets its own listener group on the same shard count.
    // One shard = one listener, so this adds `shards` HTTP reactors
    // alongside the native ones — all feeding the same worker pool,
    // connection limiter and metrics.
    let mut http_addr = None;
    if let Some(ha) = config.http_addr {
        let group = bind_group(ha)?;
        http_addr = Some(group[0].local_addr()?);
        listeners.extend(group.into_iter().map(|l| (l, Protocol::Http)));
    }
    let metrics = router.metrics().clone();
    let srv = if uring {
        crate::net::uring::spawn(router, &config, listeners, stop.clone(), drain.clone())?
    } else {
        crate::net::driver::spawn(router, &config, listeners, stop.clone(), drain.clone())?
    };
    Ok(ServerHandle {
        addr,
        http_addr,
        stop,
        drain,
        threads: srv.threads,
        waker: Waker::Events(srv.wakes),
        metrics,
    })
}

/// The thread-per-connection transport. The accept thread tracks its
/// connection threads and joins them before exiting, so a joined
/// `ServerHandle` means every connection is finished and the
/// `conns_open` gauge has settled — the same guarantee the epoll
/// transport's drain gives.
fn serve_threaded(
    router: Arc<Router>,
    config: ServerConfig,
    listener: TcpListener,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) -> anyhow::Result<ServerHandle> {
    // One connection cap across both listeners, as on the sharded
    // transports; the rate limiter only ever gates HTTP connections.
    let limiter = ConnLimiter::new(config.max_connections);
    let rate = RateLimiter::new(config.rate_limit);
    let handle_metrics = router.metrics().clone();
    let mut threads = Vec::new();
    let mut wake_addrs = vec![addr];
    let mut http_addr = None;
    if let Some(ha) = config.http_addr {
        let http_listener = TcpListener::bind(ha)?;
        let bound = http_listener.local_addr()?;
        http_addr = Some(bound);
        wake_addrs.push(bound);
        threads.push(accept_loop(
            router.clone(),
            config.clone(),
            http_listener,
            true,
            rate.clone(),
            limiter.clone(),
            stop.clone(),
            drain.clone(),
        ));
    }
    threads.push(accept_loop(
        router,
        config,
        listener,
        false,
        rate,
        limiter,
        stop.clone(),
        drain.clone(),
    ));
    Ok(ServerHandle {
        addr,
        http_addr,
        stop,
        drain,
        threads,
        waker: Waker::Connect(wake_addrs),
        metrics: handle_metrics,
    })
}

/// One blocking accept loop (native or HTTP), spawning a thread per
/// admitted connection. The accept thread tracks its connection
/// threads and joins them before exiting (see [`serve_threaded`]).
#[allow(clippy::too_many_arguments)]
fn accept_loop(
    router: Arc<Router>,
    config: ServerConfig,
    listener: TcpListener,
    http: bool,
    rate: Option<Arc<RateLimiter>>,
    limiter: Arc<ConnLimiter>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) -> JoinHandle<()> {
    let metrics = router.metrics().clone();
    std::thread::spawn(move || {
        let mut conn_threads: Vec<JoinHandle<()>> = Vec::new();
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) || drain.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished connection threads as we go, so a
            // long-lived server does not accumulate dead handles.
            let mut i = 0;
            while i < conn_threads.len() {
                if conn_threads[i].is_finished() {
                    let _ = conn_threads.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            let Ok(stream) = stream else { continue };
            let Some(permit) = limiter.try_acquire() else {
                Metrics::inc(&metrics.conns_refused, 1);
                if http {
                    refuse_busy_over_http(stream, &limiter);
                } else {
                    refuse_busy(stream, &limiter);
                }
                continue;
            };
            Metrics::inc(&metrics.conns_accepted, 1);
            Metrics::inc(&metrics.conns_open, 1);
            let router = router.clone();
            let metrics = metrics.clone();
            let rate = rate.clone();
            let stop2 = stop.clone();
            let drain2 = drain.clone();
            let config = config.clone();
            let spawned = std::thread::Builder::new()
                .name("b64simd-conn".to_string())
                .spawn(move || {
                    if http {
                        let _ = handle_http_connection(
                            stream, &router, &config, &rate, &metrics, &stop2, &drain2,
                        );
                    } else {
                        let _ =
                            handle_connection(stream, &router, &config, &metrics, &stop2, &drain2);
                    }
                    Metrics::dec(&metrics.conns_open, 1);
                    drop(permit);
                });
            match spawned {
                Ok(t) => conn_threads.push(t),
                Err(_) => {
                    // Thread exhaustion: shed the connection (permit
                    // and socket drop) rather than killing the acceptor.
                    Metrics::dec(&metrics.conns_open, 1);
                }
            }
        }
        // Drain: the connection threads observe the flags themselves
        // (they poll between reads); joining them here is what makes
        // `ServerHandle::shutdown` mean "every accepted request
        // answered".
        for t in conn_threads {
            let _ = t.join();
        }
    })
}

/// Load-shed an over-cap connection: tell the client why before
/// closing, instead of the silent drop that used to look identical to a
/// network failure. Best-effort single nonblocking write — a refusal
/// path must never be able to stall the acceptor.
pub(crate) fn refuse_busy(stream: TcpStream, limiter: &ConnLimiter) {
    let msg = Message::RespBusy {
        message: format!(
            "server busy: {} connections open (limit {})",
            limiter.open(),
            limiter.max()
        ),
    };
    if let Ok(frame) = msg.to_frame_bytes() {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).ok();
        // `write_all` on a nonblocking socket errors out (rather than
        // spinning) if the fresh socket buffer somehow cannot take the
        // tiny frame — exactly the best-effort semantics wanted here.
        if (&stream).write_all(&frame).is_err() {
            return;
        }
        // FIN after the frame, then drain whatever request bytes the
        // client already sent: closing with unread data in the receive
        // queue makes the kernel send RST, which on some stacks would
        // discard the busy frame before the client reads it.
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let mut sink = [0u8; 4096];
        for _ in 0..16 {
            match (&stream).read(&mut sink) {
                Ok(0) | Err(_) => break, // EOF, nothing buffered, or reset
                Ok(_) => {}
            }
        }
    }
}

/// [`refuse_busy`]'s HTTP twin: a one-shot `503` with the same
/// best-effort nonblocking-write semantics.
fn refuse_busy_over_http(stream: TcpStream, limiter: &ConnLimiter) {
    let reply = busy_response(limiter.open(), limiter.max());
    stream.set_nodelay(true).ok();
    stream.set_nonblocking(true).ok();
    if (&stream).write_all(&reply).is_err() {
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut sink = [0u8; 4096];
    for _ in 0..16 {
        match (&stream).read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

/// One blocking HTTP gateway connection: the threaded-transport twin of
/// the reactors' `HttpMachine` + worker path, with the same lifecycle
/// rules as [`handle_connection`] — poll-tick reads observing
/// `stop`/`drain` and the idle / read-stall deadlines (answered with a
/// `408` instead of the native timeout frames), write timeouts on the
/// socket, and `catch_unwind` around each response.
fn handle_http_connection(
    stream: TcpStream,
    router: &Router,
    config: &ServerConfig,
    rate: &Option<Arc<RateLimiter>>,
    metrics: &Metrics,
    stop: &AtomicBool,
    drain: &AtomicBool,
) -> std::io::Result<()> {
    let mut stream = stream;
    stream.set_nodelay(true).ok();
    let mut tick = Duration::from_millis(100);
    for t in [config.idle_timeout, config.read_timeout] {
        if t != Duration::ZERO {
            tick = tick.min(t);
        }
    }
    stream.set_read_timeout(Some(tick.max(Duration::from_millis(5))))?;
    if config.write_timeout != Duration::ZERO {
        stream.set_write_timeout(Some(config.write_timeout)).ok();
    }
    let peer = stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or(std::net::IpAddr::V4(std::net::Ipv4Addr::UNSPECIFIED));
    let mut machine = HttpMachine::new(Vec::new(), rate.clone(), peer);
    let mut session = SessionState::new(config.max_streams_per_connection);
    let mut scratch = vec![0u8; 64 << 10];
    let mut last_activity = Instant::now();
    let mut frame_start: Option<Instant> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match crate::net::faults::read_stream(&mut stream, &mut scratch) {
            Ok(0) => return Ok(()),
            Ok(n) => {
                Metrics::inc(&metrics.net_bytes_in, n as u64);
                machine.push(&scratch[..n]);
                last_activity = Instant::now();
                let mut parsed_any = false;
                while let Some(job) = machine.next_job() {
                    parsed_any = true;
                    Metrics::inc(&metrics.frames_in, 1);
                    let work = HttpWork { job, draining: drain.load(Ordering::SeqCst) };
                    if !serve_one_http(work, router, &mut session, &stream, metrics)? {
                        return Ok(()); // close-after response delivered
                    }
                }
                if machine.buffered() == 0 {
                    frame_start = None;
                } else if parsed_any || frame_start.is_none() {
                    frame_start = Some(Instant::now());
                }
                if drain.load(Ordering::SeqCst) {
                    // Every request parsed so far is answered (just
                    // above); a draining server reads nothing more.
                    return Ok(());
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: nothing arrived within `tick`.
                if drain.load(Ordering::SeqCst) {
                    return Ok(());
                }
                let now = Instant::now();
                let read_stalled = config.read_timeout != Duration::ZERO
                    && frame_start.map_or(false, |t| now >= t + config.read_timeout);
                let idle = config.idle_timeout != Duration::ZERO
                    && frame_start.is_none()
                    && now >= last_activity + config.idle_timeout;
                if read_stalled || idle {
                    Metrics::inc(&metrics.timeouts, 1);
                    let notice = timeout_response(if read_stalled {
                        "timeout: request frame stalled"
                    } else {
                        "timeout: idle connection"
                    });
                    if (&stream).write_all(&notice).is_ok() {
                        Metrics::inc(&metrics.frames_out, 1);
                        Metrics::inc(&metrics.net_bytes_out, notice.len() as u64);
                    }
                    return Ok(());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Build and write the response for one HTTP job on the blocking
/// transport. Returns `Ok(false)` when the connection must close (the
/// response said so, or the handler panicked).
fn serve_one_http(
    work: HttpWork,
    router: &Router,
    session: &mut SessionState,
    stream: &TcpStream,
    metrics: &Metrics,
) -> std::io::Result<bool> {
    // See `serve_one`: no worker hand-off on this transport, so the
    // parse and dequeue stamps coincide.
    let clock = ReqClock::new(Proto::Http);
    clock.stamp_parse();
    clock.stamp_dequeue();
    let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
        respond_clocked(work, router, session, Vec::new(), Some(&clock))
    }));
    let (reply, close) = match outcome {
        Ok((reply, close)) => (reply, close),
        Err(_) => {
            Metrics::inc(&metrics.worker_panics, 1);
            (panic_response(), true)
        }
    };
    if reply.is_empty() {
        // A swallowed stream job (error already answered): no bytes.
        return Ok(!close);
    }
    if let Err(e) = (&*stream).write_all(&reply) {
        if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) {
            // The peer stopped reading its replies: the write-stall
            // shed, enforced here by the socket write timeout.
            Metrics::inc(&metrics.timeouts, 1);
        }
        return Err(e);
    }
    Metrics::inc(&metrics.frames_out, 1);
    Metrics::inc(&metrics.net_bytes_out, reply.len() as u64);
    metrics.record_clock_stages(&clock);
    metrics.record_clock_flush(&clock, "service");
    Ok(!close)
}

/// Serialized close-notice frames for the connection deadlines. The
/// exact strings are normative (`docs/PROTOCOL.md`, "Timeouts and
/// close semantics") and shared by both transports, so the parity
/// oracle holds on the timeout paths too.
pub(crate) fn idle_timeout_frame() -> Option<Vec<u8>> {
    Message::RespError { id: 0, message: "timeout: idle connection".into() }
        .to_frame_bytes()
        .ok()
}

/// See [`idle_timeout_frame`]; sent when a partial request frame
/// stalls (the slow-loris shed).
pub(crate) fn stall_timeout_frame() -> Option<Vec<u8>> {
    Message::RespError { id: 0, message: "timeout: request frame stalled".into() }
        .to_frame_bytes()
        .ok()
}

/// One blocking connection, with the same lifecycle rules as the epoll
/// transport: reads poll on a short timeout so the thread can observe
/// `stop`/`drain` and the idle / read-stall deadlines; writes are
/// bounded by the configured write timeout; each request dispatch runs
/// under `catch_unwind`, so a panicking handler costs this connection
/// one error reply and a close, never the whole process.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    config: &ServerConfig,
    metrics: &Metrics,
    stop: &AtomicBool,
    drain: &AtomicBool,
) -> Result<(), ProtoError> {
    let mut stream = stream;
    stream.set_nodelay(true).ok();
    // The poll tick bounds how stale a stop/drain/deadline check can
    // get; tighten it under sub-100ms deadlines so tests with tiny
    // timeouts observe them promptly.
    let mut tick = Duration::from_millis(100);
    for t in [config.idle_timeout, config.read_timeout] {
        if t != Duration::ZERO {
            tick = tick.min(t);
        }
    }
    stream.set_read_timeout(Some(tick.max(Duration::from_millis(5))))?;
    if config.write_timeout != Duration::ZERO {
        stream.set_write_timeout(Some(config.write_timeout)).ok();
    }
    let mut frames = FrameMachine::new(Vec::new());
    let mut session = SessionState::new(config.max_streams_per_connection);
    let mut scratch = vec![0u8; 64 << 10];
    let mut last_activity = Instant::now();
    // When the partial frame at the head of the accumulator started;
    // only a *complete* frame resets it (see `ServerConfig::read_timeout`).
    let mut frame_start: Option<Instant> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match crate::net::faults::read_stream(&mut stream, &mut scratch) {
            Ok(0) => return Ok(()),
            Ok(n) => {
                Metrics::inc(&metrics.net_bytes_in, n as u64);
                frames.push(&scratch[..n]);
                last_activity = Instant::now();
                let mut parsed_any = false;
                loop {
                    match frames.next_frame()? {
                        Some(msg) => {
                            parsed_any = true;
                            Metrics::inc(&metrics.frames_in, 1);
                            if !serve_one(msg, router, &mut session, &stream, metrics)? {
                                return Ok(()); // handler panicked: close
                            }
                        }
                        None => break,
                    }
                }
                if frames.buffered() == 0 {
                    frame_start = None;
                } else if parsed_any || frame_start.is_none() {
                    frame_start = Some(Instant::now());
                }
                if drain.load(Ordering::SeqCst) {
                    // Every frame parsed so far is answered (just
                    // above); a draining server reads nothing more.
                    return Ok(());
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: nothing arrived within `tick`.
                if drain.load(Ordering::SeqCst) {
                    return Ok(());
                }
                let now = Instant::now();
                let read_stalled = config.read_timeout != Duration::ZERO
                    && frame_start.map_or(false, |t| now >= t + config.read_timeout);
                let idle = config.idle_timeout != Duration::ZERO
                    && frame_start.is_none()
                    && now >= last_activity + config.idle_timeout;
                if read_stalled || idle {
                    Metrics::inc(&metrics.timeouts, 1);
                    let frame =
                        if read_stalled { stall_timeout_frame() } else { idle_timeout_frame() };
                    if let Some(frame) = frame {
                        if (&stream).write_all(&frame).is_ok() {
                            Metrics::inc(&metrics.frames_out, 1);
                            Metrics::inc(&metrics.net_bytes_out, frame.len() as u64);
                        }
                    }
                    return Ok(());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Dispatch one request on the blocking transport and write its reply.
/// Returns `Ok(false)` when the handler panicked: the error reply has
/// been written and the caller must close the connection (pipelined
/// requests behind the panic are dropped — the session state they
/// would run against is suspect).
fn serve_one(
    msg: Message,
    router: &Router,
    session: &mut SessionState,
    stream: &TcpStream,
    metrics: &Metrics,
) -> Result<bool, ProtoError> {
    // The blocking transport has no worker hand-off: the request
    // dequeues the instant it parses, so queue time is ~0 by
    // construction and the clock feeds the same stage histograms the
    // sharded transports do.
    let clock = ReqClock::new(Proto::Native);
    clock.stamp_parse();
    clock.stamp_dequeue();
    let id = msg.request_id();
    let (reply, keep_going) = match std::panic::catch_unwind(AssertUnwindSafe(|| {
        dispatch_clocked(msg, router, session, Some(&clock))
    })) {
        Ok(reply) => (reply, true),
        Err(_) => {
            Metrics::inc(&metrics.worker_panics, 1);
            let reply = Message::RespError {
                id,
                message: "internal error: request handler panicked".to_string(),
            };
            (reply, false)
        }
    };
    let frame = reply.to_frame_bytes()?;
    clock.stamp_sink();
    if let Err(e) = (&*stream).write_all(&frame) {
        if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) {
            // The peer stopped reading its replies: the write-stall
            // shed, enforced here by the socket write timeout.
            Metrics::inc(&metrics.timeouts, 1);
        }
        return Err(e.into());
    }
    Metrics::inc(&metrics.frames_out, 1);
    Metrics::inc(&metrics.net_bytes_out, frame.len() as u64);
    metrics.record_clock_stages(&clock);
    metrics.record_clock_flush(&clock, "service");
    Ok(keep_going)
}

fn outcome_to_message(id: u64, outcome: Outcome) -> Message {
    match outcome {
        Outcome::Data(data) => Message::RespData { id, data },
        Outcome::Valid => Message::RespData { id, data: Vec::new() },
        Outcome::Invalid(e) => Message::RespError { id, message: e.to_string() },
        Outcome::Rejected(r) => Message::RespError { id, message: r.to_string() },
        Outcome::Internal(m) => Message::RespError { id, message: m },
    }
}

fn stream_err(id: u64, e: StreamError) -> Message {
    Message::RespError { id, message: e.to_string() }
}

/// Resolve a wire codec name against the session's registry: built-in
/// alphabet names keep resolving exactly as before the negotiation
/// extension, new built-ins (`hex`, `base32`, `base32hex` and the
/// aliases) come with the registry, and dynamically registered names
/// are connection-scoped. The legacy "unknown alphabet" error text is
/// preserved for unresolvable names.
fn resolve_codec(session: &SessionState, name: &str) -> Result<CodecSel, ProtoError> {
    session.codecs().resolve(name).ok_or_else(|| ProtoError::UnknownAlphabet(name.to_string()))
}

/// Resolve the codec and run a one-shot request through the router.
#[allow(clippy::too_many_arguments)]
fn one_shot(
    router: &Router,
    session: &SessionState,
    id: u64,
    kind: RequestKind,
    alphabet: String,
    mode: Mode,
    ws: Whitespace,
    data: Vec<u8>,
    clock: Option<&ReqClock>,
) -> Message {
    let codec = match resolve_codec(session, &alphabet) {
        Ok(c) => c,
        Err(e) => return Message::RespError { id, message: e.to_string() },
    };
    let resp =
        router.process_clocked(Request { id, kind, payload: data, codec, mode, ws }, clock);
    outcome_to_message(id, resp.outcome)
}

/// Fault-injection hook for the panic-isolation tests: an `Encode`
/// request naming the reserved alphabet `__faults_panic` panics inside
/// the handler, exactly where a codec bug would. Compiled to nothing
/// without the `faults` feature, so the reserved name cannot be
/// triggered in production builds (there it is just an unknown
/// alphabet).
#[cfg(feature = "faults")]
fn maybe_injected_panic(msg: &Message) {
    if let Message::Encode { alphabet, .. } = msg {
        if alphabet == "__faults_panic" {
            panic!("injected handler panic (faults test hook)");
        }
    }
}

#[cfg(not(feature = "faults"))]
#[inline(always)]
fn maybe_injected_panic(_msg: &Message) {}

/// Execute one request message against the router / session. Shared by
/// both transports: the blocking path calls it inline on the connection
/// thread, the epoll path on a net worker (with the session behind the
/// connection's mutex). The optional request-lifecycle clock is stamped
/// by the router's codec branches; streaming session work stamps its
/// own kernel here, and records its wall clock into the overall latency
/// histogram — stream chunks never pass through the router. `None`
/// skips stage attribution without branching the request path.
pub(crate) fn dispatch_clocked(
    msg: Message,
    router: &Router,
    session: &mut SessionState,
    clock: Option<&ReqClock>,
) -> Message {
    maybe_injected_panic(&msg);
    match msg {
        Message::Encode { id, alphabet, mode, data } => one_shot(
            router,
            session,
            id,
            RequestKind::Encode,
            alphabet,
            mode,
            Whitespace::None,
            data,
            clock,
        ),
        Message::Decode { id, alphabet, mode, ws, data } => {
            // The one-shot whitespace knob (wire tag 0x04) rides through
            // to the router, which strips and rebases error offsets.
            one_shot(router, session, id, RequestKind::Decode, alphabet, mode, ws, data, clock)
        }
        Message::Validate { id, alphabet, mode, data } => one_shot(
            router,
            session,
            id,
            RequestKind::Validate,
            alphabet,
            mode,
            Whitespace::None,
            data,
            clock,
        ),
        Message::StreamBegin { id, decode, alphabet, mode, ws, wrap } => {
            let codec = match resolve_codec(session, &alphabet) {
                Ok(c) => c,
                Err(e) => return Message::RespError { id, message: e.to_string() },
            };
            let r = if decode {
                if wrap != 0 {
                    return Message::RespError {
                        id,
                        message: "wrap is only valid on encode streams".into(),
                    };
                }
                session.open_codec_decode(id, codec, mode, ws)
            } else {
                session.open_codec_encode(id, codec, wrap as usize)
            };
            match r {
                Ok(()) => Message::RespData { id, data: Vec::new() },
                Err(e) => stream_err(id, e),
            }
        }
        Message::CodecHello { id } => Message::RespCodecs { id, codecs: session.codecs().list() },
        Message::CodecRegister { id, name, pad, chars } => {
            match session.codecs_mut().register(&name, &chars, pad) {
                // Success acks with the assigned 16-bit codec id as a
                // little-endian RespData payload; the client may then
                // use the registered name in any request on this
                // connection.
                Ok(cid) => Message::RespData { id, data: cid.to_le_bytes().to_vec() },
                Err(e) => Message::RespError { id, message: e.to_string() },
            }
        }
        // Stream payload work never passes through the router, so it
        // records its wall clock into the overall latency histogram
        // here (the sharded transports' stage histograms get their
        // stamps from the same clock).
        Message::StreamChunk { id, data } => {
            let start = Instant::now();
            let reply = match session.chunk(id, &data) {
                Ok(out) => Message::RespData { id, data: out },
                Err(e) => stream_err(id, e),
            };
            if let Some(c) = clock {
                c.stamp_kernel();
            }
            router.metrics().latency.record(start.elapsed());
            reply
        }
        Message::StreamEnd { id } => {
            let start = Instant::now();
            let reply = match session.finish(id) {
                Ok(out) => Message::RespData { id, data: out },
                Err(e) => stream_err(id, e),
            };
            if let Some(c) = clock {
                c.stamp_kernel();
            }
            router.metrics().latency.record(start.elapsed());
            reply
        }
        Message::Stats => {
            // Mirror the faults layer's injection counter into the
            // metrics snapshot so a chaos run can assert its plan
            // actually fired (always zero without the feature).
            #[cfg(feature = "faults")]
            router
                .metrics()
                .faults_injected
                .store(crate::net::faults::injected(), Ordering::Relaxed);
            Message::RespStats { report: router.metrics().report() }
        }
        Message::Ping => Message::Pong,
        // A server never receives responses; answer with an error frame.
        other => Message::RespError { id: 0, message: format!("unexpected message {other:?}") },
    }
}

/// Resolve a one-shot request's codec, or the error reply to send.
#[allow(clippy::too_many_arguments)]
fn make_request(
    session: &SessionState,
    id: u64,
    kind: RequestKind,
    alphabet: String,
    mode: Mode,
    ws: Whitespace,
    data: Vec<u8>,
) -> Result<Request, Message> {
    match resolve_codec(session, &alphabet) {
        Ok(codec) => Ok(Request { id, kind, payload: data, codec, mode, ws }),
        Err(e) => Err(Message::RespError { id, message: e.to_string() }),
    }
}

/// [`dispatch_clocked`] on the zero-copy reply path: the complete reply
/// frame is written into `sink` instead of materializing a [`Message`].
/// The one-shot hot paths go through [`Router::process_into`], which
/// lets the codec kernels fill the payload in place; everything else
/// (stream control, stats, errors) serializes its small reply directly
/// into the sink. The produced bytes are identical to framing the
/// [`Message`] reply — pinned by the router's parity tests and
/// `rust/tests/transport.rs`. `Err` marks an unframeable (oversized)
/// reply, fatal for the connection on both paths. The clock works as in
/// [`dispatch_clocked`]: the router's sink branches stamp kernel and
/// sink; stream payload replies stamp their own boundaries here, since
/// they bypass the router.
pub(crate) fn dispatch_into_clocked(
    msg: Message,
    router: &Router,
    session: &mut SessionState,
    sink: &mut ReplySink,
    clock: Option<&ReqClock>,
) -> Result<(), ProtoError> {
    // The router's sink-path error is the coordinator-owned
    // `FrameTooLarge`; at this layer it becomes the protocol error the
    // transports treat as fatal.
    let framed = |r: Result<(), crate::coordinator::FrameTooLarge>| {
        r.map_err(|e| ProtoError::FrameTooLarge(e.0))
    };
    maybe_injected_panic(&msg);
    match msg {
        Message::Encode { id, alphabet, mode, data } => {
            match make_request(session, id, RequestKind::Encode, alphabet, mode, Whitespace::None, data)
            {
                Ok(req) => framed(router.process_into_clocked(req, sink, clock)),
                Err(reply) => sink.push_message(&reply),
            }
        }
        Message::Decode { id, alphabet, mode, ws, data } => {
            match make_request(session, id, RequestKind::Decode, alphabet, mode, ws, data) {
                Ok(req) => framed(router.process_into_clocked(req, sink, clock)),
                Err(reply) => sink.push_message(&reply),
            }
        }
        Message::Validate { id, alphabet, mode, data } => {
            match make_request(session, id, RequestKind::Validate, alphabet, mode, Whitespace::None, data)
            {
                Ok(req) => framed(router.process_into_clocked(req, sink, clock)),
                Err(reply) => sink.push_message(&reply),
            }
        }
        // Stream payload replies: the session already materialized the
        // output bytes, so frame them with one copy into the sink
        // instead of the serialize-then-copy `push_message` pair.
        Message::StreamChunk { id, data } => {
            let start = Instant::now();
            let r = match session.chunk(id, &data) {
                Ok(out) => {
                    if let Some(c) = clock {
                        c.stamp_kernel();
                    }
                    sink.push_data(id, &out)
                }
                Err(e) => sink.push_message(&stream_err(id, e)),
            };
            if let Some(c) = clock {
                c.stamp_sink();
            }
            router.metrics().latency.record(start.elapsed());
            r
        }
        Message::StreamEnd { id } => {
            let start = Instant::now();
            let r = match session.finish(id) {
                Ok(out) => {
                    if let Some(c) = clock {
                        c.stamp_kernel();
                    }
                    sink.push_data(id, &out)
                }
                Err(e) => sink.push_message(&stream_err(id, e)),
            };
            if let Some(c) = clock {
                c.stamp_sink();
            }
            router.metrics().latency.record(start.elapsed());
            r
        }
        other => {
            let reply = dispatch_clocked(other, router, session, clock);
            let r = sink.push_message(&reply);
            if let Some(c) = clock {
                c.stamp_sink();
            }
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_parse_table() {
        assert_eq!(Transport::parse("epoll"), Some(Transport::Epoll));
        assert_eq!(Transport::parse("uring"), Some(Transport::Uring));
        assert_eq!(Transport::parse("io_uring"), Some(Transport::Uring));
        assert_eq!(Transport::parse("io-uring"), Some(Transport::Uring));
        assert_eq!(Transport::parse("threaded"), Some(Transport::Threaded));
        assert_eq!(Transport::parse("threads"), Some(Transport::Threaded));
        for bad in ["", "Epoll", "URING", "kqueue", "iouring", " epoll"] {
            assert_eq!(Transport::parse(bad), None, "accepted {bad:?}");
        }
    }

    #[test]
    fn transport_names_round_trip_through_parse() {
        for t in [Transport::Epoll, Transport::Uring, Transport::Threaded] {
            assert_eq!(Transport::parse(t.name()), Some(t));
        }
    }

    #[test]
    fn transport_parse_strict_names_key_value_and_accepted_set() {
        let err = Transport::parse_strict("kqueue").unwrap_err();
        assert_eq!(err.key, "B64SIMD_TRANSPORT");
        assert_eq!(err.value, "kqueue");
        assert_eq!(err.accepted, TRANSPORT_ACCEPTED);
        let msg = err.to_string();
        assert!(msg.contains("B64SIMD_TRANSPORT"), "{msg}");
        assert!(msg.contains("kqueue"), "{msg}");
        assert!(msg.contains("epoll | uring | threaded"), "{msg}");
        assert_eq!(Transport::parse_strict("uring"), Ok(Transport::Uring));
    }

    #[test]
    fn switch_parse_table() {
        for on in ["1", "true", "on"] {
            assert_eq!(ServerConfig::parse_switch(on), Some(true), "{on}");
        }
        for off in ["0", "false", "off"] {
            assert_eq!(ServerConfig::parse_switch(off), Some(false), "{off}");
        }
        for bad in ["", "yes", "no", "ON", "True", "2"] {
            assert_eq!(ServerConfig::parse_switch(bad), None, "accepted {bad:?}");
        }
    }

    #[test]
    fn stream_begin_with_invalid_wrap_is_a_typed_error() {
        // Regression: `MimeCodec::with_line_len` used to assert on a
        // bad line length, so a StreamBegin frame carrying `wrap = 1`
        // panicked the handler (an `0x82` only via the catch_unwind
        // backstop). It must be an ordinary typed error reply.
        use crate::coordinator::backend::rust_factory;
        use crate::coordinator::RouterConfig;
        let router = Router::new(rust_factory(), RouterConfig::default());
        let mut session = SessionState::new(4);
        let reply = dispatch_clocked(
            Message::StreamBegin {
                id: 9,
                decode: false,
                alphabet: "standard".into(),
                mode: Mode::Strict,
                ws: Whitespace::None,
                wrap: 1,
            },
            &router,
            &mut session,
            None,
        );
        match reply {
            Message::RespError { id, message } => {
                assert_eq!(id, 9);
                assert!(message.contains("invalid wrap line length 1"), "{message}");
            }
            other => panic!("want RespError, got {other:?}"),
        }
        assert_eq!(session.open_count(), 0, "failed open must not leak a stream slot");
    }

    #[test]
    fn config_parse_error_is_a_std_error() {
        // `serve` surfaces UringUnsupported/ConfigParseError through
        // anyhow, which requires Error + Send + Sync.
        fn assert_err<E: std::error::Error + Send + Sync + 'static>() {}
        assert_err::<ConfigParseError>();
    }
}
